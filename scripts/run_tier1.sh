#!/usr/bin/env bash
# Tier-1 verify: the line the ROADMAP pins and CI runs.
#
#   scripts/run_tier1.sh [extra pytest args...]
#
# Property tests require `hypothesis` (see requirements-dev.txt); without it
# they skip cleanly and the rest of the suite still runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
