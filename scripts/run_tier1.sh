#!/usr/bin/env bash
# Tier-1 verify: the line the ROADMAP pins and CI runs.
#
#   scripts/run_tier1.sh [extra pytest args...]
#
# Property tests require `hypothesis` (see requirements-dev.txt); without it
# they skip cleanly and the rest of the suite still runs.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# Per-test deadline (pytest-timeout): a hung multi-device exchange or
# subprocess must fail its own test with a traceback, not stall the suite.
# Gated on the plugin being importable — environments without it (the
# pinned container) run identically, just without the deadline.
TIMEOUT_ARGS=()
if python -c "import pytest_timeout" >/dev/null 2>&1; then
  TIMEOUT_ARGS=(--timeout=600 --timeout-method=thread)
fi
# Coverage floor on the serving subsystem (pytest-cov): opt-in via
# REPRO_COV=1 — CI's fast job sets it; the pinned container (no pip
# install) and quick local loops skip it.  Same double gate as the
# timeout: env var AND plugin importable.
COV_ARGS=()
if [ "${REPRO_COV:-0}" = "1" ] && python -c "import pytest_cov" >/dev/null 2>&1; then
  COV_ARGS=(--cov=repro.serving --cov-report=term-missing:skip-covered
            --cov-fail-under="${REPRO_COV_FLOOR:-75}")
fi
exec python -m pytest -x -q \
  ${TIMEOUT_ARGS[@]+"${TIMEOUT_ARGS[@]}"} \
  ${COV_ARGS[@]+"${COV_ARGS[@]}"} \
  "$@"
