"""Regenerate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
results/dryrun/*.json.  Narrative sections live in EXPERIMENTS.header.md and
EXPERIMENTS.perf.md and are concatenated around the generated tables.

    PYTHONPATH=src python scripts/build_experiments.py
"""

import glob
import json
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load():
    rows = []
    for f in sorted(glob.glob(os.path.join(ROOT, "results", "dryrun", "*.json"))):
        d = json.load(open(f))
        if not d.get("skipped"):
            rows.append(d)
    return rows


def dryrun_table(rows):
    out = [
        "| arch | shape | mesh | chips | peak mem/dev | HLO GFLOP/dev | HLO GB/dev | coll MB/dev | #coll ops | compile s |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for d in rows:
        out.append(
            "| {arch} | {shape} | {mesh} | {chips} | {mem:.1f} GiB | {fl:.1f} | {by:.1f} | {co:.1f} | {cnt} | {cs:.0f} |".format(
                arch=d["arch"], shape=d["shape"], mesh=d["mesh"], chips=d["chips"],
                mem=d["peak_memory_per_device"] / 2**30,
                fl=d["flops_per_device"] * d.get("loop_scale", 1) / 1e9,
                by=d["bytes_per_device"] * d.get("loop_scale", 1) / 1e9,
                co=d["collective_bytes_per_device"] * d.get("loop_scale", 1) / 1e6,
                cnt=d.get("hlo_collective_count", d["collective_breakdown"].get("count", 0)),
                cs=d.get("compile_s", 0),
            )
        )
    return "\n".join(out)


def roofline_table(rows):
    out = [
        "| arch | shape | mesh | t_compute | t_memory | t_collective | dominant | MODEL_FLOPS/HLO_FLOPS | one-line lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    levers = {
        "memory": "cut HBM traffic: weight/cache dtype, fewer temp copies, better remat policy",
        "collective": "re-shard to shrink/merge collectives; overlap with compute; hierarchical decomposition",
        "compute": "raise MXU utilisation: larger fused GEMM tiles, drop redundant recompute",
    }
    for d in rows:
        out.append(
            "| {arch} | {shape} | {mesh} | {tc:.2f} ms | {tm:.2f} ms | {tl:.2f} ms | **{dom}** | {uf:.2f} | {lev} |".format(
                arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
                tc=d["t_compute"] * 1e3, tm=d["t_memory"] * 1e3,
                tl=d["t_collective"] * 1e3, dom=d["dominant"],
                uf=d["useful_flops_ratio"], lev=levers[d["dominant"]],
            )
        )
    return "\n".join(out)


def main():
    rows = load()
    head = open(os.path.join(ROOT, "EXPERIMENTS.header.md")).read()
    perf = open(os.path.join(ROOT, "EXPERIMENTS.perf.md")).read()
    single = [d for d in rows if d["mesh"] == "16x16"]
    multi = [d for d in rows if d["mesh"] == "2x16x16"]
    doc = "\n".join(
        [
            head,
            "\n## §Dry-run\n",
            f"All {len(rows)} (architecture × shape × mesh) combinations lower AND compile "
            "(`.lower().compile()`) on the production meshes — 16×16 (256 chips) and "
            "2×16×16 (512 chips, the multi-pod pass that proves the `pod` axis shards). "
            "Raw artifacts: `results/dryrun/*.json` (memory_analysis, cost_analysis, "
            "collective schedule).\n",
            "### Single-pod (16×16)\n",
            dryrun_table(single),
            "\n### Multi-pod (2×16×16)\n",
            dryrun_table(multi),
            "\n## §Roofline (single-pod, per prompt spec)\n",
            "Terms per the spec: `t_x = per-device HLO quantity / per-chip peak` "
            "(197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s ICI link), with the "
            "**loop-scale calibration** described below.\n",
            roofline_table(single),
            "\n",
            perf,
        ]
    )
    open(os.path.join(ROOT, "EXPERIMENTS.md"), "w").write(doc)
    print("wrote EXPERIMENTS.md with", len(rows), "combos")


if __name__ == "__main__":
    main()
