"""Paged-KV shootout: block-table pages vs contiguous per-slot slabs at an
equal KV memory budget.

The tentpole claim: page-indirect KV storage (alloc-on-append, free-on-
release, PagedAttention-style block tables) serves *more concurrent decode
slots from the same KV memory*, because short requests stop paying for the
worst-case context a contiguous slab must reserve.  This bench drives the
continuous-batching engine through a mixed short/long-prompt workload under
three configurations and writes ``BENCH_paged_kv.json`` at the repo root:

* ``contiguous_eqmem`` — contiguous slabs at the *same KV byte budget* as
  the paged pool: 4 slots × 256 rows = 1024 KV rows;
* ``paged``           — paged pool, 64 usable pages × 16 rows = the same
  1024 KV rows, but backing 16 slots (alloc-on-append means a slot only
  holds pages for rows it has actually written);
* ``contiguous_ref``  — contiguous slabs at 16 slots (4× the memory): the
  numerics reference the paged run must match bit-for-bit.

The engine runs the modeled clock (deterministic ``step_time_fn``), the
model is the pure-dense ``phi4-mini-3.8b-reduced`` (no MoE capacity
coupling between slots), and the gates the tentpole must pass are

    slots_ratio           = paged slots / eq-mem contiguous slots ≥ 3,
    streams_bit_identical = paged tokens == contiguous_ref tokens per rid,
    kernel_matches_oracle = paged Pallas kernel ≈ jnp gather oracle.

Run:  PYTHONPATH=src python -m benchmarks.paged_kv_bench
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.request import WorkloadSpec, sample_requests

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_paged_kv.json")

ARCH = "phi4-mini-3.8b-reduced"
CACHE_LEN = 256
PAGE_SIZE = 16
PAGED_SLOTS = 16
# equal-memory contiguous baseline: PAGED_SLOTS·CACHE_LEN/ps usable pages
# would fully back 16 slots — cap the pool at 1024 rows (64 pages + null)
# and give the contiguous baseline the same 1024 rows as 4 full slots
NUM_PAGES = 64 + 1
CONTIG_SLOTS = (NUM_PAGES - 1) * PAGE_SIZE // CACHE_LEN  # = 4

N_LONG, LONG_IN, LONG_OUT = 2, 96, 16
N_SHORT, SHORT_IN, SHORT_OUT = 14, 8, 8
N_REQUESTS = N_LONG + N_SHORT

T_DECODE = 2e-3  # modeled decode clock — the comparison is scheduling-only


def _requests(cfg, seed=0):
    spec = WorkloadSpec(
        mean_input=8, mean_output=8, vocab_size=cfg.vocab_size,
        max_input=LONG_IN, max_output=LONG_OUT, seed=seed,
    )
    # burst arrival: every request is waiting at t=0, so concurrency is
    # limited only by how many slots the KV budget backs
    arr = np.zeros(N_REQUESTS)
    reqs = sample_requests(spec, arr, with_prompts=True)
    rng = np.random.default_rng(seed + 1)
    for i, r in enumerate(reqs):
        if i < N_LONG:
            r.input_len, r.output_len = LONG_IN, LONG_OUT
        else:
            r.input_len, r.output_len = SHORT_IN, SHORT_OUT
        r.prompt = rng.integers(0, cfg.vocab_size, size=r.input_len, dtype=np.int32)
    return reqs


def _peak_concurrency(completed) -> int:
    """Max number of requests simultaneously holding an *active* slot,
    from the (first-token, finished] intervals of the served stream."""
    events = []
    for r in completed:
        events.append((r.prefill_done, 1))
        events.append((r.finished, -1))
    peak = cur = 0
    # releases before starts at ties: same-timestamp slot reuse is not overlap
    for _, d in sorted(events, key=lambda e: (e[0], e[1])):
        cur += d
        peak = max(peak, cur)
    return peak


def _kv_rows_budget(name: str) -> int:
    if name == "paged":
        return (NUM_PAGES - 1) * PAGE_SIZE
    slots = CONTIG_SLOTS if name == "contiguous_eqmem" else PAGED_SLOTS
    return slots * CACHE_LEN


def _kernel_gate(seed=0) -> bool:
    """Paged Pallas kernel (interpreted off-TPU) vs the jnp gather oracle."""
    import jax.numpy as jnp

    from repro.kernels.decode_attention.ops import paged_decode_attention

    rng = np.random.default_rng(seed)
    B, nh, nkv, hd, ps, P, nblk = 4, 8, 2, 64, 16, 13, 3
    q = jnp.asarray(rng.standard_normal((B, nh, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((P, ps, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((P, ps, nkv, hd)), jnp.float32)
    bt = jnp.asarray(rng.permutation(P - 1)[: B * nblk].reshape(B, nblk) + 1, jnp.int32)
    lens = jnp.asarray([5, 16, 33, 48], jnp.int32)
    got = paged_decode_attention(q, k, v, bt, lens)
    want = paged_decode_attention(q, k, v, bt, lens, backend="jnp")
    return bool(jnp.allclose(got, want, atol=1e-5, rtol=1e-5))


def run_modes() -> Dict:
    cfg = get_config(ARCH)
    params = model_mod.init_params(cfg, 0)
    common = dict(
        cache_len=CACHE_LEN, scheduler="none",
        step_time_fn=lambda n_active: T_DECODE,
    )
    modes = [
        ("contiguous_eqmem", dict(max_batch=CONTIG_SLOTS, **common)),
        ("paged", dict(max_batch=PAGED_SLOTS, kv_page_size=PAGE_SIZE,
                       kv_num_pages=NUM_PAGES, **common)),
        ("contiguous_ref", dict(max_batch=PAGED_SLOTS, **common)),
    ]
    results, streams = [], {}
    for name, kw in modes:
        eng = ServingEngine(cfg, params, **kw)
        m = eng.run(_requests(cfg))
        assert m["completed"] == N_REQUESTS, (name, m)
        streams[name] = {r.rid: tuple(r.tokens_out) for r in eng.completed}
        pages = m.get("kv_pages", {})
        results.append(
            {
                "mode": name,
                "slots": kw["max_batch"],
                "kv_rows_budget": _kv_rows_budget(name),
                "peak_concurrent_slots": _peak_concurrency(eng.completed),
                "completed": m["completed"],
                "tokens": m["tokens"],
                "clock_s": round(m["clock"], 4),
                "tpot_p99_ms": round(m["tpot_p99"] * 1e3, 3),
                "pages_peak": pages.get("pages_peak", 0),
                "pages_free_end": pages.get("pages_free", 0),
                "fragmentation": round(pages.get("fragmentation", 0.0), 4),
            }
        )
    by = {r["mode"]: r for r in results}
    assert by["paged"]["kv_rows_budget"] == by["contiguous_eqmem"]["kv_rows_budget"]
    # the paged pool must actually have fit the workload (no overcommit miss)
    assert by["paged"]["pages_peak"] <= NUM_PAGES - 1
    slots_ratio = by["paged"]["slots"] / by["contiguous_eqmem"]["slots"]
    conc_ratio = (
        by["paged"]["peak_concurrent_slots"]
        / max(1, by["contiguous_eqmem"]["peak_concurrent_slots"])
    )
    return {
        "bench": "paged_kv",
        "arch": ARCH,
        "workload": (
            f"mixed {N_SHORT}×(in={SHORT_IN},out={SHORT_OUT}) short + "
            f"{N_LONG}×(in={LONG_IN},out={LONG_OUT}) long"
        ),
        "page_size": PAGE_SIZE,
        "num_pages": NUM_PAGES,
        "kv_rows_budget": _kv_rows_budget("paged"),
        "modeled_clock": {"t_decode_s": T_DECODE},
        "slots_ratio_eqmem": round(slots_ratio, 2),
        "concurrency_ratio_eqmem": round(conc_ratio, 2),
        "slots_gate_3x": bool(slots_ratio >= 3.0),
        "streams_bit_identical": bool(streams["paged"] == streams["contiguous_ref"]),
        "kernel_matches_oracle": _kernel_gate(),
        "modes": results,
    }


def run() -> List[Row]:
    """Harness entry point (benchmarks.run)."""
    report = run_modes()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows: List[Row] = []
    for e in report["modes"]:
        rows.append(
            (
                f"paged_kv/{e['mode']}",
                e["clock_s"] * 1e6,
                f"slots={e['slots']} rows={e['kv_rows_budget']} "
                f"peak_conc={e['peak_concurrent_slots']} "
                f"pages_peak={e['pages_peak']}",
            )
        )
    rows.append(
        (
            "paged_kv/gate",
            0.0,
            f"slots_ratio={report['slots_ratio_eqmem']} "
            f"gate_3x={report['slots_gate_3x']} "
            f"streams_bit_identical={report['streams_bit_identical']} "
            f"kernel_matches_oracle={report['kernel_matches_oracle']}",
        )
    )
    return rows


def main() -> None:
    report = run_modes()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {OUT_PATH}")
    for e in report["modes"]:
        print(
            f"{e['mode']:17s} slots={e['slots']:2d} rows={e['kv_rows_budget']:5d} "
            f"peak_conc={e['peak_concurrent_slots']:2d} clock={e['clock_s']:.3f}s "
            f"pages_peak={e['pages_peak']}"
        )
    print(
        f"slots_ratio={report['slots_ratio_eqmem']} (gate ≥3: {report['slots_gate_3x']}), "
        f"streams identical: {report['streams_bit_identical']}, "
        f"kernel vs oracle: {report['kernel_matches_oracle']}"
    )


if __name__ == "__main__":
    main()
