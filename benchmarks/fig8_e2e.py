"""Fig. 8 — end-to-end TPOT + per-GPU throughput: Janus vs SGLang /
MegaScale-Infer / xDeepServe across batch sizes and SLOs (modeled on the
paper's H100 testbed constants, DeepSeek-V2-style model)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, paper_perf_model, timeit
from repro.core.baselines import CoupledPolicy, FixedUnitPolicy, MonolithicPolicy
from repro.core.scaling import SLOScaler


def _compare(arch: str, slos, batches, n_max: int, slots: int) -> list[Row]:
    from repro.core.baselines import random_numpy

    rng = np.random.default_rng(0)
    pm_janus, _ = paper_perf_model(arch, slots=slots)
    pm_base, _ = paper_perf_model(
        arch, slots=slots,
        scheduler=lambda e, l: random_numpy(e, l, rng)  # baselines schedule randomly
    )
    rows: list[Row] = []
    policies = {
        "sglang": MonolithicPolicy(),
        "megascale": CoupledPolicy(),
        "xdeepserve": FixedUnitPolicy(),
    }
    for slo in slos:
        for B in batches:
            sc = SLOScaler(pm_janus, n_max=n_max)
            # demand that sustains this batch: λ = B / TPOT(B @ reference cfg)
            ref = pm_janus.tpot(B, 4, 8)
            lam = B / ref.tpot
            us = timeit(lambda: sc.scale(lam, slo), repeat=1)
            best = sc.scale(lam, slo)
            if best is None:
                rows.append((f"fig8/{arch}/janus_B{B}_slo{int(slo*1000)}", us, "infeasible"))
                continue
            rows.append(
                (
                    f"fig8/{arch}/janus_B{B}_slo{int(slo*1000)}",
                    us,
                    f"{best.n_a}A{best.n_e}E tpot={best.tpot*1000:.0f}ms tpg={best.tpg:.0f}",
                )
            )
            sc_b = SLOScaler(pm_base, n_max=n_max)
            for name, pol in policies.items():
                d = pol.decide(sc_b, lam, slo)
                ev = sc_b.evaluate(lam, slo, d.n_a, d.n_e)
                tpot = ev.tpot if ev else float("inf")
                tpg = (ev.batch / ev.tpot / d.total_gpus) if ev else 0.0
                ratio = best.tpg / tpg if tpg > 0 else float("inf")
                rows.append(
                    (
                        f"fig8/{arch}/{name}_B{B}_slo{int(slo*1000)}",
                        us,
                        f"{d.n_a}A{d.n_e}E tpot={tpot*1000:.0f}ms tpg={tpg:.0f} janus_x{ratio:.2f}",
                    )
                )
    return rows


def run() -> list[Row]:
    rows = _compare("dsv2-lite", (0.2, 0.15), (64, 256, 512, 1024), n_max=16, slots=12)
    # paper scale: full DeepSeek-V2 (236B) — the monolithic memory floor binds
    # (model alone needs a 16-GPU tier), widening Janus's per-GPU advantage
    rows += _compare("dsv2", (0.2,), (256, 1024), n_max=32, slots=27)
    return rows
