"""Speculative-decode shootout: draft-k/verify-1 multi-token decode vs plain
greedy on both executors, bit-exactness gated through faults and preemption.

The tentpole claim: a small draft model proposes ``spec_k`` tokens per step,
one batched ``decode_step_verify`` scores all ``spec_k + 1`` positions, and
the engine accepts the longest greedy-matching prefix — so every verify round
emits 1..k+1 tokens while the output stream stays *bit-identical to
non-speculative greedy by construction* (rejected rows never dirty the KV
cache; the verify logits at an accepted position depend only on accepted
stream tokens).  This bench drives the continuous-batching engine through a
reduced chat preset on the MoE config and writes ``BENCH_spec_decode.json``
at the repo root:

* ``mono_base`` / ``mono_spec``       — single-pool executor, spec off/on;
* ``disagg_base`` / ``disagg_spec``   — two-pool executor at equal device
  counts, the verify exchange batching k+1 tokens per slot through the
  adaptive two-phase dispatch;
* ``disagg_spec_fault``               — spec on + mid-run attention device
  kill, recovered by deterministic replay;
* ``preempt_base`` / ``preempt_spec`` — priority scheduler, paged KV: a
  high-priority arrival spills a draft-mid-flight slot, which later
  restores and resumes speculating.

The clock is modeled: a plain decode step costs ``T_DECODE``; a verify round
costs ``T_DECODE + (k + 1) * T_DRAFT`` (draft forwards at 1/8 the target
step — the size ratio a real draft pairing buys; the bench self-drafts so
acceptance is the upper bound, making this the amortisation ceiling).  Gates:

    mean accepted tokens/step > 1.5 on the chat preset,
    spec tokens/s > non-spec tokens/s on disagg at equal devices,
    streams bit-identical to non-spec greedy on both executors,
    ... including through the attention kill and a preempt/restore cycle
    (which must actually preempt — the run asserts preemptions >= 1).

Run:  PYTHONPATH=src python -m benchmarks.spec_decode_bench
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.faults import DEVICE_LOSS, FaultPlan, FaultSpec, RetryPolicy
from repro.serving.request import WorkloadSpec, sample_requests

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_spec_decode.json")

ARCH = "dsv2-lite-reduced"  # MoE: verify must survive the scheduled-MoE path
SPEC_K = 3
CACHE_LEN = 64
PAGE_SIZE = 16
N_REQUESTS = 6

T_DECODE = 2e-3  # modeled target decode/verify step
T_DRAFT = T_DECODE / 8  # modeled draft forward (8x-smaller draft)
SPEC_STEP = T_DECODE + (SPEC_K + 1) * T_DRAFT  # one verify round, all-in


def _chat_requests(cfg, n=N_REQUESTS):
    """Chat preset scaled to the reduced configs (short turns, lognormal)."""
    spec = WorkloadSpec(
        mean_input=6.0, mean_output=14.0, vocab_size=cfg.vocab_size,
        max_input=16, max_output=24, seed=3,
    )
    return sample_requests(spec, np.linspace(0.0, 0.01, n), with_prompts=True)


def _streams(eng) -> Dict[int, tuple]:
    return {r.rid: tuple(r.tokens_out) for r in eng.completed}


def _spec_kw(cfg, spec: bool) -> dict:
    if not spec:
        return dict(step_time_fn=lambda n: T_DECODE)
    # self-draft: target params double as the draft (acceptance 1.0 ceiling);
    # the modeled clock charges the k+1 draft forwards at the 8x-smaller rate
    return dict(
        draft_config=cfg, spec_k=SPEC_K, step_time_fn=lambda n: SPEC_STEP
    )


def _run_mono(cfg, params, spec: bool, **kw):
    eng = ServingEngine(
        cfg, params, max_batch=4, cache_len=CACHE_LEN, scheduler="none",
        n_prefill=1, prefill_chunk=4,
        prefill_time_fn=lambda n: n * 1e-3, **_spec_kw(cfg, spec), **kw,
    )
    m = eng.run(_chat_requests(cfg), max_steps=20_000)
    assert m["completed"] == N_REQUESTS, m
    return eng, m


def _run_disagg(cfg, params, layout, spec: bool, **kw):
    eng = ServingEngine(
        cfg, params, max_batch=4, cache_len=CACHE_LEN, layout=layout,
        scheduler="aebs", capacity_tokens=CACHE_LEN, executor="disagg",
        n_attn=2, n_prefill=1, prefill_chunk=4,
        prefill_time_fn=lambda n: n * 1e-3, **_spec_kw(cfg, spec), **kw,
    )
    m = eng.run(_chat_requests(cfg), max_steps=20_000)
    assert m["completed"] == N_REQUESTS, m
    return eng, m


def _run_preempt(cfg, params, spec: bool):
    """Priority scheduler + paged KV: two long low-priority requests fill the
    batch, a high-priority arrival preempts one mid-decode (mid-draft when
    spec is on), and the spilled request later restores and finishes."""
    reqs = _chat_requests(cfg, n=3)
    for r in reqs[:2]:
        r.arrival, r.priority, r.output_len = 0.0, 0, 40
    hi = reqs[2]
    hi.arrival, hi.priority, hi.output_len = 0.012, 5, 6
    eng = ServingEngine(
        cfg, params, max_batch=2, cache_len=CACHE_LEN, scheduler="none",
        n_prefill=1, prefill_chunk=4, kv_page_size=PAGE_SIZE,
        kv_num_pages=17, sched="priority", prefill_time_fn=lambda n: n * 1e-3,
        **_spec_kw(cfg, spec),
    )
    m = eng.run(reqs, max_steps=20_000)
    assert m["completed"] == 3, m
    return eng, m


def _tok_s(m) -> float:
    return m["tokens"] / max(m["clock"], 1e-9)


def run_modes() -> Dict:
    cfg = get_config(ARCH)
    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)

    results = []

    def _record(name, eng, m, devices):
        spec = m.get("spec", {})
        results.append(
            {
                "mode": name,
                "devices": devices,
                "tok_s": round(_tok_s(m), 1),
                "clock_s": round(m["clock"], 4),
                "verify_steps": m.get("spec", {}).get("steps", 0),
                "accepted_per_step": round(spec.get("accepted_per_step", 0.0), 3),
                "acceptance_rate": round(spec.get("acceptance_rate", 0.0), 3),
                "transfer_bytes_per_step": m.get("transfer_bytes_per_step", 0.0),
            }
        )
        return _streams(eng)

    s_mono_base = _record("mono_base", *_run_mono(cfg, params, spec=False), 1)
    s_mono_spec = _record("mono_spec", *_run_mono(cfg, params, spec=True), 1)
    s_dis_base = _record(
        "disagg_base", *_run_disagg(cfg, params, layout, spec=False), 5
    )
    s_dis_spec = _record(
        "disagg_spec", *_run_disagg(cfg, params, layout, spec=True), 5
    )

    plan = FaultPlan(faults=[FaultSpec(DEVICE_LOSS, pool="attn", index=1, at_step=3)])
    eng_f, m_f = _run_disagg(
        cfg, params, layout, spec=True, fault_plan=plan,
        retry_policy=RetryPolicy(recovery_charge_s=0.01),
    )
    s_fault = _record("disagg_spec_fault", eng_f, m_f, 5)

    eng_pb, m_pb = _run_preempt(cfg, params, spec=False)
    s_pre_base = _record("preempt_base", eng_pb, m_pb, 1)
    eng_ps, m_ps = _run_preempt(cfg, params, spec=True)
    s_pre_spec = _record("preempt_spec", eng_ps, m_ps, 1)
    assert m_ps["preemptions"] >= 1, m_ps  # the cycle must actually happen

    by = {r["mode"]: r for r in results}
    gates = {
        "accepted_per_step_gt_1.5": bool(
            by["mono_spec"]["accepted_per_step"] > 1.5
            and by["disagg_spec"]["accepted_per_step"] > 1.5
        ),
        "disagg_spec_tok_s_gt_base": bool(
            by["disagg_spec"]["tok_s"] > by["disagg_base"]["tok_s"]
        ),
        "streams_bit_identical": bool(
            s_mono_spec == s_mono_base
            and s_dis_spec == s_dis_base
            and s_dis_base == s_mono_base
        ),
        "fault_preempt_bit_identical": bool(
            s_fault == s_dis_base
            and s_pre_spec == s_pre_base
            and m_ps["preemptions"] >= 1
            and m_f["faults"]["injected"] >= 1
        ),
    }
    return {
        "bench": "spec_decode",
        "arch": ARCH,
        "spec_k": SPEC_K,
        "draft": "self (acceptance ceiling); modeled 8x-smaller draft cost",
        "workload": f"{N_REQUESTS}x chat preset (lognormal, reduced lengths)",
        "modeled_clock": {
            "t_decode_s": T_DECODE,
            "t_draft_s": T_DRAFT,
            "t_spec_step_s": SPEC_STEP,
        },
        "disagg_speedup": round(
            by["disagg_spec"]["tok_s"] / max(by["disagg_base"]["tok_s"], 1e-9), 2
        ),
        "fault": {
            "injected": m_f["faults"]["injected"],
            "recoveries": m_f["faults"]["recoveries"],
            "degraded": m_f["faults"]["degraded"],
        },
        "preempt": {
            "preemptions": m_ps["preemptions"],
            "restores": m_ps["restores"],
        },
        "gates": gates,
        "modes": results,
    }


def run() -> List[Row]:
    """Harness entry point (benchmarks.run)."""
    report = run_modes()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows: List[Row] = []
    for e in report["modes"]:
        rows.append(
            (
                f"spec_decode/{e['mode']}",
                e["clock_s"] * 1e6,
                f"tok_s={e['tok_s']} accepted_per_step={e['accepted_per_step']}",
            )
        )
    g = report["gates"]
    rows.append(
        (
            "spec_decode/gate",
            0.0,
            f"accepted_per_step_gt_1.5={g['accepted_per_step_gt_1.5']} "
            f"disagg_spec_tok_s_gt_base={g['disagg_spec_tok_s_gt_base']} "
            f"streams_bit_identical={g['streams_bit_identical']} "
            f"fault_preempt_bit_identical={g['fault_preempt_bit_identical']}",
        )
    )
    return rows


def main() -> None:
    report = run_modes()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {OUT_PATH}")
    for e in report["modes"]:
        print(
            f"{e['mode']:18s} tok_s={e['tok_s']:8.1f} "
            f"accepted/step={e['accepted_per_step']:.3f} "
            f"clock={e['clock_s']:.4f}s"
        )
    print(f"disagg_speedup={report['disagg_speedup']}x")
    print("gates:", report["gates"])


if __name__ == "__main__":
    main()
