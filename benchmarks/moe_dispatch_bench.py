"""MoE dispatch-path shootout: einsum vs scatter vs grouped (sort-based).

Times one scheduled MoE dispatch+FFN call — the serving hot path — for each
implementation across (T, E, S) sweeps on a replicated AEBS layout, and
writes ``BENCH_moe_dispatch.json`` at the repo root so the perf trajectory
is tracked from PR to PR.

Paths measured (identical outputs, equivalence-tested in
tests/test_moe_dispatch.py):

* ``einsum``   — one-hot oracle over replica slots + per-slot weight copy
* ``scatter``  — scatter/one-hot dispatch over slots + per-slot weight copy
  (``gather_slot_weights``: 3 × [S, d, f] materialised every call)
* ``grouped``  — production path: sort-based dispatch, AEBS single-replica
  collapse → one batched GEMM over the logical [E, d, f] weights, zero
  weight copies
* ``grouped_indirect`` — grouped dispatch kept on slot buckets with the
  flat slot→expert map (the non-collapsible-scheduler route: stream loop
  over activated slots)
* ``grouped_kernel``   — same, through the Pallas kernel (interpret mode on
  CPU, so timed only on the smallest sweep; compiled on TPU)

Peak-memory figures are analytic estimates of the path-specific transient
buffers (weight copies + dispatch masks/buffers), not device telemetry.

Run:  PYTHONPATH=src python -m benchmarks.moe_dispatch_bench
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.core.amax import make_routing_trace
from repro.models import moe as moe_mod

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_moe_dispatch.json")

# (T, k, E, n_instances, slots_per_instance, d, f)
SWEEPS = [
    (256, 2, 16, 4, 8, 64, 128),
    (512, 2, 32, 8, 6, 128, 256),
    (1024, 4, 64, 8, 12, 128, 256),
]

_F32 = 4


def _mem_estimates(T: int, k: int, E: int, S: int, cap: int, d: int, f: int) -> Dict[str, int]:
    """Analytic per-call transient bytes for each path (f32)."""
    I = T * k
    w_copy = 3 * S * d * f * _F32  # gather_slot_weights materialisation
    return {
        "einsum": w_copy + I * S * cap * _F32 + S * cap * d * _F32,
        "scatter": w_copy + 2 * I * S * _F32 + S * (cap + 1) * d * _F32,
        "grouped": 6 * I * _F32 + E * cap * d * _F32,
        "grouped_indirect": 6 * I * _F32 + S * cap * d * _F32 + 3 * 8 * d * f * _F32,
        "grouped_kernel": 6 * I * _F32 + S * cap * d * _F32 + 3 * d * f * _F32,
    }


def _build_case(T, k, E, n_inst, C, d, f, seed=0):
    layout = ReplicaLayout.round_robin(E, n_inst, C)
    s2e = jnp.asarray(layout.slot_to_expert.reshape(-1))
    S = int(s2e.shape[0])
    cap = moe_mod.default_capacity(T, k, S, 1.5)
    trace = make_routing_trace(max(T, 2048), E, k, skew=0.8, seed=seed)
    eids = jnp.asarray(trace[:T])
    slot_ids, load, _ = aebs_assign(eids, layout.device_tables(), n_inst)
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (T, d), jnp.float32)
    gates = jax.nn.softmax(jax.random.normal(ks[1], (T, k), jnp.float32))
    params = {
        "w_gate": jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.05,
        "w_up": jax.random.normal(ks[3], (E, d, f), jnp.float32) * 0.05,
        "w_down": jax.random.normal(ks[4], (E, f, d), jnp.float32) * 0.05,
    }
    return layout, s2e, S, cap, x, eids, slot_ids, gates, params, int(jnp.max(load))


def _paths(S, cap, E, s2e, with_kernel: bool):
    """jit-able callables (params, x, slot_ids, expert_ids, gates) → [T, d]."""

    def einsum_path(p, x, slot_ids, eids_c, gates):
        w = moe_mod.gather_slot_weights(p, s2e)
        return moe_mod.capacity_dispatch_ffn(x, slot_ids, gates, S, cap, w)

    def scatter_path(p, x, slot_ids, eids_c, gates):
        w = moe_mod.gather_slot_weights(p, s2e)
        return moe_mod.scatter_dispatch_ffn(x, slot_ids, gates, S, cap, w)

    def grouped_path(p, x, slot_ids, eids_c, gates):
        # AEBS activates one replica per expert → slots collapse to experts
        # (exactly what moe_layer(dispatch="grouped") does for AEBS)
        return moe_mod.grouped_dispatch_ffn(x, eids_c, gates, E, cap, p)

    def grouped_indirect_path(p, x, slot_ids, eids_c, gates):
        return moe_mod.grouped_dispatch_ffn(
            x, slot_ids, gates, S, cap, p, slot_to_expert=s2e, backend="stream"
        )

    out = {
        "einsum": einsum_path,
        "scatter": scatter_path,
        "grouped": grouped_path,
        "grouped_indirect": grouped_indirect_path,
    }
    if with_kernel:
        out["grouped_kernel"] = lambda p, x, slot_ids, eids_c, gates: (
            moe_mod.grouped_dispatch_ffn(
                x, slot_ids, gates, S, cap, p, slot_to_expert=s2e, backend="kernel"
            )
        )
    return out


def run_sweeps(repeat: int = 5) -> Dict:
    on_tpu = jax.default_backend() == "tpu"
    results = []
    for i, (T, k, E, n_inst, C, d, f) in enumerate(SWEEPS):
        layout, s2e, S, cap, x, eids, slot_ids, gates, params, a_max = _build_case(
            T, k, E, n_inst, C, d, f, seed=i
        )
        # the collapsed bucket ids the production grouped path dispatches on
        eids_c = jnp.maximum(s2e, 0)[slot_ids]
        # interpret-mode kernels are emulation: time them only where cheap
        with_kernel = on_tpu or (T <= 256)
        mems = _mem_estimates(T, k, E, S, cap, d, f)
        entry = {
            "T": T, "k": k, "E": E, "S": S, "capacity": cap, "d": d, "f": f,
            "n_instances": n_inst, "a_max": a_max, "paths": {},
        }
        ref = None
        for name, fn in _paths(S, cap, E, s2e, with_kernel).items():
            jfn = jax.jit(fn)
            call = lambda: jax.block_until_ready(jfn(params, x, slot_ids, eids_c, gates))
            us = timeit(call, repeat=repeat, warmup=2)
            y = np.asarray(jfn(params, x, slot_ids, eids_c, gates))
            if ref is None:
                ref = y
            else:
                np.testing.assert_allclose(y, ref, atol=1e-4, rtol=1e-3)
            entry["paths"][name] = {
                "wall_ms": round(us / 1e3, 4),
                "peak_mem_est_mb": round(mems[name] / 2**20, 3),
            }
        sc, gr = entry["paths"]["scatter"], entry["paths"]["grouped"]
        entry["speedup_grouped_vs_scatter"] = round(sc["wall_ms"] / gr["wall_ms"], 3)
        results.append(entry)
    return {
        "bench": "moe_dispatch",
        "backend": jax.default_backend(),
        "kernel_mode": "compiled" if on_tpu else "interpret",
        "notes": "scheduled serving shapes; AEBS routing on a replicated "
                 "round-robin layout; skewed (0.8) routing trace; memory "
                 "figures are analytic per-call transient estimates",
        "sweeps": results,
    }


def run() -> List[Row]:
    """Harness entry point (benchmarks.run)."""
    report = run_sweeps(repeat=3)
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows: List[Row] = []
    for e in report["sweeps"]:
        for name, r in e["paths"].items():
            rows.append(
                (
                    f"moe_dispatch/T{e['T']}_E{e['E']}_S{e['S']}/{name}",
                    r["wall_ms"] * 1e3,
                    f"mem={r['peak_mem_est_mb']}MB",
                )
            )
        rows.append(
            (
                f"moe_dispatch/T{e['T']}_E{e['E']}_S{e['S']}/speedup",
                0.0,
                f"grouped_vs_scatter={e['speedup_grouped_vs_scatter']}x",
            )
        )
    return rows


def main() -> None:
    report = run_sweeps()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {OUT_PATH}")
    for e in report["sweeps"]:
        line = " ".join(
            f"{n}={r['wall_ms']:.2f}ms" for n, r in e["paths"].items()
        )
        print(
            f"T={e['T']} E={e['E']} S={e['S']} cap={e['capacity']} "
            f"a_max={e['a_max']}: {line} | grouped vs scatter "
            f"{e['speedup_grouped_vs_scatter']}x"
        )


if __name__ == "__main__":
    main()
