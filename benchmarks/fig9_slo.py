"""Fig. 9 — Janus under various SLOs: the latency/throughput trade-off and
the SLO-dependent configuration choice."""

from __future__ import annotations

from benchmarks.common import Row, paper_perf_model, timeit
from repro.core.scaling import SLOScaler


def run() -> list[Row]:
    pm, _ = paper_perf_model()
    rows: list[Row] = []
    for B in (64, 256, 512):
        ref = pm.tpot(B, 4, 8)
        lam = B / ref.tpot
        # SLO grid spanning the model's own TPOT range (our analytic H100
        # coefficients are tighter than the paper's measured system, so the
        # interesting regime sits at smaller absolute latencies)
        base_ms = ref.tpot * 1000.0
        for mult in (0.4, 0.7, 1.0, 1.5, 3.0):
            slo_ms = base_ms * mult
            sc = SLOScaler(pm, n_max=16)
            us = timeit(lambda: sc.scale(lam, slo_ms / 1000.0), repeat=1)
            best = sc.scale(lam, slo_ms / 1000.0)
            if best is None:
                rows.append((f"fig9/B{B}_slo{slo_ms:.1f}ms", us, "infeasible"))
            else:
                rows.append(
                    (
                        f"fig9/B{B}_slo{slo_ms:.1f}ms",
                        us,
                        f"{best.n_a}A{best.n_e}E tpg={best.tpg:.0f} tpot={best.tpot*1000:.1f}ms",
                    )
                )
    return rows
