"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Run:

    PYTHONPATH=src python -m benchmarks.run [--only fig13,fig15]
"""

from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "table1_memory",
    "fig1_parallelism",
    "fig2_layer_latency",
    "fig3_activation_patterns",
    "fig8_e2e",
    "fig9_slo",
    "fig10_variants",
    "fig11_trace",
    "fig12_ablation",
    "fig13_amax",
    "fig14_moe_latency",
    "fig15_overhead",
    "fig16_search",
    "fig17_bound",
    "sec6_pipelining",
    "engine_schedulers",
    "moe_dispatch_bench",
    "disagg_pipeline_bench",
    "prefill_disagg_bench",
    "fault_recovery_bench",
    "slo_schedule_bench",
    "paged_kv_bench",
    "prefix_cache_bench",
    "spec_decode_bench",
    "roofline_report",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated module prefixes")
    args = ap.parse_args()
    only = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for modname in MODULES:
        if only and not any(modname.startswith(o) for o in only):
            continue
        t0 = time.perf_counter()
        try:
            mod = __import__(f"benchmarks.{modname}", fromlist=["run"])
            for name, us, derived in mod.run():
                print(f"{name},{us:.2f},{derived}")
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{modname}/ERROR,0,{type(e).__name__}: {e}")
        finally:
            dt = time.perf_counter() - t0
            print(f"{modname}/_wall,{dt*1e6:.0f},ok", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
