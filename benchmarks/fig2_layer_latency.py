"""Fig. 2 — latency patterns of attention vs MoE layers.

Left panel: attention latency rises with batch while MoE latency is nearly
flat once all experts are touched.  Right panel: MoE latency is linear in the
number of distinct activated experts.  Derived from the per-layer roofline
coefficients on the paper's H100 constants."""

from __future__ import annotations

from benchmarks.common import Row, timeit
from repro.configs import get_config
from repro.core.comm import H100
from repro.core.scaling import LayerCoeffs


def run() -> list[Row]:
    cfg = get_config("dsv2-lite")
    co = LayerCoeffs.from_config(cfg, H100)
    rows: list[Row] = []
    us = timeit(lambda: LayerCoeffs.from_config(cfg, H100))

    s_ctx = 512.0
    for b in (16, 64, 256, 512, 2048):
        t_attn = max(co.c_a, co.alpha * b + co.c_kv * b * s_ctx)
        rows.append((f"fig2/attn_latency_B{b}", us, f"{t_attn*1e6:.1f}us"))
    # MoE latency vs distinct activated experts (32-expert instance, §2.2)
    for a in (2, 8, 16, 24, 32):
        t_moe = co.beta * a + co.c_e
        rows.append((f"fig2/moe_latency_act{a}", us, f"{t_moe*1e6:.1f}us"))
    # claim check: linearity — ratio of slopes
    t8 = co.beta * 8 + co.c_e
    t32 = co.beta * 32 + co.c_e
    rows.append(("fig2/moe_linear_in_experts", us, f"t32/t8={t32/t8:.2f}"))
    return rows
