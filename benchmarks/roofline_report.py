"""Roofline report — aggregates the dry-run artifacts (results/dryrun/*.json)
into the §Roofline table: three terms, dominant bottleneck, useful-FLOPs
ratio, per (arch × shape × mesh)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row


def run() -> list[Row]:
    rows: list[Row] = []
    files = sorted(glob.glob(os.path.join("results", "dryrun", "*.json")))
    if not files:
        return [("roofline/missing", 0.0, "run `python -m repro.launch.dryrun --all` first")]
    for f in files:
        d = json.load(open(f))
        if d.get("skipped"):
            continue
        name = f"roofline/{d['arch']}__{d['shape']}__{d['mesh']}"
        total = max(d["t_compute"], d["t_memory"], d["t_collective"])
        rows.append(
            (
                name,
                d.get("compile_s", 0.0) * 1e6,
                f"t_comp={d['t_compute']*1e3:.2f}ms t_mem={d['t_memory']*1e3:.2f}ms "
                f"t_coll={d['t_collective']*1e3:.2f}ms dom={d['dominant']} "
                f"mem/dev={d['peak_memory_per_device']/2**30:.1f}GiB "
                f"useful_flops={d['useful_flops_ratio']:.2f}",
            )
        )
    return rows
