"""Two-pool decode shootout: mono vs disagg vs disagg+ping-pong.

Measures one continuous-batching decode step of ``dsv2-lite-reduced`` across
(batch, n_a, n_e) sweeps in three execution modes and writes
``BENCH_disagg_pipeline.json`` at the repo root:

* ``mono``            — the jitted monolithic ``decode_step`` (one device);
* ``disagg``          — :class:`repro.serving.disagg.DisaggExecutor`,
  sequential per-layer exchange (attention pool → MoE pool → back);
* ``disagg_pingpong`` — the same executor with m=2 micro-batch ping-pong
  (attention of micro-batch i overlapped with MoE of micro-batch i+1).

Because forced-host CPU "devices" share one execution queue, the wall clock
cannot express cross-pool overlap; the overlap figure is therefore composed
from the *measured per-stage times* (each stage timed with barriers): the
pipelined step is bounded by the busier pool plus hand-off sync, which is
exactly the §6 pipeline model — the analytic prediction from
``benchmarks.sec6_pipelining.pipeline_times`` is printed next to every
measured row.  On genuinely disjoint hardware the wall clock converges to
the composed bound.

Run:  PYTHONPATH=src python -m benchmarks.disagg_pipeline_bench
"""

from __future__ import annotations

import json
import os

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, paper_perf_model, timeit
from benchmarks.sec6_pipelining import SYNC, pipeline_times
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.models import model as model_mod
from repro.launch.steps import build_disagg_executor

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_disagg_pipeline.json")

ARCH = "dsv2-lite-reduced"
CACHE_LEN = 64
# (batch, n_attn, n_moe)
SWEEPS = [(32, 2, 2), (256, 2, 2), (256, 2, 4), (512, 2, 4)]


def _setup(cfg, B):
    params = model_mod.init_params(cfg, 0)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0, cfg.vocab_size)
    positions = jnp.full((B,), CACHE_LEN // 2, jnp.int32)
    caches = model_mod.init_decode_caches(cfg, B, CACHE_LEN)
    return params, tokens, positions, caches


def _bench_mono(cfg, params, tokens, positions, caches, layout, cap, repeat):
    from repro.core.aebs import aebs_assign

    moe_ctx = dict(
        dispatch="grouped",
        layout_tables=layout.device_tables(),
        slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
        num_instances=layout.num_instances,
        scheduler=aebs_assign,
        capacity=cap,
    )
    step = jax.jit(
        lambda p, t, c, i: model_mod.decode_step(p, t, c, i, cfg, extra={"moe_ctx": moe_ctx})
    )
    call = lambda: jax.block_until_ready(step(params, tokens, caches, positions)[0])
    return timeit(call, repeat=repeat, warmup=2)


def run_sweeps(repeat: int = 5) -> Dict:
    cfg = get_config(ARCH)
    pm, _ = paper_perf_model()
    results = []
    for B, n_a, n_e in SWEEPS:
        params, tokens, positions, caches = _setup(cfg, B)
        layout = ReplicaLayout.round_robin(cfg.num_experts, n_e, 2)
        cap = 4 * B  # ample: keeps the three modes token-identical
        mono_us = _bench_mono(cfg, params, tokens, positions, caches, layout, cap, repeat)

        def make(pp):
            ex = build_disagg_executor(
                cfg, params, n_a, n_e, max_batch=B, cache_len=CACHE_LEN,
                layout=layout, capacity=cap, ping_pong=pp,
            )
            ex.load_caches(caches)
            return ex

        ex_seq = make(False)
        seq_us = timeit(
            lambda: jax.block_until_ready(ex_seq.decode_step(tokens, positions)[0]),
            repeat=repeat, warmup=2,
        )
        st: Dict[str, float] = {}
        n_meas = max(2, repeat - 1)
        for _ in range(n_meas):
            _, tel = ex_seq.decode_step(tokens, positions, collect_stage_times=True)
            for kk, vv in tel["stage_times"].items():
                st[kk] = st.get(kk, 0.0) + vv / n_meas

        ex_pp = make(True)
        pp_us = timeit(
            lambda: jax.block_until_ready(ex_pp.decode_step(tokens, positions)[0]),
            repeat=repeat, warmup=2,
        )

        # overlap-composed pipelined step from the measured sequential stage
        # times: with m=2 ping-pong the attention pool runs attention +
        # exchange + combine while the MoE pool runs the expert stages, so on
        # disjoint pools the step is bounded by the busier pool plus the
        # per-micro-batch hand-off sync and the (unoverlapped) head.
        n_layers = cfg.num_layers
        attn_pool = st["attn"] + st["exchange"] + st["combine"]
        moe_pool = st["moe"]
        pipelined = max(attn_pool, moe_pool) + st["head"] + 2 * n_layers * SYNC
        sequential = st["attn"] + st["exchange"] + st["moe"] + st["combine"] + st["head"]

        t_seq_pred, pipes_pred = pipeline_times(pm, B, n_a, n_e, ms=(2,))
        entry = {
            "arch": ARCH, "batch": B, "n_attn": n_a, "n_moe": n_e,
            "mono_step_ms": round(mono_us / 1e3, 3),
            "disagg_step_ms": round(seq_us / 1e3, 3),
            "disagg_pingpong_wall_ms": round(pp_us / 1e3, 3),
            "disagg_stage_ms": {k: round(v * 1e3, 3) for k, v in st.items()},
            "disagg_composed_ms": round(sequential * 1e3, 3),
            "pingpong_composed_ms": round(pipelined * 1e3, 3),
            "pingpong_overlap_gain": round(1.0 - pipelined / max(sequential, 1e-12), 3),
            "regime": tel["regime"],
            "transfer_bytes_per_step": tel["bytes_total"],
            "analytic_paper_scale": {
                "t_seq_us": round(t_seq_pred * 1e6, 1),
                "t_pipe_m2_us": round(pipes_pred[2] * 1e6, 1),
            },
            # require a material margin (>5%) so the gate can actually fail
            # when pool work becomes too imbalanced or sync overhead grows —
            # max(a,b) < a+b alone would be a tautology
            "pingpong_beats_sequential": bool(pipelined < 0.95 * sequential),
        }
        results.append(entry)
    return {
        "bench": "disagg_pipeline",
        "backend": jax.default_backend(),
        "devices": len(jax.devices()),
        "notes": "wall times on shared-core forced-host devices serialise "
                 "cross-pool work; *_composed_ms compose measured per-stage "
                 "times into the two-pool schedule (the §6 pipeline bound); "
                 "ample capacity so all three modes emit identical tokens",
        "sweeps": results,
    }


def run() -> List[Row]:
    """Harness entry point (benchmarks.run)."""
    report = run_sweeps(repeat=3)
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows: List[Row] = []
    for e in report["sweeps"]:
        rows.append(
            (
                f"disagg_pipeline/B{e['batch']}_a{e['n_attn']}e{e['n_moe']}",
                e["disagg_step_ms"] * 1e3,
                f"mono={e['mono_step_ms']}ms seq={e['disagg_composed_ms']}ms "
                f"pp={e['pingpong_composed_ms']}ms ({e['regime']}) "
                f"analytic_seq={e['analytic_paper_scale']['t_seq_us']}us",
            )
        )
    return rows


def main() -> None:
    report = run_sweeps()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {OUT_PATH}  (devices={report['devices']})")
    for e in report["sweeps"]:
        print(
            f"B={e['batch']} {e['n_attn']}A{e['n_moe']}E [{e['regime']}]: "
            f"mono={e['mono_step_ms']}ms disagg={e['disagg_step_ms']}ms "
            f"pp_wall={e['disagg_pingpong_wall_ms']}ms | composed seq="
            f"{e['disagg_composed_ms']}ms pp={e['pingpong_composed_ms']}ms "
            f"(gain {e['pingpong_overlap_gain']:.0%}) | §6 analytic "
            f"seq={e['analytic_paper_scale']['t_seq_us']}us "
            f"pipe={e['analytic_paper_scale']['t_pipe_m2_us']}us"
        )


if __name__ == "__main__":
    main()
