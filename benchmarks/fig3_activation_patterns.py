"""Fig. 3 — MoE-layer latency under token volume and activation skew: with
all experts activated, batch size and skew have only marginal impact
(latency is set by distinct activated experts, not token counts)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timeit
from repro.configs import get_config
from repro.core.aebs import aebs_numpy
from repro.core.amax import make_routing_trace
from repro.core.comm import H100
from repro.core.placement import build_layout
from repro.core.scaling import LayerCoeffs


def run() -> list[Row]:
    cfg = get_config("dsv2-lite")
    co = LayerCoeffs.from_config(cfg, H100)
    E, k, n_e, C = 32, 1, 1, 32  # the paper's single-GPU 32-expert instance
    rows: list[Row] = []
    for skew_name, skew in (("uniform", 0.0), ("skewed", 1.2)):
        trace = make_routing_trace(8192, E, k, skew=skew, seed=3)
        layout = build_layout(trace, E, n_e, C)
        for B in (64, 256, 1024, 4096):
            rng = np.random.default_rng(B)
            acts = []
            for _ in range(8):
                s = trace[rng.integers(0, len(trace), B)]
                acts.append(aebs_numpy(s, layout)[1].max())
            a = float(np.mean(acts))
            t = (co.beta * a + co.c_e) * 1e6
            us = timeit(lambda: aebs_numpy(trace[:B], layout), repeat=3)
            rows.append((f"fig3/{skew_name}_B{B}", us, f"act={a:.1f}/32 latency={t:.0f}us"))
    return rows
