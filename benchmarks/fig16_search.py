"""Fig. 16 — the (n_a, n_e) scaling search space: feasibility structure and
the selected configuration for three representative cases."""

from __future__ import annotations

from benchmarks.common import Row, paper_perf_model, timeit
from repro.core.scaling import SLOScaler


def run() -> list[Row]:
    pm, _ = paper_perf_model()
    rows: list[Row] = []
    cases = [(64, 0.2), (256, 0.2), (512, 0.3)]
    for B, slo in cases:
        sc = SLOScaler(pm, n_max=12)
        lam = B / pm.tpot(B, 4, 8).tpot
        us = timeit(lambda: sc.scale(lam, slo), repeat=1)
        best = sc.scale(lam, slo)
        feas = [r for r in sc.search_log if r.feasible]
        infeas = [r for r in sc.search_log if not r.feasible]
        tag = f"{best.n_a}A{best.n_e}E" if best else "none"
        rows.append(
            (
                f"fig16/B{B}_slo{int(slo*1000)}",
                us,
                f"selected={tag} feasible={len(feas)} infeasible={len(infeas)} "
                f"best_tpg={best.tpg:.0f}" if best else "infeasible",
            )
        )
    return rows
