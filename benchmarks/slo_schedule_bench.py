"""SLO-aware scheduling shootout: FIFO vs priority+preemption.

Replays one bursty multi-tenant :class:`TraceSpec` — a high-priority chat
tenant with a tight TTFT SLO arriving in bursts over a low-priority
batch-offline tenant that keeps every decode slot busy — through the real
engine (mono executor, paged KV, modeled clock) at *equal devices*, under
both admission schedulers, and writes ``BENCH_slo_schedule.json`` at the
repo root with the acceptance gates:

* ``priority_beats_fifo``   — priority+preemption attains strictly more
  SLOs than FIFO on the same trace and the same hardware;
* ``preemptions_exercised`` — the priority run actually spilled KV (the
  win must come from preemption, not luck);
* ``streams_bit_identical`` — every preempted/restored request's token
  stream is bit-identical to its uninterrupted FIFO stream (KV
  spill/restore is a block-table move, not a recompute);
* ``replay_10k_completed``  — the ≥10k-request slice of the same workload
  replays through the ClusterSimulator's scaling policies in CI time.

Run:  PYTHONPATH=src python -m benchmarks.slo_schedule_bench
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

from benchmarks.common import Row
from repro.configs import get_config
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.trace import TenantSpec, TraceSpec

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_slo_schedule.json")

ARCH = "phi4-mini-3.8b-reduced"
T_DECODE = 2e-3  # modeled decode-step clock (deterministic timing)

# the engine-replay trace: small enough for CI, contended enough that FIFO
# parks chat bursts behind batch-offline decodes
ENGINE_TRACE = TraceSpec(
    duration=0.25,
    seed=7,
    tenants=[
        TenantSpec(
            name="batch",
            klass="batch-offline",
            rate=60.0,
            arrival="poisson",
            priority=0,
            ttft_slo=5.0,
            workload=dict(mean_input=6, mean_output=28, max_input=12, max_output=36),
        ),
        TenantSpec(
            name="chat",
            klass="chat",
            rate=40.0,
            arrival="bursty",
            burstiness=4.0,
            epoch=0.05,
            priority=5,
            ttft_slo=0.02,
            workload=dict(mean_input=6, mean_output=8, max_input=12, max_output=12),
        ),
    ],
)

# the simulator-replay trace: same tenant mix, scaled past 10k requests
SIM_TRACE = TraceSpec(
    duration=120.0,
    seed=7,
    tenants=[
        TenantSpec(name="batch", klass="batch-offline", rate=25.0,
                   arrival="poisson", priority=0,
                   workload=dict(mean_output=64.0, max_output=256)),
        TenantSpec(name="chat", klass="chat", rate=60.0, arrival="bursty",
                   burstiness=4.0, epoch=10.0, priority=5),
    ],
)


def _engine(cfg, params, sched: str) -> ServingEngine:
    return ServingEngine(
        cfg, params, max_batch=4, cache_len=64, scheduler="none",
        step_time_fn=lambda n_active: T_DECODE,
        kv_page_size=16, sched=sched,
    )


def run_scenarios() -> Dict:
    cfg = get_config(ARCH)
    params = model_mod.init_params(cfg, 0)

    runs = {}
    streams = {}
    for sched in ("fifo", "priority"):
        eng = _engine(cfg, params, sched)
        reqs = ENGINE_TRACE.build(vocab_size=cfg.vocab_size, with_prompts=True)
        m = eng.run(reqs, max_steps=50_000)
        assert m["completed"] == len(reqs), (sched, m)
        streams[sched] = {r.rid: tuple(r.tokens_out) for r in eng.completed}
        runs[sched] = {
            "completed": m["completed"],
            "preemptions": m["preemptions"],
            "restores": m["restores"],
            "slo_attainment": m["slo"]["attainment"],
            "slo_per_tenant": m["slo"]["per_tenant"],
            "ttft_p99_ms": round(m["ttft_p99"] * 1e3, 3),
            "clock_s": round(m["clock"], 4),
        }

    # the FIFO run never preempts, so it doubles as the uninterrupted
    # baseline: identical per-rid streams prove spill/restore is lossless
    bit_identical = streams["fifo"] == streams["priority"]

    # ≥10k-request replay through the analytic scaling policies (the same
    # workload family, binned into windows of actual sampled token demand)
    from repro.core.amax import MonteCarloAmax, make_routing_trace
    from repro.core.scaling import PerfModel
    from repro.serving.simulator import ClusterSimulator

    sim_cfg = get_config("dsv2-lite")
    routing = make_routing_trace(2048, sim_cfg.num_experts, sim_cfg.top_k,
                                 skew=0.8, seed=0)
    pm = PerfModel(sim_cfg, amax_estimator=MonteCarloAmax(
        routing, sim_cfg.num_experts, trials=4), slots_per_instance=12, s_ctx=512)
    sim = ClusterSimulator(pm, slo=0.2, n_max=8)
    sim_reqs = SIM_TRACE.build(with_prompts=False)
    sim_results = sim.replay(sim_reqs, window_s=10.0)
    n_windows = len(sim_results["janus"].records)

    report = {
        "arch": ARCH,
        "engine_trace_requests": runs["fifo"]["completed"],
        "runs": runs,
        "simulator_replay": {
            "requests": len(sim_reqs),
            "windows": n_windows,
            "policies": {
                name: {
                    "slo_attainment": round(res.slo_attainment, 4),
                    "mean_gpus": round(res.mean_gpus, 2),
                    "slo_per_device": round(res.slo_per_device, 5),
                }
                for name, res in sim_results.items()
            },
        },
        "gates": {
            "priority_beats_fifo": bool(
                runs["priority"]["slo_attainment"] > runs["fifo"]["slo_attainment"]
            ),
            "preemptions_exercised": bool(runs["priority"]["preemptions"] >= 1),
            "streams_bit_identical": bool(bit_identical),
            "replay_10k_completed": bool(
                len(sim_reqs) >= 10_000
                and n_windows > 0
                and all(len(r.records) == n_windows for r in sim_results.values())
            ),
        },
    }
    return report


def run() -> List[Row]:
    report = run_scenarios()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows: List[Row] = []
    for sched, r in report["runs"].items():
        rows.append((
            f"slo_schedule/{sched}",
            r["ttft_p99_ms"] * 1e3,  # us
            f"attain={r['slo_attainment']:.3f} preempt={r['preemptions']}",
        ))
    for name, pol in report["simulator_replay"]["policies"].items():
        rows.append((
            f"slo_schedule/replay_{name}",
            0.0,
            f"attain={pol['slo_attainment']} spd={pol['slo_per_device']}",
        ))
    gates = report["gates"]
    rows.append((
        "slo_schedule/gates",
        0.0,
        "all_pass" if all(gates.values()) else json.dumps(gates),
    ))
    return rows


def main() -> None:
    for name, us, derived in run():
        print(f"{name},{us:.2f},{derived}")
    print(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    main()
