"""Fig. 12 — ablation of Janus's mechanisms: one-phase vs two-phase
communication × attention-side vs MoE-side gating × ±AEBS."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, paper_perf_model, timeit
from repro.core.baselines import random_numpy


def run() -> list[Row]:
    rng = np.random.default_rng(0)
    pm_aebs, _ = paper_perf_model()
    pm_rand, _ = paper_perf_model(scheduler=lambda e, l: random_numpy(e, l, rng))
    n_a, n_e = 4, 8
    rows: list[Row] = []
    variants = [
        ("1PC+EGate+AEBS", pm_aebs, "1pc"),
        ("2PC+AGate+rand", pm_rand, "agate"),
        ("2PC+EGate+rand", pm_rand, "2pc"),
        ("2PC+EGate+AEBS(full)", pm_aebs, "2pc"),
    ]
    full = None
    for B in (64, 256, 512):
        us = timeit(lambda: pm_aebs.tpot(B, n_a, n_e), repeat=2)
        results = {}
        for name, pm, scheme in variants:
            r = pm.tpot(B, n_a, n_e, scheme=scheme)
            results[name] = r.tpot
        full = results["2PC+EGate+AEBS(full)"]
        for name, tpot in results.items():
            rel = tpot / full
            rows.append(
                (f"fig12/{name}_B{B}", us, f"tpot={tpot*1000:.1f}ms vs_full={rel:.2f}x")
            )
    return rows
