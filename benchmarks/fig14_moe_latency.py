"""Fig. 14 — MoE-layer latency under scheduling policies (β·a_max + c_e with
a_max from real scheduler execution; H100 coefficients)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timeit
from repro.configs import get_config
from repro.core.aebs import aebs_numpy
from repro.core.amax import make_routing_trace
from repro.core.baselines import token_hash_numpy
from repro.core.comm import H100
from repro.core.placement import build_layout
from repro.core.scaling import LayerCoeffs


def run() -> list[Row]:
    cfg = get_config("dsv2-lite")
    co = LayerCoeffs.from_config(cfg, H100)
    E, k, C = cfg.num_experts, cfg.top_k, 12
    trace = make_routing_trace(16384, E, k, skew=1.0, seed=2)
    rng = np.random.default_rng(3)
    rows: list[Row] = []
    for n_e in (8, 16):
        layout = build_layout(trace, E, n_e, C)
        for B in (64, 256, 512):
            idxs = [rng.integers(0, trace.shape[0], B) for _ in range(10)]
            a_j = np.mean([aebs_numpy(trace[i], layout)[1].max() for i in idxs])
            a_e = np.mean([token_hash_numpy(trace[i], layout)[1].max() for i in idxs])
            t_j = (co.beta * a_j + co.c_e) * 1e6
            t_e = (co.beta * a_e + co.c_e) * 1e6
            us = timeit(lambda: aebs_numpy(trace[idxs[0]], layout), repeat=3)
            rows.append(
                (
                    f"fig14/E{n_e}_B{B}",
                    us,
                    f"janus={t_j:.0f}us eplb={t_e:.0f}us speedup={t_e/t_j:.2f}x",
                )
            )
    return rows
