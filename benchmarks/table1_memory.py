"""Table 1 — expert vs total memory footprint of MoE configs (the motivation
for disaggregation: experts dominate)."""

from __future__ import annotations

from benchmarks.common import Row, timeit
from repro.configs import REGISTRY


def run() -> list[Row]:
    rows: list[Row] = []
    for name in ("qwen2-moe-a2.7b", "phi3.5-moe-42b-a6.6b", "dsv2-lite", "scaled-ds-1", "scaled-ds-2"):
        cfg = REGISTRY[name]
        us = timeit(cfg.param_counts)
        pc = cfg.param_counts()
        tot = sum(pc.values()) * cfg.bytes_per_param() / 2**30
        exp = pc["expert"] * cfg.bytes_per_param() / 2**30
        rows.append(
            (f"table1/{name}", us, f"expert={exp:.1f}GiB total={tot:.1f}GiB ratio={exp/tot*100:.1f}%")
        )
    return rows
