"""Prefix-cache shootout: radix block-table splicing + batched prefill vs
cold chunked prefill on the shared-system-prompt workload.

The tentpole claim: when every prompt opens with the same system prompt,
page-granular prefix caching serves the shared span by *splicing page ids
into the new slot's block table* — zero recompute, zero KV copy — so warm
TTFT collapses to the cost of the unique tail, and batched multi-prompt
prefill amortises the per-call overhead across concurrent cold prompts.
This bench drives the continuous-batching engine through the
``shared_prefix_spec`` workload under four mono configurations plus a
disaggregated cold/warm/fault triple, and writes
``BENCH_prefix_cache.json`` at the repo root:

* ``cold``       — staggered arrivals, prefix cache off (every prompt pays
  full chunked prefill);
* ``warm``       — same arrivals, prefix cache on: request 0 publishes the
  shared pages, requests 1..N-1 splice them (hit rate (N-1)/N);
* ``cold_burst`` — all arrivals at t=0, cache off, serial prefill: the
  throughput baseline;
* ``batched``    — same burst, cache on + ``prefill_batch=4``: concurrent
  cold prompts fuse into one padded-and-masked prefill call per device.

The clocks are modeled (deterministic ``step_time_fn`` /
``prefill_time_fn`` with a fixed per-call overhead, so batching has
something real to amortise) and the gates the tentpole must pass are

    warm_ttft < cold_ttft,
    hit_rate ≥ 0.8 on the shared-prompt preset,
    batched prefill throughput > cold serial throughput,
    streams bit-identical: warm == cold, batched == cold_burst, and the
    disagg warm run == disagg cold — including with a mid-run attention
    device kill while the cache is live.

Run:  PYTHONPATH=src python -m benchmarks.prefix_cache_bench
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.faults import DEVICE_LOSS, FaultPlan, FaultSpec, RetryPolicy
from repro.serving.request import sample_requests, shared_prefix_spec

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_prefix_cache.json")

ARCH = "phi4-mini-3.8b-reduced"
DISAGG_ARCH = "dsv2-lite-reduced"
PAGE_SIZE = 16
CACHE_LEN = 160  # max prompt (48 shared + 32 tail) + max output (64), paged
CHUNK = 8
N_REQUESTS = 10
STAGGER = 0.2  # s between arrivals — request i publishes before i+1 submits

T_DECODE = 2e-3  # modeled decode step
T_PREFILL_FIX = 1e-3  # fixed per-prefill-call overhead (what batching saves)
T_PREFILL_TOK = 1e-3  # per prompt token


def _requests(cfg, burst: bool):
    spec = shared_prefix_spec(vocab_size=cfg.vocab_size)
    arr = np.zeros(N_REQUESTS) if burst else np.arange(N_REQUESTS) * STAGGER
    return sample_requests(spec, arr, with_prompts=True)


def _streams(eng) -> Dict[int, tuple]:
    return {r.rid: tuple(r.tokens_out) for r in eng.completed}


def _prefill_tok_s(eng) -> float:
    """Prefill-pool throughput: prompt tokens the pool made decodable per
    second of makespan (arrival of the first prompt → last first-token).
    Spliced prefix spans count — serving them from shared pages *is* the
    speedup being measured."""
    done = max(r.prefill_done for r in eng.completed)
    t0 = min(r.arrival for r in eng.completed)
    toks = sum(r.input_len for r in eng.completed)
    return toks / max(done - t0, 1e-9)


def _run(cfg, params, burst: bool, **kw):
    eng = ServingEngine(
        cfg, params, max_batch=4, cache_len=CACHE_LEN, scheduler="none",
        n_prefill=1, prefill_chunk=CHUNK, kv_page_size=PAGE_SIZE,
        step_time_fn=lambda n: T_DECODE,
        prefill_time_fn=lambda n: T_PREFILL_FIX + n * T_PREFILL_TOK,
        **kw,
    )
    m = eng.run(_requests(cfg, burst), max_steps=20_000)
    assert m["completed"] == N_REQUESTS, m
    return eng, m


def _run_disagg(cfg, params, layout, **kw):
    # requests are sampled fresh per run (deterministic seed → identical
    # prompts) — Request objects carry runtime state and must not be reused
    reqs = _disagg_requests(cfg)
    eng = ServingEngine(
        cfg, params, max_batch=4, cache_len=64, layout=layout,
        scheduler="aebs", capacity_tokens=64, executor="disagg",
        n_attn=2, n_prefill=1, prefill_chunk=4, kv_page_size=PAGE_SIZE,
        step_time_fn=lambda n: T_DECODE,
        prefill_time_fn=lambda n: T_PREFILL_FIX + n * T_PREFILL_TOK,
        **kw,
    )
    m = eng.run(reqs, max_steps=20_000)
    assert m["completed"] == len(reqs), m
    return eng, m


def _disagg_requests(cfg, n=6):
    spec = shared_prefix_spec(
        vocab_size=cfg.vocab_size, shared_prefix_len=12, mean_input=4.0,
        max_input=8, mean_output=8.0, max_output=12,
    )
    return sample_requests(spec, np.arange(n) * 0.5, with_prompts=True)


def run_modes() -> Dict:
    cfg = get_config(ARCH)
    params = model_mod.init_params(cfg, 0)

    modes = [
        ("cold", False, {}),
        ("warm", False, dict(prefix_cache=True)),
        ("cold_burst", True, {}),
        ("batched", True, dict(prefix_cache=True, prefill_batch=4)),
    ]
    results, streams = [], {}
    for name, burst, kw in modes:
        eng, m = _run(cfg, params, burst, **kw)
        streams[name] = _streams(eng)
        prefix = m.get("prefix_cache", {})
        results.append(
            {
                "mode": name,
                "arrivals": "burst" if burst else f"stagger {STAGGER}s",
                "ttft_mean_ms": round(m["ttft_mean"] * 1e3, 3),
                "prefill_tok_s": round(_prefill_tok_s(eng), 1),
                "clock_s": round(m["clock"], 4),
                "hit_rate": round(prefix.get("hit_rate", 0.0), 3),
                "saved_tokens": prefix.get("saved_tokens", 0),
                "saved_frac": round(prefix.get("saved_frac", 0.0), 3),
                "shared_pages": prefix.get("shared_pages", 0),
            }
        )
    by = {r["mode"]: r for r in results}

    # disagg triple: cold / warm / warm + mid-run attention-device kill —
    # per-shard indexes must keep the PR-4 bit-identical-streams invariant
    # through splice, re-shard and fault replay
    cfg2 = get_config(DISAGG_ARCH)
    params2 = model_mod.init_params(cfg2, 0)
    layout = ReplicaLayout.round_robin(cfg2.num_experts, 2, 3)
    d_cold, _ = _run_disagg(cfg2, params2, layout)
    d_warm, dm_warm = _run_disagg(cfg2, params2, layout, prefix_cache=True)
    plan = FaultPlan(faults=[FaultSpec(DEVICE_LOSS, pool="attn", index=1, at_step=6)])
    d_fault, dm_fault = _run_disagg(
        cfg2, params2, layout, prefix_cache=True, fault_plan=plan,
        retry_policy=RetryPolicy(recovery_charge_s=0.01),
    )
    disagg = {
        "arch": DISAGG_ARCH,
        "warm_hit_rate": round(dm_warm["prefix_cache"]["hit_rate"], 3),
        "warm_streams_match_cold": bool(_streams(d_warm) == _streams(d_cold)),
        "fault_streams_match_cold": bool(_streams(d_fault) == _streams(d_cold)),
        "fault_injected": dm_fault["faults"]["injected"],
        "fault_recoveries": dm_fault["faults"]["recoveries"],
        "fault_degraded": dm_fault["faults"]["degraded"],
    }

    gates = {
        "warm_ttft_lt_cold": bool(by["warm"]["ttft_mean_ms"] < by["cold"]["ttft_mean_ms"]),
        "hit_rate_ge_0.8": bool(by["warm"]["hit_rate"] >= 0.8),
        "batched_tok_s_gt_cold": bool(
            by["batched"]["prefill_tok_s"] > by["cold_burst"]["prefill_tok_s"]
        ),
        "streams_bit_identical": bool(
            streams["warm"] == streams["cold"]
            and streams["batched"] == streams["cold_burst"]
            and disagg["warm_streams_match_cold"]
            and disagg["fault_streams_match_cold"]
        ),
    }
    return {
        "bench": "prefix_cache",
        "arch": ARCH,
        "workload": (
            f"{N_REQUESTS}×shared_prefix_spec (48-token system prompt + "
            f"lognormal tails)"
        ),
        "page_size": PAGE_SIZE,
        "prefill_chunk": CHUNK,
        "modeled_clock": {
            "t_decode_s": T_DECODE,
            "t_prefill_fixed_s": T_PREFILL_FIX,
            "t_prefill_per_tok_s": T_PREFILL_TOK,
        },
        "warm_ttft_speedup": round(
            by["cold"]["ttft_mean_ms"] / max(by["warm"]["ttft_mean_ms"], 1e-9), 2
        ),
        "batched_tok_s_speedup": round(
            by["batched"]["prefill_tok_s"]
            / max(by["cold_burst"]["prefill_tok_s"], 1e-9),
            2,
        ),
        "gates": gates,
        "modes": results,
        "disagg": disagg,
    }


def run() -> List[Row]:
    """Harness entry point (benchmarks.run)."""
    report = run_modes()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows: List[Row] = []
    for e in report["modes"]:
        rows.append(
            (
                f"prefix_cache/{e['mode']}",
                e["ttft_mean_ms"] * 1e3,
                f"prefill_tok_s={e['prefill_tok_s']} hit_rate={e['hit_rate']} "
                f"saved_tokens={e['saved_tokens']}",
            )
        )
    g = report["gates"]
    rows.append(
        (
            "prefix_cache/gate",
            0.0,
            f"warm_ttft_lt_cold={g['warm_ttft_lt_cold']} "
            f"hit_rate_ge_0.8={g['hit_rate_ge_0.8']} "
            f"batched_tok_s_gt_cold={g['batched_tok_s_gt_cold']} "
            f"streams_bit_identical={g['streams_bit_identical']}",
        )
    )
    return rows


def main() -> None:
    report = run_modes()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {OUT_PATH}")
    for e in report["modes"]:
        print(
            f"{e['mode']:11s} ttft={e['ttft_mean_ms']:8.3f}ms "
            f"prefill_tok_s={e['prefill_tok_s']:7.1f} "
            f"hit_rate={e['hit_rate']:.3f} saved={e['saved_tokens']}"
        )
    print(
        f"warm_ttft_speedup={report['warm_ttft_speedup']}x "
        f"batched_tok_s_speedup={report['batched_tok_s_speedup']}x"
    )
    print("gates:", report["gates"])
    print("disagg:", report["disagg"])


if __name__ == "__main__":
    main()
