"""Fig. 10 — Scaled-DS variants: TPOT reduction of Janus (AEBS + 2PC) vs a
MegaScale-style baseline (random scheduling, AGate), at 8 and 16 MoE
instances.  Scaled-DS-2's larger pool needs 16 instances before replica
redundancy restores scheduling gains — the paper's observation."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, paper_perf_model, timeit
from repro.core.baselines import random_numpy


def run() -> list[Row]:
    rows: list[Row] = []
    rng = np.random.default_rng(0)
    for arch in ("scaled-ds-1", "scaled-ds-2"):
        for n_e in (8, 16):
            pm_j, _ = paper_perf_model(arch, slots=32)
            pm_m, _ = paper_perf_model(
                arch, slots=32, scheduler=lambda e, l: random_numpy(e, l, rng)
            )
            for B in (128, 512):
                us = timeit(lambda: pm_j.tpot(B, 4, n_e), repeat=2)
                tj = pm_j.tpot(B, 4, n_e, scheme="2pc")
                tm_base = pm_m.tpot(B, 4, n_e, scheme="agate")
                red = 1.0 - tj.tpot / tm_base.tpot
                rows.append(
                    (
                        f"fig10/{arch}_E{n_e}_B{B}",
                        us,
                        f"janus={tj.tpot*1000:.0f}ms megascale={tm_base.tpot*1000:.0f}ms reduction={red*100:.0f}%",
                    )
                )
    return rows
