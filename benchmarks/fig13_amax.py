"""Fig. 13 — maximum activated-expert count a_max under AEBS vs EPLB-style
(token-hash) and random scheduling, across batch sizes and MoE-side scales.
This is REAL execution of the schedulers (numpy path), not a model."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timeit
from repro.core.aebs import aebs_numpy
from repro.core.amax import make_routing_trace
from repro.core.baselines import random_numpy, token_hash_numpy
from repro.core.placement import build_layout


def run() -> list[Row]:
    E, k, C = 64, 6, 12
    trace = make_routing_trace(16384, E, k, skew=1.0, seed=0)
    rng = np.random.default_rng(1)
    rows: list[Row] = []
    for n_e in (8, 12, 16):
        layout = build_layout(trace, E, n_e, C)
        for B in (16, 64, 256, 512):
            idxs = [rng.integers(0, trace.shape[0], B) for _ in range(12)]
            a = {"aebs": [], "eplb": [], "random": []}
            for idx in idxs:
                s = trace[idx]
                a["aebs"].append(aebs_numpy(s, layout)[1].max())
                a["eplb"].append(token_hash_numpy(s, layout)[1].max())
                a["random"].append(random_numpy(s, layout, rng)[1].max())
            us = timeit(lambda: aebs_numpy(trace[idxs[0]], layout), repeat=3)
            rows.append(
                (
                    f"fig13/E{n_e}_B{B}",
                    us,
                    f"aebs={np.mean(a['aebs']):.1f} eplb={np.mean(a['eplb']):.1f} "
                    f"random={np.mean(a['random']):.1f}",
                )
            )
    return rows
