"""Fig. 1 — normalized latency of attention and MoE layers vs parallelism
degree at several batch sizes: attention barely benefits at small/moderate
batch (memory-bound plateau), MoE consistently benefits (fewer activated
experts per instance) though sublinearly."""

from __future__ import annotations

from benchmarks.common import Row, paper_perf_model, timeit


def run() -> list[Row]:
    pm, _ = paper_perf_model()
    rows: list[Row] = []
    us = timeit(lambda: pm.t_attn(16.0))
    for B in (16, 64, 512):
        base_attn = None
        base_moe = None
        for par in (1, 2, 4, 8):
            t_attn = pm.t_attn(B / par)  # attention data-parallel degree
            t_moe, a = pm.t_moe(6 * par, B)  # MoE-side parallelism degree
            if par == 1:
                base_attn, base_moe = t_attn, t_moe
            rows.append(
                (
                    f"fig1/B{B}_par{par}",
                    us,
                    f"attn={t_attn/base_attn:.2f}x moe={t_moe/base_moe:.2f}x "
                    f"(ideal={1/par:.2f}x) a_max={a:.1f}",
                )
            )
    return rows
