"""Fig. 15 — AEBS scheduling overhead vs batch size and MoE-side scale.

Measures REAL wall time of (a) the jitted jnp scheduler (the in-step path)
and (b) the host/numpy path, on this CPU.  The paper reports <90 µs at
B=4096 on a GPU kernel; the claim checked here is the scaling *shape*: cost
grows with batch then plateaus once most experts are activated, and grows
mildly from 8 → 16 instances."""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import Row, timeit
from repro.core.aebs import aebs_numpy
from repro.core.amax import make_routing_trace
from repro.core.placement import build_layout
from repro.kernels.aebs.ops import aebs_schedule


def run() -> list[Row]:
    E, k, C = 64, 6, 12
    trace = make_routing_trace(8192, E, k, skew=1.0, seed=0)
    rows: list[Row] = []
    for n_e in (8, 16):
        layout = build_layout(trace, E, n_e, C)
        tables = layout.device_tables()
        for B in (64, 256, 1024, 4096):
            eids = jnp.asarray(trace[:B])
            jit_us = timeit(
                lambda: aebs_schedule(eids, tables, n_e)[0].block_until_ready(), repeat=5
            )
            np_us = timeit(lambda: aebs_numpy(trace[:B], layout), repeat=5)
            rows.append(
                (f"fig15/E{n_e}_B{B}", jit_us, f"kernel={jit_us:.0f}us host={np_us:.0f}us")
            )
    return rows
