"""End-to-end engine comparison: the REAL serving engine (reduced MoE model,
actual JAX execution) under AEBS vs baselines, with the modeled step clock
driven by each step's true a_max (connecting the executed schedule to the
paper's latency model)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timeit
from repro.configs import get_config
from repro.core.amax import make_routing_trace
from repro.core.comm import H100
from repro.core.placement import build_layout
from repro.core.scaling import LayerCoeffs
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.request import WorkloadSpec, sample_requests
from repro.serving.trace import poisson_arrivals


def run() -> list[Row]:
    cfg = get_config("qwen2-moe-a2.7b-reduced")
    big = get_config("dsv2-lite")
    co = LayerCoeffs.from_config(big, H100)  # paper-scale latency coefficients
    params = model_mod.init_params(cfg, 0)
    trace = make_routing_trace(2048, cfg.num_experts, cfg.top_k, skew=0.8, seed=0)
    layout = build_layout(trace, cfg.num_experts, 2, 3)
    rows: list[Row] = []
    results = {}
    for sched in ("aebs", "token_hash", "none"):
        spec = WorkloadSpec(mean_input=6, mean_output=12, vocab_size=cfg.vocab_size,
                            max_input=16, max_output=20, seed=4)
        reqs = sample_requests(spec, poisson_arrivals(80.0, 0.15, seed=4), with_prompts=True)
        eng = ServingEngine(
            cfg, params, max_batch=4, cache_len=64,
            layout=layout if sched != "none" else None,
            scheduler=sched, capacity_tokens=64,
            step_time_fn=lambda n: big.num_layers * (co.beta * 4 + co.c_e),
        )
        us = timeit(lambda: None)
        m = eng.run(reqs, max_steps=2000)
        results[sched] = m
        rows.append(
            (
                f"engine/{sched}",
                us,
                f"completed={m['completed']} tokens={m['tokens']} "
                f"tpot_mean={m.get('tpot_mean', 0)*1000:.1f}ms",
            )
        )
    # numerical transparency check across schedulers (same tokens generated)
    same = results["aebs"]["tokens"] == results["none"]["tokens"]
    rows.append(("engine/scheduling_transparent", 0.0, str(same)))
    return rows
