"""Fault-injection recovery shootout: what does surviving a failure cost?

Drives the three-pool engine (dsv2-lite-reduced, degenerate in-process
pools, modeled clock) through one fault-free baseline and four seeded fault
scenarios, and writes ``BENCH_fault_recovery.json`` at the repo root:

* ``baseline``       — no plan armed (the fault-free hot path);
* ``attn_loss``      — one attention device killed mid-decode: the lost KV
  shard is rebuilt by deterministic re-prefill + re-decode replay;
* ``moe_loss``       — one MoE device killed: expert placement re-planned
  onto the survivors, only that pool re-lowered;
* ``prefill_loss``   — the prefill device killed mid-chunk: the displaced
  request requeues from chunk 0;
* ``transient_xchg`` — a healing exchange timeout: the idempotent decode
  step retries under exponential backoff.

The modeled clock makes the timing deterministic, so the report isolates
what each recovery path charges: recovery latency (wall), fault stall
(modeled backoff + recovery charge), throughput vs baseline — and the gate
the tentpole must pass:

    every scenario's final token streams are bit-identical to baseline.

Run:  PYTHONPATH=src python -m benchmarks.fault_recovery_bench
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.faults import (
    DEVICE_LOSS,
    EXCHANGE_TIMEOUT,
    FaultPlan,
    FaultSpec,
    RetryPolicy,
)
from repro.serving.request import WorkloadSpec, sample_requests

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_fault_recovery.json")

ARCH = "dsv2-lite-reduced"
N_REQUESTS = 6
T_DECODE = 2e-3
RECOVERY_CHARGE = 0.05  # modeled wall cost of one permanent-fault recovery

SCENARIOS = [
    ("attn_loss", FaultSpec(DEVICE_LOSS, pool="attn", index=1, at_step=8)),
    ("moe_loss", FaultSpec(DEVICE_LOSS, pool="moe", index=0, at_step=8)),
    ("prefill_loss", FaultSpec(DEVICE_LOSS, pool="prefill", index=0, at_step=2)),
    ("transient_xchg", FaultSpec(EXCHANGE_TIMEOUT, at_step=6, transient=True,
                                 fail_count=2)),
]


def _requests(cfg):
    spec = WorkloadSpec(mean_input=8, mean_output=24, vocab_size=cfg.vocab_size,
                        max_input=24, max_output=32, seed=5)
    # packed arrivals: the batch is full when the fault lands, so recovery
    # carries live KV state instead of recovering empty slots
    return sample_requests(spec, np.linspace(0, 0.01, N_REQUESTS), with_prompts=True)


def _engine(cfg, params, layout, plan=None):
    return ServingEngine(
        cfg, params, max_batch=4, cache_len=96, layout=layout,
        scheduler="aebs", capacity_tokens=64,
        executor="disagg", n_attn=2, n_prefill=1, prefill_chunk=8,
        step_time_fn=lambda n_active: T_DECODE,
        fault_plan=plan,
        retry_policy=RetryPolicy(recovery_charge_s=RECOVERY_CHARGE),
    )


def run_scenarios() -> Dict:
    cfg = get_config(ARCH)
    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)

    results = []
    streams = {}
    for name, spec in [("baseline", None)] + SCENARIOS:
        plan = FaultPlan(faults=[spec], seed=0) if spec is not None else None
        eng = _engine(cfg, params, layout, plan)
        m = eng.run(_requests(cfg), max_steps=50_000)
        assert m["completed"] == N_REQUESTS, (name, m)
        streams[name] = {r.rid: tuple(r.tokens_out) for r in eng.completed}
        row = {
            "scenario": name,
            "completed": m["completed"],
            "tokens": m["tokens"],
            "throughput_tok_s": round(m["throughput_tok_s"], 2),
            "tpot_p99_ms": round(m["tpot_p99"] * 1e3, 3),
            "clock_s": round(m["clock"], 4),
        }
        if plan is not None:
            f = m["faults"]
            row.update(
                detected=f["detected"],
                retries=f["retries"],
                recoveries=f["recoveries"],
                requeued=f["requeued"],
                replayed_slots=f["replayed_slots"],
                degraded=f["degraded"],
                fault_stall_s=round(f["fault_stall_s"], 4),
                recovery_latency_mean_s=round(f["recovery_latency_mean_s"], 4),
                recovery_latency_max_s=round(f["recovery_latency_max_s"], 4),
            )
        results.append(row)

    identical = all(streams[n] == streams["baseline"] for n in streams)
    base = next(r for r in results if r["scenario"] == "baseline")
    recovered = all(
        r.get("degraded", 0) == 0 and (r.get("recoveries", 0) > 0 or r.get("retries", 0) > 0)
        for r in results
        if r["scenario"] != "baseline"
    )
    return {
        "bench": "fault_recovery",
        "arch": ARCH,
        "modeled_clock": {"t_decode_s": T_DECODE,
                          "recovery_charge_s": RECOVERY_CHARGE},
        "streams_bit_identical": bool(identical),
        "all_scenarios_recovered": bool(recovered),
        "baseline_throughput_tok_s": base["throughput_tok_s"],
        "scenarios": results,
    }


def run() -> List[Row]:
    """Harness entry point (benchmarks.run)."""
    report = run_scenarios()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows: List[Row] = []
    for e in report["scenarios"]:
        rows.append(
            (
                f"fault_recovery/{e['scenario']}",
                e.get("recovery_latency_mean_s", 0.0) * 1e6,
                f"thr={e['throughput_tok_s']}tok/s stall={e.get('fault_stall_s', 0.0)}s "
                f"recoveries={e.get('recoveries', 0)} replayed={e.get('replayed_slots', 0)} "
                f"requeued={e.get('requeued', 0)}",
            )
        )
    rows.append(
        (
            "fault_recovery/gate",
            0.0,
            f"streams_bit_identical={report['streams_bit_identical']} "
            f"all_recovered={report['all_scenarios_recovered']}",
        )
    )
    return rows


def main() -> None:
    report = run_scenarios()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {OUT_PATH}")
    for e in report["scenarios"]:
        print(
            f"{e['scenario']:15s} thr={e['throughput_tok_s']:8.2f}tok/s "
            f"tpot_p99={e['tpot_p99_ms']:.2f}ms "
            f"stall={e.get('fault_stall_s', 0.0):.3f}s "
            f"recovery={e.get('recovery_latency_mean_s', 0.0):.3f}s"
        )
    print(
        f"streams bit-identical: {report['streams_bit_identical']}; "
        f"all scenarios recovered: {report['all_scenarios_recovered']}"
    )


if __name__ == "__main__":
    main()
