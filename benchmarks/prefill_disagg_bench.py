"""Prefill-pool admission shootout: blocking vs pipelined chunked prefill.

Drives the continuous-batching engine through the *long-prompt* workload
preset (``repro.serving.request.long_prompt_spec`` — mean input ≈ 512,
max 4096 tokens: the regime where one prompt's prefill rivals dozens of
decode steps) under three admission configurations and writes
``BENCH_prefill_disagg.json`` at the repo root:

* ``blocking``       — legacy admission: each whole prompt prefills inline
  before decoding resumes, charging the decode clock;
* ``pipelined_p1``   — one-device prefill pool, chunked prefill + streamed
  per-chunk KV hand-off, admission never charges the decode clock;
* ``pipelined_p2``   — two prefill devices: queued prompts overlap.

The engine runs the *modeled clock* (deterministic ``step_time_fn`` /
``prefill_time_fn`` with paper-ish per-token costs), so the comparison
isolates the admission schedule itself: identical arrivals, identical token
streams (bit-equal chunked prefill, ample capacity), different stall
accounting.  Reported per mode: TTFT mean/p99, TPOT mean/p99, decode-stall
time, and the gate the tentpole must pass —

    pipelined beats blocking on decode-stall time AND TPOT p99.

Run:  PYTHONPATH=src python -m benchmarks.prefill_disagg_bench
"""

from __future__ import annotations

import json
import os
from typing import Dict, List

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.request import long_prompt_spec, sample_requests
from repro.serving.trace import poisson_arrivals

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
OUT_PATH = os.path.join(REPO_ROOT, "BENCH_prefill_disagg.json")

ARCH = "dsv2-lite-reduced"
CACHE_LEN = 4096 + 160  # max prompt + headroom for generations
N_REQUESTS = 14
RATE = 6.0  # arrivals/s — keeps several requests in flight

# modeled clock (paper-ish magnitudes): decode ≈ 2 ms/step; prefill ≈ 40 µs
# per prompt token, so a 4k prompt costs ≈ 80 decode steps when blocking
T_DECODE = 2e-3
T_PREFILL_TOK = 40e-6


def _engines(cfg, params, layout):
    common = dict(
        max_batch=6, cache_len=CACHE_LEN, layout=layout, scheduler="aebs",
        # decode capacity ample (≤ max_batch tokens/step); prefill capacity
        # is drop-free by default (per-call token count) — so every mode
        # emits identical tokens and only the admission schedule differs
        capacity_tokens=64,
        step_time_fn=lambda n_active: T_DECODE,
        prefill_time_fn=lambda n_tok: T_PREFILL_TOK * n_tok,
    )
    return [
        ("blocking", dict(admission="blocking", prefill_chunk=CHUNK, **common)),
        ("pipelined_p1", dict(n_prefill=1, prefill_chunk=CHUNK, **common)),
        ("pipelined_p2", dict(n_prefill=2, prefill_chunk=CHUNK, **common)),
    ]


CHUNK = 256


def _requests(cfg, seed=0):
    spec = long_prompt_spec(vocab_size=cfg.vocab_size, mean_output=24.0,
                            max_output=128, seed=seed)
    arr = poisson_arrivals(RATE, N_REQUESTS / RATE, seed=seed)[:N_REQUESTS]
    if len(arr) < N_REQUESTS:
        arr = np.linspace(0, N_REQUESTS / RATE, N_REQUESTS)
    reqs = sample_requests(spec, arr, with_prompts=True)
    # quantise prompt lengths to the chunk size: the timing model is length-
    # proportional either way, and it bounds jit retraces (one trace per
    # distinct shape) so the bench measures scheduling, not compilation
    rng = np.random.default_rng(seed + 1)
    for r in reqs:
        n = int(np.ceil(r.input_len / CHUNK) * CHUNK)
        r.input_len = n
        r.prompt = rng.integers(0, cfg.vocab_size, size=n, dtype=np.int32)
    return reqs


def run_modes() -> Dict:
    cfg = get_config(ARCH)
    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
    results = []
    streams = {}
    for name, kw in _engines(cfg, params, layout):
        eng = ServingEngine(cfg, params, **kw)
        m = eng.run(_requests(cfg), max_steps=200_000)
        assert m["completed"] == N_REQUESTS, (name, m)
        streams[name] = {r.rid: tuple(r.tokens_out) for r in eng.completed}
        results.append(
            {
                "mode": name,
                "admission": eng.admission,
                "n_prefill": len(eng.prefill_worker.devices) if eng.prefill_worker else 0,
                "completed": m["completed"],
                "tokens": m["tokens"],
                "ttft_mean_s": round(m["ttft_mean"], 4),
                "ttft_p99_s": round(m["ttft_p99"], 4),
                "tpot_mean_ms": round(m["tpot_mean"] * 1e3, 3),
                "tpot_p99_ms": round(m["tpot_p99"] * 1e3, 3),
                "decode_stall_s": round(m["decode_stall_time"], 4),
                "prefill_chunks": m.get("prefill_chunks", 0),
                "clock_s": round(m["clock"], 3),
            }
        )
    # all modes must serve bit-identical token streams (chunked prefill is
    # numerically transparent) — the schedule is the only thing that moves
    identical = all(streams[n] == streams["blocking"] for n in streams)
    block = next(r for r in results if r["mode"] == "blocking")
    pipe = next(r for r in results if r["mode"] == "pipelined_p1")
    return {
        "bench": "prefill_disagg",
        "arch": ARCH,
        "workload": "long_prompt (mean_input≈512, max_input=4096)",
        "modeled_clock": {"t_decode_s": T_DECODE, "t_prefill_per_token_s": T_PREFILL_TOK},
        "streams_bit_identical": bool(identical),
        "pipelined_beats_blocking": bool(
            pipe["decode_stall_s"] < block["decode_stall_s"]
            and pipe["tpot_p99_ms"] < block["tpot_p99_ms"]
        ),
        "modes": results,
    }


def run() -> List[Row]:
    """Harness entry point (benchmarks.run)."""
    report = run_modes()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    rows: List[Row] = []
    for e in report["modes"]:
        rows.append(
            (
                f"prefill_disagg/{e['mode']}",
                e["tpot_p99_ms"] * 1e3,
                f"ttft={e['ttft_mean_s']}s stall={e['decode_stall_s']}s "
                f"tpot_p99={e['tpot_p99_ms']}ms chunks={e['prefill_chunks']}",
            )
        )
    rows.append(
        (
            "prefill_disagg/gate",
            0.0,
            f"pipelined_beats_blocking={report['pipelined_beats_blocking']} "
            f"streams_bit_identical={report['streams_bit_identical']}",
        )
    )
    return rows


def main() -> None:
    report = run_modes()
    with open(OUT_PATH, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {OUT_PATH}")
    for e in report["modes"]:
        print(
            f"{e['mode']:13s} ttft={e['ttft_mean_s']:.3f}s/{e['ttft_p99_s']:.3f}s "
            f"tpot={e['tpot_mean_ms']:.2f}/{e['tpot_p99_ms']:.2f}ms "
            f"stall={e['decode_stall_s']:.3f}s chunks={e['prefill_chunks']}"
        )
    print(
        f"pipelined beats blocking: {report['pipelined_beats_blocking']} "
        f"(streams identical: {report['streams_bit_identical']})"
    )


if __name__ == "__main__":
    main()
