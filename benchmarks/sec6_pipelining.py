"""§6 discussion — attention/MoE micro-batch pipelining analysis.

The paper argues pipelining attention and MoE across micro-batches (as
MegaScale-Infer does) has limited benefit at typical online batch sizes:
splitting a small batch gives little per-micro-batch latency reduction
(both sides sit on their memory-bound plateaus) while adding per-stage
synchronisation overhead.  We quantify that with the calibrated layer model:

  T_pipe(m) ≈ (T_attn(B/m) + T_moe(B/m) + sync) · m  overlapped as
              max-stage-bound pipeline:  (m+1)·max(stage) + sync·m
  vs  T_seq = T_attn(B) + T_moe(B) + T_comm.
"""

from __future__ import annotations

from typing import Dict, Tuple

from benchmarks.common import Row, paper_perf_model, timeit

SYNC = 10e-6  # per-micro-batch hand-off overhead


def pipeline_times(
    pm, B: float, n_a: int, n_e: int, sync: float = SYNC, ms: Tuple[int, ...] = (2, 4, 8)
) -> Tuple[float, Dict[int, float]]:
    """Analytic sequential vs pipelined step time for one MoE layer pass.

    Returns ``(t_seq, {m: t_pipe})`` — the §6 model the measured
    ``benchmarks.disagg_pipeline_bench`` numbers are printed against."""
    ta = pm.t_attn(B / n_a)
    tm, _ = pm.t_moe(n_e, B)
    tc = pm.t_comm(n_a, n_e, B)
    t_seq = ta + tm + tc
    pipes: Dict[int, float] = {}
    for m in ms:
        ta_m = pm.t_attn(B / n_a / m)
        tm_m, _ = pm.t_moe(n_e, B / m)
        stage = max(ta_m, tm_m)
        pipes[m] = (m + 1) * stage + m * (sync + tc / m)
    return t_seq, pipes


def run() -> list[Row]:
    pm, _ = paper_perf_model()
    n_a, n_e = 4, 8
    rows: list[Row] = []
    for B in (32, 64, 256, 2048):
        us = timeit(lambda: pm.tpot(B, n_a, n_e), repeat=2)
        t_seq, pipes = pipeline_times(pm, B, n_a, n_e)
        best = ("none", t_seq)
        for m, t_pipe in pipes.items():
            if t_pipe < best[1]:
                best = (f"m={m}", t_pipe)
        gain = (t_seq - best[1]) / t_seq * 100
        rows.append(
            (
                f"sec6/pipeline_B{B}",
                us,
                f"seq={t_seq*1e6:.0f}us best_pipe={best[0]} "
                f"({best[1]*1e6:.0f}us) gain={gain:.0f}%",
            )
        )
    return rows
