"""Shared benchmark utilities: timing + the paper-scale performance model."""

from __future__ import annotations

import time
from typing import Callable, List, Tuple

import numpy as np

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def timeit(fn: Callable, *args, repeat: int = 5, warmup: int = 1) -> float:
    """Median wall time of fn(*args) in microseconds."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def paper_perf_model(arch: str = "dsv2-lite", n_trace: int = 4096, skew: float = 1.0,
                     slots: int = 12, s_ctx: float = 512.0, hw=None, trials: int = 6,
                     scheduler=None):
    """PerfModel on the paper's H100 testbed constants with a ShareGPT-like
    skewed routing trace (the common setup of Figs. 8–16)."""
    from repro.configs import get_config
    from repro.core.amax import MonteCarloAmax, make_routing_trace
    from repro.core.comm import H100
    from repro.core.scaling import PerfModel

    cfg = get_config(arch)
    trace = make_routing_trace(n_trace, cfg.num_experts, cfg.top_k, skew=skew, seed=0)
    kw = {}
    if scheduler is not None:
        kw["scheduler"] = scheduler
    mc = MonteCarloAmax(trace, cfg.num_experts, trials=trials, **kw)
    return PerfModel(cfg, hw=hw or H100, amax_estimator=mc, slots_per_instance=slots, s_ctx=s_ctx), trace


def fmt_rows(rows: List[Row]) -> str:
    return "\n".join(f"{n},{us:.2f},{d}" for n, us, d in rows)
