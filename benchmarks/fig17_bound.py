"""Fig. 17 / Appendix A — analytical a_max bound (Eq. 5) vs the Monte-Carlo
estimate across n_e ∈ {6, 8, 12, 16} and three batch-size regimes."""

from __future__ import annotations

from benchmarks.common import Row, timeit
from repro.core.amax import MonteCarloAmax, amax_bound, make_routing_trace
from repro.core.placement import build_layout


def run() -> list[Row]:
    E, k, C = 64, 6, 27
    trace = make_routing_trace(16384, E, k, skew=0.8, seed=0)
    mc = MonteCarloAmax(trace, E, trials=12)
    rows: list[Row] = []
    violations = 0
    for n_e in (6, 8, 12, 16):
        layout = build_layout(trace, E, n_e, min(C, 64 // n_e + 12))
        for B in (4, 16, 64, 256, 512):
            us = timeit(lambda: mc.estimate(layout, B), repeat=1)
            est = mc.estimate(layout, B)
            bd = amax_bound(n_e, B, E, k, layout.capacity)
            if bd < est:
                violations += 1
            rows.append(
                (f"fig17/ne{n_e}_B{B}", us, f"mc={est:.2f} bound={bd} gap={bd-est:.2f}")
            )
    rows.append(("fig17/one_sided_violations", 0.0, str(violations)))
    return rows
