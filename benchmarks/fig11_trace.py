"""Fig. 11 — 24-hour production-trace scaling study (trace-driven simulation,
15-minute decision interval): GPU-hours + SLO attainment per system."""

from __future__ import annotations

from benchmarks.common import Row, paper_perf_model, timeit
from repro.serving.simulator import ClusterSimulator
from repro.serving.trace import diurnal_rate_profile


def run() -> list[Row]:
    pm, _ = paper_perf_model()
    sim = ClusterSimulator(pm, slo=0.2, n_max=32)
    t, rates = diurnal_rate_profile(hours=24, step_minutes=15.0, mean_rate=30.0, seed=0)
    us = timeit(lambda: sim.run_janus(t[:4], rates[:4], 256.0), repeat=1)
    res = sim.compare(t, rates, tokens_per_req=256.0)
    rows: list[Row] = []
    base = res["janus"].gpu_hours
    for name, r in res.items():
        save = (1 - base / r.gpu_hours) * 100 if r.gpu_hours > 0 and name != "janus" else 0.0
        gpus = [rec.total_gpus for rec in r.records]
        rows.append(
            (
                f"fig11/{name}",
                us,
                f"gpu_hours={r.gpu_hours:.0f} slo={r.slo_attainment*100:.0f}% "
                f"range={min(gpus)}-{max(gpus)}gpus"
                + (f" janus_saves={save:.0f}%" if name != "janus" else ""),
            )
        )
    return rows
