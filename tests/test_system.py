"""End-to-end behaviour tests for the reproduced system — the paper's core
claims exercised through the public API."""

import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.aebs import aebs_numpy
from repro.core.amax import MonteCarloAmax, amax_bound, make_routing_trace
from repro.core.baselines import random_numpy
from repro.core.placement import build_layout
from repro.core.scaling import PerfModel, SLOScaler
from repro.models import model as model_mod
from repro.training.train_loop import train


def test_claim_aebs_reduces_amax_with_scale():
    """Fig. 13: AEBS's win over random scheduling grows with MoE-side scale
    (more instances → more replica redundancy → more choices)."""
    E, k, C = 64, 6, 12
    trace = make_routing_trace(8192, E, k, skew=1.0, seed=0)
    rng = np.random.default_rng(0)
    gains = []
    for n_e in (8, 16):
        layout = build_layout(trace, E, n_e, C)
        d_aebs, d_rand = [], []
        for _ in range(8):
            idx = rng.integers(0, trace.shape[0], 256)
            d_aebs.append(aebs_numpy(trace[idx], layout)[1].max())
            d_rand.append(random_numpy(trace[idx], layout, rng)[1].max())
        gains.append(np.mean(d_rand) - np.mean(d_aebs))
    assert gains[0] >= 0
    assert gains[1] >= gains[0] - 0.5  # gain sustained/growing at 16 instances


def test_claim_asymmetric_configs_win():
    """Fig. 8/16: the scaler picks asymmetric (n_a ≪ n_e) configurations at
    light load — e.g. the paper's 1A6E — rather than scaling both sides."""
    cfg = get_config("dsv2-lite")
    trace = make_routing_trace(2048, cfg.num_experts, cfg.top_k, skew=1.0, seed=0)
    mc = MonteCarloAmax(trace, cfg.num_experts, trials=4)
    pm = PerfModel(cfg, amax_estimator=mc, slots_per_instance=12, s_ctx=512)
    sc = SLOScaler(pm, n_max=12)
    best = sc.scale(demand=2000.0, slo=0.2)
    assert best is not None and best.feasible
    assert best.n_e > best.n_a  # MoE side dominates the resource footprint


def test_claim_bound_holds_and_regimes():
    """Appendix A: Eq. 5 is one-sided; a_max saturates at high B."""
    E, k, C, n_e = 64, 6, 12, 8
    trace = make_routing_trace(4096, E, k, skew=0.8, seed=1)
    layout = build_layout(trace, E, n_e, C)
    mc = MonteCarloAmax(trace, E, trials=4)
    prev = 0.0
    for B in (4, 16, 64, 256, 1024):
        est = mc.estimate(layout, B)
        assert amax_bound(n_e, B, E, k, C) >= est
        assert est >= prev - 0.6  # monotone-ish growth
        prev = est
    assert est <= C


def test_end_to_end_training_converges():
    """Substrate sanity: the full train loop reduces loss on a small MoE."""
    cfg = get_config("dsv2-lite").reduced()
    res = train(cfg, steps=60, batch_size=8, seq_len=64, log_every=20, log_fn=lambda *_: None)
    assert res["final_loss"] < res["first_loss"]


def test_end_to_end_generation_deterministic():
    """Greedy decode is reproducible across engine instantiations."""
    cfg = get_config("gemma2-2b-reduced")
    params = model_mod.init_params(cfg, 0)
    tokens = jnp.arange(12)[None, :] % cfg.vocab_size
    outs = []
    for _ in range(2):
        _, caches = model_mod.prefill(params, tokens, cfg, cache_len=32)
        t = tokens[:, -1:]
        seq = []
        for i in range(6):
            logits, caches = model_mod.decode_step(params, t, caches, jnp.int32(12 + i), cfg)
            t = model_mod.greedy_token(logits)[:, None]
            seq.append(int(t[0, 0]))
        outs.append(seq)
    assert outs[0] == outs[1]
