"""Prefix cache: allocator refcounts and radix-index invariants (property
tests), splice/COW semantics, prefix-hit-vs-cold bit-identical streams on
both executors (including a mid-run attention kill and a prefill-device
requeue), the page-leak guard under a cancel/reject storm, and the operator
surface (CLI flags, shared-prefix workload, autoscaler prefill discount).

The load-bearing claim everywhere: serving a prefix hit is *block-table
splicing only* — the shared span's pages hold rows any prompt with that
token prefix would have produced bit-identically, so warm streams equal
cold streams by construction, and the only thing that changes is who pays
for prefill.
"""

import sys

import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs import get_config
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PageAllocator, PagedKVCache, PrefixIndex
from repro.serving.request import (
    WorkloadSpec,
    sample_requests,
    shared_prefix_spec,
)

PS = 16  # page size used throughout the engine-level tests


# ---------------------------------------------------------------------------
# allocator refcounts
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=99), min_size=1, max_size=120))
def test_page_allocator_refcount_roundtrip(ops):
    """Any alloc/ref/free interleaving against a model of per-page refcounts:
    a page leaves the free list at first alloc, survives every free but the
    last, and the free + in-use split always accounts for the whole pool."""
    num_pages = 8
    alloc = PageAllocator(num_pages)
    model = {}  # page -> refcount
    for op in ops:
        kind = op % 3
        if kind == 0:
            try:
                p = alloc.alloc()
            except RuntimeError:
                assert alloc.num_free == 0
                continue
            assert p not in model  # never hand out a held page
            model[p] = 1
        elif model:
            p = sorted(model)[op % len(model)]
            if kind == 1:
                alloc.ref(p)
                model[p] += 1
            else:
                alloc.free(p)
                model[p] -= 1
                if model[p] == 0:
                    del model[p]
                    assert alloc.refcount(p) == 0
                else:
                    assert alloc.refcount(p) == model[p]  # still held
        assert alloc.in_use == len(model)
        assert alloc.num_free + alloc.in_use == num_pages - 1
        for p, r in model.items():
            assert alloc.refcount(p) == r
    for p, r in list(model.items()):
        for _ in range(r):
            alloc.free(p)
    assert alloc.in_use == 0 and alloc.num_free == num_pages - 1


def test_page_allocator_ref_errors():
    alloc = PageAllocator(4)
    with pytest.raises(RuntimeError, match="unallocated"):
        alloc.ref(1)
    p = alloc.alloc()
    alloc.ref(p)
    alloc.free(p)
    assert alloc.refcount(p) == 1  # second holder keeps it alive
    alloc.free(p)
    with pytest.raises(RuntimeError, match="double free"):
        alloc.free(p)


# ---------------------------------------------------------------------------
# splice / copy-on-write
# ---------------------------------------------------------------------------


def test_splice_adopts_full_pages_and_cows_partial():
    pager = PagedKVCache(4, 64, 16)
    pager.ensure(0, 39)  # rows 0..39 → 3 pages, last one 8 rows deep
    src = pager.slot_pages(0)
    cow = pager.splice(1, src, 40)
    assert cow is not None
    src_pg, dst_pg, rows = cow
    assert src_pg == src[2] and rows == 40 - 2 * 16
    assert dst_pg not in src  # partial boundary gets a private page
    assert pager.slot_pages(1)[:2] == src[:2]  # full pages adopted by ref
    assert pager.allocator.refcount(src[0]) == 2
    assert pager.allocator.refcount(src[2]) == 1  # partial page NOT shared
    assert pager.hiwater[1] == 40
    # page-aligned splice needs no COW
    assert pager.splice(2, src, 32) is None
    assert pager.allocator.refcount(src[1]) == 3
    # releasing a splicer only drops its pins
    pager.release(1)
    pager.release(2)
    assert pager.allocator.refcount(src[0]) == 1
    assert sorted(pager.pages_of([0])) == sorted(src)
    with pytest.raises(RuntimeError, match="fresh slot"):
        pager.splice(0, src, 16)
    with pytest.raises(ValueError, match="need"):
        pager.splice(3, src[:1], 40)


# ---------------------------------------------------------------------------
# radix index: publish / lookup / evict
# ---------------------------------------------------------------------------


def _publish(index, pager, tokens, slot=0):
    """Prefill ``slot`` far enough to back ``tokens`` and publish the
    chunk-aligned prefix, then release the slot (index pins survive)."""
    upto = (len(tokens) // index.chunk) * index.chunk
    if upto:
        pager.ensure(slot, upto - 1)
        index.publish(np.asarray(tokens, np.int32), upto, slot)
    pager.release(slot)
    return upto


def test_prefix_index_publish_lookup_roundtrip():
    pager = PagedKVCache(2, 64, 16)
    index = PrefixIndex(8, pager)
    tokens = np.arange(32, dtype=np.int32)
    pager.ensure(0, 31)
    owned = pager.slot_pages(0)
    assert index.publish(tokens, 32, 0) == 4  # one node per chunk
    pager.release(0)
    match, pages = index.lookup(tokens)
    assert match == 32 and pages == owned
    # diverging tail: only the shared chunks match
    fork = tokens.copy()
    fork[20:] += 1000
    match, pages = index.lookup(fork)
    assert match == 16 and pages == owned[:1]
    # limit caps the walk (the full-hit cap in the engine)
    match, _ = index.lookup(tokens, limit=24)
    assert match == 24
    assert index.lookup(tokens + 7)[0] == 0
    s = index.stats()
    assert s["hits"] == 3 and s["misses"] == 1
    # chunk (8) < page (16): consecutive chunk nodes pin the same page, so
    # shared_pages counts *pins* (4 nodes × 1 page), not unique pages
    assert s["shared_pages"] == 4 and s["nodes"] == 4
    assert s["saved_tokens"] == 32 + 16 + 24


@settings(max_examples=40, deadline=None)
@given(
    st.lists(
        st.lists(st.integers(min_value=0, max_value=1), min_size=1, max_size=24),
        min_size=1,
        max_size=10,
    )
)
def test_prefix_index_matches_longest_prefix_model(prompts):
    """Against a brute-force model over a 2-token vocab (maximal prefix
    collisions): lookup always returns the longest chunk-aligned prefix
    shared with *some* published prompt, and allocator accounting matches
    the index's pin count exactly."""
    chunk = 4
    pager = PagedKVCache(1, 32, 4, num_pages=257)
    index = PrefixIndex(chunk, pager)
    published = []
    for prompt in prompts:
        prompt = prompt[: (len(prompt) // chunk) * chunk + chunk - 1][:24]
        toks = np.asarray(prompt, np.int32)
        want = 0
        for p in published:
            n = 0
            while (
                n + chunk <= min(len(p), len(toks))
                and list(p[n : n + chunk]) == list(toks[n : n + chunk])
            ):
                n += chunk
            want = max(want, n)
        got, pages = index.lookup(toks)
        assert got == want
        assert len(pages) == (got + pager.page_size - 1) // pager.page_size
        _publish(index, pager, toks)
        if (len(toks) // chunk) * chunk:
            published.append(list(toks))
        # every pin the index holds is a live allocator ref; nothing else is
        assert pager.allocator.in_use >= index.stats()["nodes"] * 0
        assert index.held_pages == sum(len(n.pages) for n in index._nodes)
    index.drop_all()
    assert pager.allocator.in_use == 0


def test_prefix_index_lru_leaf_eviction_respects_splices():
    """Over-budget inserts evict least-recently-used *leaves*; eviction only
    drops the index's pin, so a page still spliced into a live block table
    survives until that slot releases it."""
    chunk, ps = 4, 4
    pager = PagedKVCache(2, 16, 4, num_pages=40)
    index = PrefixIndex(chunk, pager, max_pages=4)
    a = np.arange(16, dtype=np.int32)
    b = np.arange(16, dtype=np.int32) + 100
    _publish(index, pager, a)
    match, a_pages = index.lookup(a)
    assert match == 16
    assert pager.splice(1, a_pages, 16) is None  # page-aligned, live holder
    _publish(index, pager, b)  # held 8 > budget 4 → A's chain evicted
    s = index.stats()
    assert s["shared_pages"] == 4 and s["evicted_pages"] == 4
    assert index.lookup(a)[0] == 0  # A is gone from the index...
    for p in a_pages:  # ...but its pages live on in slot 1's table
        assert pager.allocator.refcount(p) == 1
    assert index.lookup(b)[0] == 16  # B (recently used) survived
    pager.release(1)
    assert pager.allocator.in_use == 4  # only B's pins remain


# ---------------------------------------------------------------------------
# engine level: warm == cold, on both executors
# ---------------------------------------------------------------------------


def _shared_reqs(cfg, n=6, stagger=0.5, seed=123, shared=10):
    """Shared system prompt + unique tails, staggered so request i publishes
    before request i+1 submits."""
    spec = WorkloadSpec(mean_input=6, mean_output=6, vocab_size=cfg.vocab_size,
                        max_input=12, max_output=8, seed=seed)
    rs = sample_requests(spec, np.arange(n) * stagger, with_prompts=True)
    rng = np.random.default_rng(seed)
    head = rng.integers(0, cfg.vocab_size, size=shared, dtype=np.int32)
    for i, r in enumerate(rs):
        tail = rng.integers(0, cfg.vocab_size, size=4 + i % 3, dtype=np.int32)
        r.prompt = np.concatenate([head, tail])
        r.input_len = len(r.prompt)
    return rs


def _streams(eng):
    return {r.rid: tuple(r.tokens_out) for r in eng.completed}


def _assert_no_leaks(eng):
    """After a drain, the only live pages are prefix-index pins, and every
    page's refcount equals exactly the number of nodes pinning it."""
    from collections import Counter

    if getattr(eng, "disagg", None) is not None:
        pairs = zip(eng.disagg._indexes or [], eng.disagg._pagers)
    else:
        pairs = [(eng.prefix, eng.paged)]
    for idx, pager in pairs:
        pins = Counter(p for node in idx._nodes for p in node.pages)
        assert pager.allocator.in_use == len(pins)
        for p, c in pins.items():
            assert pager.allocator.refcount(p) == c


@pytest.fixture(scope="module")
def phi4():
    cfg = get_config("phi4-mini-3.8b-reduced")
    return cfg, model_mod.init_params(cfg, 0)


def _mono_engine(cfg, params, **kw):
    return ServingEngine(
        cfg, params, max_batch=3, cache_len=64, scheduler="none",
        n_prefill=1, prefill_chunk=8, kv_page_size=PS,
        step_time_fn=lambda n: 2e-3,
        prefill_time_fn=lambda n: 1e-3 + n * 1e-3, **kw,
    )


def test_mono_prefix_hit_streams_bit_identical(phi4):
    """Cold vs warm vs warm+batched on the mono engine: identical streams,
    a real hit rate, faster warm TTFT, and a drained pool afterwards (only
    the index's own pins remain in use)."""
    cfg, params = phi4
    runs = {}
    for name, kw in (
        ("cold", {}),
        ("warm", dict(prefix_cache=True)),
        ("batched", dict(prefix_cache=True, prefill_batch=3)),
    ):
        eng = _mono_engine(cfg, params, **kw)
        m = eng.run(_shared_reqs(cfg), max_steps=4000)
        assert m["completed"] == 6
        runs[name] = (_streams(eng), m, eng)
    assert runs["warm"][0] == runs["cold"][0]
    assert runs["batched"][0] == runs["cold"][0]
    for name in ("warm", "batched"):
        s = runs[name][1]["prefix_cache"]
        assert s["hits"] >= 4 and s["saved_tokens"] > 0
        _assert_no_leaks(runs[name][2])
    assert runs["warm"][1]["ttft_mean"] < runs["cold"][1]["ttft_mean"]


def test_prefix_hit_with_speculation_streams_bit_identical(phi4):
    """A warm prefix splice hands the verify path a KV cache the engine never
    prefilled itself (adopted pages + CoW tail); the draft model rebuilds its
    own cache from the prompt tokens, and the streams stay bit-identical to
    the cold non-speculative run."""
    cfg, params = phi4
    eng_cold = _mono_engine(cfg, params)
    m_cold = eng_cold.run(_shared_reqs(cfg), max_steps=4000)
    eng_spec = _mono_engine(cfg, params, prefix_cache=True,
                            draft_config=cfg, spec_k=2)
    m_spec = eng_spec.run(_shared_reqs(cfg), max_steps=4000)
    assert m_cold["completed"] == m_spec["completed"] == 6
    assert _streams(eng_spec) == _streams(eng_cold)
    s = m_spec["prefix_cache"]
    assert s["hits"] >= 4 and s["saved_tokens"] > 0  # splices actually happened
    assert m_spec["spec"]["accepted_per_step"] > 1.0  # speculation ran on them
    _assert_no_leaks(eng_spec)


def test_prefix_cache_requires_paged_kv(phi4):
    cfg, params = phi4
    with pytest.raises(ValueError, match="paged KV"):
        ServingEngine(cfg, params, max_batch=2, cache_len=64,
                      scheduler="none", prefix_cache=True)


@pytest.fixture(scope="module")
def dsv2():
    from repro.core.aebs import ReplicaLayout

    cfg = get_config("dsv2-lite-reduced")
    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
    return cfg, params, layout


def _disagg_engine(cfg, params, layout, **kw):
    from repro.serving.faults import RetryPolicy

    return ServingEngine(
        cfg, params, max_batch=4, cache_len=64, layout=layout,
        scheduler="aebs", capacity_tokens=64, executor="disagg",
        n_attn=2, n_prefill=1, prefill_chunk=4, kv_page_size=PS,
        step_time_fn=lambda n: 2e-3,
        prefill_time_fn=lambda n: 1e-3 + n * 1e-3,
        retry_policy=RetryPolicy(recovery_charge_s=0.01), **kw,
    )


def test_disagg_prefix_hit_streams_bit_identical(dsv2):
    """Per-shard indexes on the disagg executor: warm streams equal cold,
    and the splices survive a mid-run attention-device kill (replay) and a
    prefill-device kill (requeue → release → re-splice) bit-identically."""
    from repro.serving.faults import DEVICE_LOSS, FaultPlan, FaultSpec

    cfg, params, layout = dsv2

    def reqs():
        # request 0 publishes the prefix at ~0.02s then decodes for a long
        # window; 1..5 arrive at ~0.04s, splice, and queue on the single
        # prefill device — so a prefill-pool kill at step 14 (~0.05s of
        # decode clock) catches live PREFILLING slots and must requeue them
        rs = _shared_reqs(cfg, seed=9, shared=10, stagger=0.0)
        rs[0].output_len = 40
        for i, r in enumerate(rs[1:]):
            r.arrival = 0.04 + 0.001 * i
        return rs

    runs = {}
    for name, kw in (
        ("cold", {}),
        ("warm", dict(prefix_cache=True)),
        ("warm_attn_kill", dict(
            prefix_cache=True,
            fault_plan=FaultPlan(faults=[
                FaultSpec(DEVICE_LOSS, pool="attn", index=1, at_step=6)]),
        )),
        ("warm_prefill_kill", dict(
            prefix_cache=True,
            fault_plan=FaultPlan(faults=[
                FaultSpec(DEVICE_LOSS, pool="prefill", index=0, at_step=14)]),
        )),
    ):
        eng = _disagg_engine(cfg, params, layout, **kw)
        m = eng.run(reqs(), max_steps=4000)
        assert m["completed"] == 6, name
        runs[name] = (_streams(eng), m, eng)
    for name in ("warm", "warm_attn_kill", "warm_prefill_kill"):
        assert runs[name][0] == runs["cold"][0], name
        assert runs[name][1]["prefix_cache"]["hits"] >= 2, name
    f = runs["warm_attn_kill"][1]["faults"]
    assert f["recoveries"] == 1 and f["degraded"] == 0
    f = runs["warm_prefill_kill"][1]["faults"]
    assert f["recoveries"] == 1 and f["requeued"] >= 1 and f["degraded"] == 0
    # requeue replayed through splice without leaking reserved pages
    _assert_no_leaks(runs["warm_prefill_kill"][2])


# ---------------------------------------------------------------------------
# page-leak guard: cancel / reject storm
# ---------------------------------------------------------------------------


def test_cancel_reject_storm_releases_pages(phi4):
    """Requests cancelled *mid-prefill* (deadline lapses while the slot is
    RESERVED/PREFILLING, after their prefix splice) must release every
    reserved page and drop their pins: after the storm the allocator is back
    to baseline — the only pages in use are the index's own."""
    cfg, params = phi4
    eng = _mono_engine(cfg, params, prefix_cache=True)
    reqs = _shared_reqs(cfg, n=8, stagger=0.1)
    rng = np.random.default_rng(7)
    for i, r in enumerate(reqs):
        if 2 <= i < 6:
            # long doomed prompts: ~5 chunks ≈ 45ms of modeled prefill, so
            # the 20ms deadline lapses while the slot is mid-prefill
            tail = rng.integers(0, cfg.vocab_size, size=30, dtype=np.int32)
            r.prompt = np.concatenate([reqs[0].prompt[:10], tail])
            r.input_len = len(r.prompt)
            r.deadline = r.arrival + 0.02
    m = eng.run(reqs, max_steps=6000)
    assert m["completed"] == 4 and m["rejected"] == 4
    s = m["prefix_cache"]
    assert s["hits"] >= 4  # the doomed requests spliced before dying
    _assert_no_leaks(eng)


def test_cancel_slot_api_releases_pages(phi4):
    """Direct cancel_slot on an in-flight prefill: the worker drops the
    in-flight work, the splice's pages free, and the request comes back."""
    cfg, params = phi4
    eng = _mono_engine(cfg, params, prefix_cache=True)
    r0, r1 = _shared_reqs(cfg, n=2, stagger=0.0)
    m = eng.run([r0], max_steps=2000)  # publishes the shared prefix
    assert m["completed"] == 1
    baseline = eng.paged.allocator.in_use  # index pins only
    assert baseline > 0
    eng._submit_request(r1)  # reserves a slot, splices, queues the prefill
    assert r1.slot >= 0
    assert eng.paged.allocator.in_use > baseline  # splice holds pages
    got = eng.cancel_slot(r1.slot)
    assert got is r1
    _assert_no_leaks(eng)
    assert eng.slots.slot_req[r1.slot] is None


# ---------------------------------------------------------------------------
# operator surface: workload preset, CLI, autoscaler discount
# ---------------------------------------------------------------------------


def test_shared_prefix_workload_preset():
    spec = shared_prefix_spec(vocab_size=100, seed=4)
    reqs = sample_requests(spec, np.linspace(0, 1, 6), with_prompts=True)
    heads = {tuple(r.prompt[: spec.shared_prefix_len]) for r in reqs}
    assert len(heads) == 1  # every prompt opens with the same system prompt
    tails = {tuple(r.prompt[spec.shared_prefix_len :]) for r in reqs}
    assert len(tails) > 1
    for r in reqs:
        assert r.input_len == len(r.prompt) >= spec.shared_prefix_len + 1
    # default spec is unchanged (shared_prefix_len=0 leaves sampling alone)
    base = sample_requests(WorkloadSpec(vocab_size=100, seed=4),
                           np.linspace(0, 1, 6), with_prompts=True)
    assert all(r.prompt is not None for r in base)


def test_serve_cli_prefix_cache(monkeypatch, capsys):
    from repro.launch import serve

    monkeypatch.setattr(
        sys, "argv",
        ["serve", "--arch", "phi4-mini-3.8b", "--scheduler", "none",
         "--rate", "40", "--duration", "0.1", "--max-batch", "2",
         "--cache-len", "128", "--kv-page-size", "16", "--prefix-cache",
         "--prefix-cache-pages", "32", "--prefill-batch", "2",
         "--n-prefill", "1", "--prefill-chunk", "8",
         "--workload", "shared-prefix"],
    )
    serve.main()
    out = capsys.readouterr().out
    assert "prefix_cache" in out and "kv_pages" in out


def test_autoscaler_prefix_discount_shrinks_prefill_pool():
    from repro.core.scaling import PerfModel
    from repro.serving.controller import AutoScaler

    cfg = get_config("dsv2-lite-reduced")
    ctrl = AutoScaler(PerfModel(cfg, slots_per_instance=3, s_ctx=64), slo=0.2,
                      n_max=8, prefill_tok_rate=100.0, window=10.0)
    ctrl.observe(0.0, 16.0, input_tokens=4000.0)
    assert ctrl.decide_prefill(1.0) == 4
    # a warm cache serving 80% of prompt tokens shrinks the pool demand
    ctrl._prefix_saved_frac = 0.8
    assert ctrl.decide_prefill(1.0) == 1
    # per-request knowledge of saved tokens discounts at observe() instead
    ctrl2 = AutoScaler(PerfModel(cfg, slots_per_instance=3, s_ctx=64), slo=0.2,
                       n_max=8, prefill_tok_rate=100.0, window=10.0)
    ctrl2.observe(0.0, 16.0, input_tokens=4000.0, saved_input_tokens=3200.0)
    assert ctrl2.decide_prefill(1.0) == 1
