"""int8 KV cache (§Perf P3, beyond-paper): numerics + spec plumbing."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, input_specs
from repro.models import model as M
from repro.models.attention import dequantize_kv, quantize_kv


def test_quantize_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 8, 128), jnp.float32) * 3
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.shape == (4, 64, 8)
    back = dequantize_kv(q, s, jnp.float32)
    err = np.abs(np.asarray(back - x)).max() / np.abs(np.asarray(x)).max()
    assert err < 0.01  # absmax int8: ≤ 1/254 relative


@pytest.mark.parametrize("base", ["gemma2-2b", "qwen2-moe-a2.7b", "zamba2-2.7b", "whisper-tiny"])
def test_int8_kv_decode_close_to_fullprec(base):
    cfg = dataclasses.replace(get_config(base + "-reduced"), kv_quant=True)
    params = M.init_params(cfg, 0)
    B, S = 2, 24
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    extra = {"moe_ctx": {"capacity": 512}} if cfg.has_moe else {}
    if cfg.frontend == "audio_frames":
        extra["encoder_frames"] = (
            jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1
        ).astype(jnp.bfloat16)
    extra = extra or None
    lf, _ = M.logits_fn(params, tokens, cfg, extra=extra)
    _, caches = M.prefill(params, tokens[:, :S], cfg, cache_len=S + 8, extra=extra)
    kv_keys = [k for k in caches if k.startswith("kv_") and not k.endswith("_scale")]
    assert kv_keys and all(caches[k].dtype == jnp.int8 for k in kv_keys)
    got, _ = M.decode_step(params, tokens[:, S:], caches, jnp.int32(S), cfg, extra=extra)
    want = np.asarray(lf[:, S], np.float32)
    err = np.abs(want - np.asarray(got, np.float32)).max() / (np.abs(want).max() + 1e-9)
    assert err < 0.05, err


def test_int8_specs_and_sharding():
    from types import SimpleNamespace

    from repro.sharding.rules import input_pspecs

    cfg = dataclasses.replace(get_config("phi3.5-moe-42b-a6.6b"), kv_quant=True)
    shape = SHAPES["decode_32k"]
    specs = input_specs(cfg, shape)
    assert specs["kv_k"].dtype == jnp.int8
    assert specs["kv_k_scale"].shape == specs["kv_k"].shape[:-1]
    mesh = SimpleNamespace(shape={"data": 16, "model": 16})
    psp = input_pspecs(cfg, shape, specs, mesh)
    assert len(tuple(psp["kv_k_scale"])) == 4
