"""SLO-aware scaling (Eq. 1–3, Algorithm 2) + a_max bound (Appendix A)."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.amax import MonteCarloAmax, amax_bound, make_routing_trace
from repro.core.placement import build_layout
from repro.core.scaling import PerfModel, SLOScaler, solve_batch


@pytest.fixture(scope="module")
def pm():
    cfg = get_config("dsv2-lite")
    trace = make_routing_trace(4096, cfg.num_experts, cfg.top_k, skew=1.0, seed=0)
    mc = MonteCarloAmax(trace, cfg.num_experts, trials=4)
    return PerfModel(cfg, amax_estimator=mc, slots_per_instance=12, s_ctx=512)


def test_bound_is_one_sided(pm):
    """Appendix A / Fig. 17: Eq. 5 never under-predicts the MC estimate."""
    cfg = pm.cfg
    trace = pm.amax_est.trace
    for n_e in (6, 8, 12, 16):
        layout = build_layout(trace, cfg.num_experts, n_e, pm.C)
        for B in (4, 16, 64, 256, 512):
            mc = pm.amax_est.estimate(layout, B)
            bound = amax_bound(n_e, B, cfg.num_experts, cfg.top_k, pm.C)
            assert bound >= mc - 1e-9, (n_e, B, bound, mc)


def test_amax_saturates_with_batch(pm):
    """App. A regimes: a_max grows with B then plateaus ≤ C."""
    cfg = pm.cfg
    layout = build_layout(pm.amax_est.trace, cfg.num_experts, 8, pm.C)
    vals = [pm.amax_est.estimate(layout, B) for B in (4, 32, 256, 2048)]
    assert vals[0] < vals[-1] <= pm.C
    assert vals[-1] - vals[-2] < 0.25 * max(vals[-2] - vals[1], 1e-9) + 1.0


def test_fixed_point_satisfies_littles_law(pm):
    lam = 3000.0
    B = solve_batch(pm, lam, n_a=4, n_e=8, b_max=4096)
    assert B is not None and B > 1
    tpot = pm.tpot(B, 4, 8).tpot
    assert abs(B - lam * tpot) / B < 0.01


def test_fixed_point_boundaries(pm):
    assert solve_batch(pm, 1e-6, 4, 8, b_max=4096) == 1.0  # too light
    assert solve_batch(pm, 1e9, 4, 8, b_max=64) is None  # unsustainable


def test_scaler_picks_min_gpu_feasible(pm):
    sc = SLOScaler(pm, n_max=12)
    best = sc.scale(demand=2000.0, slo=0.2)
    assert best is not None and best.feasible
    # brute force: nothing cheaper is feasible
    cheaper = [
        r for r in sc.search_log if r.feasible and r.n_a + r.n_e < best.n_a + best.n_e
    ]
    assert not cheaper
    assert best.n_e >= sc.n_e_min  # enough slots to seat all experts


def test_scaler_monotone_in_demand(pm):
    sc = SLOScaler(pm, n_max=14)
    gpus = []
    for lam in (500.0, 2000.0, 8000.0):
        best = sc.scale(lam, slo=0.2)
        assert best is not None
        gpus.append(best.n_a + best.n_e)
    assert gpus[0] <= gpus[1] <= gpus[2]


def test_tighter_slo_needs_more_resources(pm):
    sc = SLOScaler(pm, n_max=16)
    loose = sc.scale(4000.0, slo=0.3)
    tight = sc.scale(4000.0, slo=0.08)
    if tight is not None and loose is not None:
        assert tight.n_a + tight.n_e >= loose.n_a + loose.n_e
        assert loose.tpg >= tight.tpg * 0.95  # relaxed SLO → ≥ TPG (Fig. 9)


def test_dense_arch_degenerates(pm):
    """Non-MoE archs: a_max ≡ 1 and no comm term (DESIGN §Arch-applicability)."""
    cfg = get_config("yi-34b")
    m = PerfModel(cfg, s_ctx=512)
    r = m.tpot(64, 4, 4)
    assert r.a_max == 1.0
    assert r.t_comm == 0.0
