"""Cluster simulator + autoscaler: Fig. 11 qualitative claims."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.amax import MonteCarloAmax, make_routing_trace
from repro.core.scaling import PerfModel
from repro.serving.controller import AutoScaler
from repro.serving.simulator import ClusterSimulator
from repro.serving.trace import (
    arrivals_from_profile,
    bursty_arrivals,
    diurnal_rate_profile,
    poisson_arrivals,
)


@pytest.fixture(scope="module")
def sim():
    cfg = get_config("dsv2-lite")
    trace = make_routing_trace(2048, cfg.num_experts, cfg.top_k, skew=1.0, seed=0)
    mc = MonteCarloAmax(trace, cfg.num_experts, trials=4)
    pm = PerfModel(cfg, amax_estimator=mc, slots_per_instance=12, s_ctx=512)
    return ClusterSimulator(pm, slo=0.2, n_max=16)


def test_janus_min_gpu_hours(sim):
    """Fig. 11: Janus ≤ every baseline in GPU-hours at full SLO attainment."""
    t, rates = diurnal_rate_profile(hours=6, mean_rate=3.0, seed=1)
    res = sim.compare(t, rates, tokens_per_req=256.0)
    assert res["janus"].slo_attainment == 1.0
    for name in ("sglang", "megascale", "xdeepserve"):
        assert res["janus"].gpu_hours <= res[name].gpu_hours + 1e-9, name


def test_janus_tracks_load(sim):
    t, rates = diurnal_rate_profile(hours=6, mean_rate=12.0, peak_over_mean=3.0, seed=2)
    res = sim.run_janus(t, rates, tokens_per_req=256.0)
    gpus = np.array([r.total_gpus for r in res.records])
    assert gpus.max() > gpus.min()  # actually scales with the diurnal shape
    # top-quartile demand windows use at least as many GPUs (on average) as
    # bottom-quartile windows (MC noise makes per-window comparisons flaky)
    q1, q3 = np.quantile(rates, [0.25, 0.75])
    assert gpus[rates >= q3].mean() >= gpus[rates <= q1].mean()


def test_trace_generators():
    arr = poisson_arrivals(50.0, 10.0, seed=0)
    assert 300 < len(arr) < 700 and (np.diff(arr) >= 0).all()
    b = bursty_arrivals(50.0, 10.0, burstiness=3.0, seed=0)
    assert len(b) > 0 and (np.diff(b) >= 0).all()
    t, rates = diurnal_rate_profile(hours=24, mean_rate=100.0, burst_peak_over_mean=7.5)
    assert rates.max() / rates.mean() > 3.0  # bursty peaks (Fig. 4)
    a = arrivals_from_profile(t, rates, seed=0)
    assert len(a) > 1000


def test_autoscaler_events():
    cfg = get_config("dsv2-lite")
    trace = make_routing_trace(1024, cfg.num_experts, cfg.top_k, skew=0.8, seed=0)
    mc = MonteCarloAmax(trace, cfg.num_experts, trials=2)
    pm = PerfModel(cfg, amax_estimator=mc, slots_per_instance=12, s_ctx=512)
    asc = AutoScaler(pm, slo=0.2, n_max=12)
    d1 = asc.decide(0.0, demand=500.0)
    d2 = asc.decide(900.0, demand=6000.0)
    assert d2.n_a + d2.n_e >= d1.n_a + d1.n_e
    assert len(asc.events) == 2
    layout = asc.replan_layout(trace, d2.n_e)
    assert layout.num_instances == d2.n_e
