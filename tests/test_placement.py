"""Replica allocation + activation-aware placement (Appendix B) properties."""

import numpy as np
from _hypo import given, settings, st

from repro.core.amax import coactivation_matrix, make_routing_trace
from repro.core.placement import (
    allocate_replicas,
    build_layout,
    instance_coactivation_load,
    place_replicas,
)


@st.composite
def alloc_case(draw):
    E = draw(st.integers(2, 64))
    n_e = draw(st.integers(1, 12))
    C = draw(st.integers((E + n_e - 1) // n_e, 3 * ((E + n_e - 1) // n_e)))
    seed = draw(st.integers(0, 1000))
    counts = np.random.default_rng(seed).integers(0, 1000, size=E).astype(float)
    return E, n_e, C, counts


@given(alloc_case())
@settings(max_examples=50, deadline=None)
def test_allocate_replicas_properties(case):
    E, n_e, C, counts = case
    R = allocate_replicas(counts, n_e, C)
    assert (R >= 1).all()  # every expert seated
    assert (R <= n_e).all()  # at most one replica per instance
    assert R.sum() <= n_e * C
    # all slots used unless capped by the n_e ceiling
    assert R.sum() == min(n_e * C, E * n_e)


def test_hot_experts_get_more_replicas():
    counts = np.array([1000.0, 10.0, 10.0, 10.0])
    R = allocate_replicas(counts, num_instances=4, capacity=2)
    assert R[0] == R.max()
    assert R.sum() == 8


@given(alloc_case())
@settings(max_examples=30, deadline=None)
def test_place_replicas_feasibility(case):
    E, n_e, C, counts = case
    R = allocate_replicas(counts, n_e, C)
    A = np.random.default_rng(1).random((E, E))
    A = (A + A.T) / 2
    layout = place_replicas(R, A, n_e, C, loads=counts)
    # per-instance capacity respected
    for g in range(n_e):
        hosted = layout.slot_to_expert[g]
        hosted = hosted[hosted >= 0]
        assert len(hosted) <= C
        assert len(np.unique(hosted)) == len(hosted)  # no dup expert per instance
    # replica counts realised exactly
    assert np.array_equal(layout.replica_counts, R)


def test_placement_beats_naive_on_coactivation():
    """Eq. 7 objective: given the SAME replica counts, activation-aware
    placement achieves ≤ max co-activation load of a naive round-robin
    placement of those replicas."""
    from repro.core.aebs import ReplicaLayout

    E, n_e, C, k = 32, 4, 10, 4
    trace = make_routing_trace(4096, E, k, skew=1.0, seed=5)
    A = coactivation_matrix(trace, E)
    counts = np.bincount(trace.reshape(-1), minlength=E).astype(float)
    R = allocate_replicas(counts, n_e, C)
    smart = place_replicas(R, A, n_e, C, loads=counts)

    # naive: deal the identical replica multiset round-robin
    stx = -np.ones((n_e, C), np.int32)
    fill = [0] * n_e
    g = 0
    for e in range(E):
        for _ in range(int(R[e])):
            tries = 0
            while (e in stx[g, : fill[g]]) or fill[g] >= C:
                g = (g + 1) % n_e
                tries += 1
                assert tries <= n_e, "naive dealing failed"
            stx[g, fill[g]] = e
            fill[g] += 1
            g = (g + 1) % n_e
    naive = ReplicaLayout.build(stx, E)
    assert np.array_equal(naive.replica_counts, R)

    smart_load = instance_coactivation_load(smart, A).max()
    naive_load = instance_coactivation_load(naive, A).max()
    assert smart_load <= naive_load * 1.02
