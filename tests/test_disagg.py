"""Two-pool disaggregated execution: consistency, exchange patterns,
reconfiguration, telemetry.

In-process tests run on the default single CPU device with degenerate
(device-reusing) pools — the full stage/exchange/combine code path executes,
transfers are local puts.  The real ≥2+2 multi-device end-to-end check runs
in a subprocess with forced host devices (same contract as test_moe_ep).
"""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.core.disagg import DevicePools, plan_exchange
from repro.models import model as model_mod
from repro.serving.disagg import DisaggExecutor
from repro.serving.engine import ServingEngine
from repro.serving.request import WorkloadSpec, sample_requests
from repro.serving.trace import poisson_arrivals


@pytest.fixture(scope="module")
def dsv2_setup():
    cfg = get_config("dsv2-lite-reduced")
    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
    return cfg, params, layout


def _requests(cfg, n=6, seed=3):
    spec = WorkloadSpec(mean_input=6, mean_output=10, vocab_size=cfg.vocab_size,
                        max_input=16, max_output=16, seed=seed)
    arr = poisson_arrivals(100.0, n / 100.0, seed=seed)[:n]
    if len(arr) < n:
        arr = np.linspace(0, 0.1, n)
    return sample_requests(spec, arr, with_prompts=True)


def _step_fixture(cfg, params, B=6, S=16, cache_len=32):
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, S + 1), 0, cfg.vocab_size)
    _, caches = model_mod.prefill(params, tokens[:, :S], cfg, cache_len=cache_len)
    positions = jnp.full((B,), S, jnp.int32)
    return tokens[:, S:], caches, positions


def _executor(cfg, params, layout, n_attn, *, B=6, cache_len=32, **kw):
    pools = DevicePools.split(n_attn, layout.num_instances, allow_reuse=True)
    return DisaggExecutor(cfg, params, pools, layout,
                          max_batch=B, cache_len=cache_len, **kw)


# ---------------------------------------------------------------------------
# Executor-level consistency
# ---------------------------------------------------------------------------


def test_disagg_pool_shapes_bit_identical(dsv2_setup):
    """Pool sharding, the two-phase exchange, and micro-batch ping-pong are
    numerically transparent: every pool shape produces bit-identical logits."""
    cfg, params, layout = dsv2_setup
    tok, caches, positions = _step_fixture(cfg, params)
    ref = None
    for n_attn, pp in [(1, False), (2, False), (3, False), (2, True)]:
        ex = _executor(cfg, params, layout, n_attn, ping_pong=pp, capacity=64)
        ex.load_caches(caches)
        logits, tel = ex.decode_step(tok, positions)
        got = np.asarray(logits)
        if ref is None:
            ref = got
        else:
            np.testing.assert_array_equal(got, ref, err_msg=f"n_attn={n_attn} pp={pp}")
        assert tel["regime"] in ("case1", "case2")
        assert tel["bytes_total"] > 0 and tel["a_max"] >= 1


def test_disagg_matches_mono_decode_step(dsv2_setup):
    """Disagg logits match the monolithic jitted decode_step to jit-boundary
    rounding (same argmax everywhere), and the updated KV caches are
    bit-identical — the two executors share op-for-op semantics."""
    from repro.core.aebs import aebs_assign

    cfg, params, layout = dsv2_setup
    tok, caches, positions = _step_fixture(cfg, params)
    moe_ctx = dict(dispatch="grouped", layout_tables=layout.device_tables(),
                   slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
                   num_instances=layout.num_instances, scheduler=aebs_assign,
                   capacity=64)
    mono_logits, mono_caches = model_mod.decode_step(
        params, tok, caches, positions, cfg, extra={"moe_ctx": moe_ctx})

    ex = _executor(cfg, params, layout, 2, capacity=64)
    ex.load_caches(caches)
    logits, _ = ex.decode_step(tok, positions)
    ml, dl = np.asarray(mono_logits), np.asarray(logits)
    np.testing.assert_allclose(dl, ml, atol=0.05, rtol=0.02)
    np.testing.assert_array_equal(np.argmax(dl, -1), np.argmax(ml, -1))
    got = ex.export_caches()
    for k in got:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(mono_caches[k]))


def test_reconfigure_preserves_caches_and_logits(dsv2_setup):
    """§3.5 actuation: resizing either pool mid-run preserves the in-flight
    KV caches bit-exactly and leaves the decode function unchanged."""
    cfg, params, layout = dsv2_setup
    tok, caches, positions = _step_fixture(cfg, params)
    ex = _executor(cfg, params, layout, 2, capacity=64)
    ex.load_caches(caches)
    ref, _ = ex.decode_step(tok, positions)
    before = {k: np.asarray(v) for k, v in ex.export_caches().items()}

    rel = ex.reconfigure(n_attn=3)
    assert rel == {"attn": True, "moe": False, "prefill": False}
    after = ex.export_caches()
    for k in before:
        np.testing.assert_array_equal(np.asarray(after[k]), before[k])
    got, _ = ex.decode_step(tok, positions)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))

    rel = ex.reconfigure(n_moe=4, layout=ReplicaLayout.round_robin(cfg.num_experts, 4, 2))
    assert rel == {"attn": False, "moe": True, "prefill": False}
    got, _ = ex.decode_step(tok, positions)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert ex.relower_log == [
        {"attn": True, "moe": False, "prefill": False},
        {"attn": False, "moe": True, "prefill": False},
    ]


def test_executor_validation(dsv2_setup):
    cfg, params, layout = dsv2_setup
    from repro.core import baselines

    with pytest.raises(ValueError, match="single-active-replica"):
        _executor(cfg, params, layout, 2, scheduler=baselines.token_hash_assign)
    with pytest.raises(ValueError, match="ping_pong"):
        _executor(cfg, params, layout, 4, B=6, ping_pong=True)  # <2 rows/device
    ssm_cfg = get_config("falcon-mamba-7b-reduced")
    with pytest.raises(ValueError):
        ssm_params = model_mod.init_params(ssm_cfg, 0)
        pools = DevicePools.split(1, 2, allow_reuse=True)
        DisaggExecutor(ssm_cfg, ssm_params, pools, layout, max_batch=4, cache_len=32)


# ---------------------------------------------------------------------------
# Exchange plan structure
# ---------------------------------------------------------------------------


def test_plan_exchange_patterns():
    pools = DevicePools.split(4, 4, devices=[jax.devices()[0]] * 8, node_size=2,
                              allow_reuse=True)
    for regime in ("case1", "case2"):
        chunks, steps = plan_exchange(pools, regime)
        assert [c.members for c in chunks] == [(0, 1), (2, 3)]
        # every MoE device must end up holding every chunk
        have = {(cid, ("attn", c.members[0])) for cid, c in enumerate(chunks)}
        for st_ in steps:
            if st_.phase == 1:
                continue
            assert (st_.chunk, st_.src) in have, (regime, st_)
            have.add((st_.chunk, st_.dst))
        for g in range(4):
            for c in range(len(chunks)):
                assert (c, ("moe", g)) in have, (regime, g, c)
    # case-1: leader→every-moe-node (slow) = attn_nodes × moe_nodes messages
    _, s1 = plan_exchange(pools, "case1")
    assert sum(1 for s in s1 if s.fabric == "slow") == 2 * 2
    # case-2: one slow message per pair
    _, s2 = plan_exchange(pools, "case2")
    assert sum(1 for s in s2 if s.fabric == "slow") == 2


def test_plan_exchange_case2_splits_across_pairs():
    """When attn_nodes < moe_nodes, case-2 must row-split each node payload
    so every pair link carries ≈ total/pairs bytes (the two_phase_case2
    assumption), not the whole payload over one slow link."""
    pools = DevicePools.split(2, 8, devices=[jax.devices()[0]] * 10, node_size=2,
                              allow_reuse=True)  # 1 attn node, 4 moe nodes
    chunks, steps = plan_exchange(pools, "case2")
    assert len(chunks) == 4 and all(c.n_subs == 4 for c in chunks)
    assert [c.sub for c in chunks] == [0, 1, 2, 3]
    slow = [s for s in steps if s.fabric == "slow"]
    assert len(slow) == 4  # one slow message per pair
    assert {s.dst for s in slow} == {("moe", 0), ("moe", 2), ("moe", 4), ("moe", 6)}


def test_disagg_exchange_split_chunks_consistent(dsv2_setup):
    """Case-2 sub-chunking (1 attention node feeding 2 MoE nodes) splits the
    payload across pair links yet reassembles the full activation block, in
    row order, on every MoE device."""
    cfg, params, layout = dsv2_setup
    ex = _executor(cfg, params, layout, 1, capacity=64)  # 1 attn dev, 2 moe nodes
    h = jnp.arange(6 * 1 * cfg.d_model, dtype=jnp.bfloat16).reshape(6, 1, cfg.d_model)
    for regime in ("case1", "case2"):
        tel = {"bytes_slow": 0, "bytes_fast": 0, "msgs_slow": 0, "msgs_fast": 0}
        outs = ex._run_exchange({0: h}, regime, tel)
        assert len(outs) == 2
        for got in outs:
            np.testing.assert_array_equal(np.asarray(got), np.asarray(h))
    # case-2 split: 2 pair messages, each ≈ half the payload on the wire
    chunks, _ = plan_exchange(ex.pools, "case2")
    assert len(chunks) == 2 and all(c.n_subs == 2 for c in chunks)


def test_reconfigure_custom_pools_stays_in_universe(dsv2_setup):
    """An executor built on a custom device subset reconfigures within that
    subset — pool addresses never drift away from where weights live."""
    cfg, params, layout = dsv2_setup
    devs = [jax.devices()[0]] * 5
    pools = DevicePools.split(2, 2, devices=devs[:4])
    ex = DisaggExecutor(cfg, params, pools, layout, max_batch=6, cache_len=32,
                        capacity=64)
    tok, caches, positions = _step_fixture(cfg, params)
    ex.load_caches(caches)
    want, _ = ex.decode_step(tok, positions)
    ex.reconfigure(n_attn=1)
    assert len(ex.pools.attn_devices) == 1 and len(ex.pools.moe_devices) == 2
    got, _ = ex.decode_step(tok, positions)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pools_anchor_unaffected_side():
    """Resizing one pool never relocates the other pool's devices (so
    reconfigure really can leave the unaffected pool's weights in place)."""
    devs = [jax.devices()[0]] * 8
    a = DevicePools.split(2, 4, devices=devs)
    b = DevicePools.split(3, 4, devices=devs)
    assert [id(d) for d in a.moe_devices] == [id(d) for d in b.moe_devices]
    c = DevicePools.split(2, 3, devices=devs)
    assert [id(d) for d in a.attn_devices] == [id(d) for d in c.attn_devices]


def test_pools_three_way_anchoring():
    """Third sub-cluster anchoring: the prefill pool sits immediately ahead
    of the (tail-anchored) MoE pool; resizing prefill never relocates either
    decode pool, and resizing attention never relocates prefill or MoE."""

    class _D:  # distinct sentinel "devices" so identity checks are real
        pass

    devs = [_D() for _ in range(10)]
    a = DevicePools.split(2, 4, devices=devs, n_prefill=2)
    assert a.attn_devices == devs[:2]
    assert a.moe_devices == devs[-4:]
    assert a.prefill_devices == devs[4:6]
    # prefill resize: decode pools anchored
    b = DevicePools.split(2, 4, devices=devs, n_prefill=3)
    assert b.attn_devices == a.attn_devices and b.moe_devices == a.moe_devices
    # attention resize: prefill + MoE anchored
    c = DevicePools.split(3, 4, devices=devs, n_prefill=2)
    assert c.prefill_devices == a.prefill_devices and c.moe_devices == a.moe_devices
    # n_prefill=0 keeps the legacy two-way layout exactly
    d = DevicePools.split(2, 4, devices=devs)
    assert d.prefill_devices == [] and d.attn_devices == a.attn_devices
    assert d.moe_devices == a.moe_devices


# ---------------------------------------------------------------------------
# Engine-level: continuous batching, telemetry, reconfigure
# ---------------------------------------------------------------------------


def test_engine_disagg_matches_mono_tokens(dsv2_setup):
    """executor='disagg' (with and without ping-pong) serves the same token
    counts per request as the monolithic engine over a multi-request
    continuous-batching run."""
    cfg, params, layout = dsv2_setup
    outs = {}
    for name, kw in [
        ("mono", dict(executor="mono")),
        ("disagg", dict(executor="disagg", n_attn=2)),
        ("disagg_pp", dict(executor="disagg", n_attn=2, ping_pong=True)),
    ]:
        eng = ServingEngine(cfg, params, max_batch=4, cache_len=64, layout=layout,
                            scheduler="aebs", capacity_tokens=64, **kw)
        m = eng.run(_requests(cfg, 5), max_steps=2000)
        assert m["completed"] == 5
        outs[name] = {r.rid: r.generated for r in eng.completed}
        if name != "mono":
            assert m["regime_counts"] and m["transfer_bytes_total"] > 0
            assert m["amax_max"] >= 1
            assert set(m["regime_counts"]) <= {"case1", "case2"}
            assert eng.transfer_bytes_log and len(eng.regime_log) == len(eng.amax_log)
    assert outs["mono"] == outs["disagg"] == outs["disagg_pp"]


def test_engine_prefill_pool_streams_bit_identical(dsv2_setup):
    """With the prefill pool enabled (pipelined chunked admission, streamed
    per-chunk KV hand-off), the continuous-batching greedy token streams are
    bit-identical to the monolithic blocking engine, and the decode clock is
    never charged for prompt work."""
    cfg, params, layout = dsv2_setup
    streams = {}
    for name, kw in [
        ("mono", dict(executor="mono")),
        ("mono_pipe", dict(executor="mono", n_prefill=1, prefill_chunk=4)),
        ("disagg_pipe", dict(executor="disagg", n_attn=2, n_prefill=1, prefill_chunk=4)),
    ]:
        eng = ServingEngine(cfg, params, max_batch=4, cache_len=64, layout=layout,
                            scheduler="aebs", capacity_tokens=64, **kw)
        m = eng.run(_requests(cfg, 5), max_steps=2000)
        assert m["completed"] == 5
        streams[name] = {r.rid: r.tokens_out for r in eng.completed}
        if name == "mono":
            assert eng.admission == "blocking"
        else:
            assert eng.admission == "pipelined"
            assert m["decode_stall_time"] == 0.0
            assert m["prefill_chunks"] >= 5  # prompts really went chunk-wise
            assert m["ttft_mean"] > 0
        assert all(len(s) > 0 for s in streams[name].values())
    assert streams["mono"] == streams["mono_pipe"] == streams["disagg_pipe"]


def test_engine_reconfigure_prefill_pool(dsv2_setup):
    """Scaling the prefill pool mid-run re-lowers only the prefill side and
    leaves served tokens identical; the AutoScaler can drive it from its own
    prompt-token demand signal."""
    from repro.core.scaling import EvalResult, PerfModel
    from repro.serving.controller import AutoScaler

    cfg, params, layout = dsv2_setup
    eng = ServingEngine(cfg, params, max_batch=4, cache_len=64, layout=layout,
                        scheduler="aebs", capacity_tokens=64,
                        executor="disagg", n_attn=2, n_prefill=1, prefill_chunk=4)
    eng.run(_requests(cfg, 3, seed=1), max_steps=2000)
    rel = eng.reconfigure(n_prefill=2)
    assert rel == {"attn": False, "moe": False, "prefill": True}
    assert len(eng.disagg.pools.prefill_devices) == 2
    assert len(eng.prefill_worker.devices) == 2
    m = eng.run(_requests(cfg, 3, seed=2), max_steps=2000)
    assert m["completed"] == 6

    # controller path: prefill demand sizes the pool independently
    ctrl = AutoScaler(PerfModel(cfg, slots_per_instance=3, s_ctx=64), slo=0.2,
                      prefill_tok_rate=100.0)
    decision = EvalResult(n_a=2, n_e=2, batch=4, tpot=0.1, t_attn=0, t_moe=0,
                          t_comm=0, a_max=1, tpg=1.0, feasible=True)
    ctrl.scaler.scale = lambda lam, slo: decision  # pin the decode decision
    for t, n_in in [(0.0, 120.0), (1.0, 150.0)]:
        ctrl.observe(t, 16.0, input_tokens=n_in)
    n_p = ctrl.decide_prefill(now=2.0, demand=250.0)
    assert n_p == 3  # ceil(250 / 100)
    ctrl.actuate(eng, now=2.0)
    assert ctrl.events[-1].n_p is not None
    assert len(eng.disagg.pools.prefill_devices) == ctrl.events[-1].n_p
    m = eng.run(_requests(cfg, 2, seed=9), max_steps=2000)
    assert m["completed"] == 8


def test_engine_reconfigure_mid_run(dsv2_setup):
    """Scaling the pools between run() segments keeps in-flight state sane
    and the served tokens identical to an undisturbed engine."""
    cfg, params, layout = dsv2_setup
    reqs_a, reqs_b = _requests(cfg, 3, seed=1), _requests(cfg, 3, seed=2)
    for r in reqs_b:
        r.rid += 100

    ref = ServingEngine(cfg, params, max_batch=3, cache_len=64, layout=layout,
                        scheduler="aebs", capacity_tokens=64,
                        executor="disagg", n_attn=2)
    ref.run(list(reqs_a), max_steps=2000)
    ref.run(list(reqs_b), max_steps=2000)
    want = {r.rid: r.generated for r in ref.completed}

    eng = ServingEngine(cfg, params, max_batch=3, cache_len=64, layout=layout,
                        scheduler="aebs", capacity_tokens=64,
                        executor="disagg", n_attn=2)
    eng.run(list(_requests(cfg, 3, seed=1)), max_steps=2000)
    rel = eng.reconfigure(n_attn=3)
    assert rel["attn"] and not rel["moe"]
    reqs_b2 = _requests(cfg, 3, seed=2)
    for r in reqs_b2:
        r.rid += 100
    m = eng.run(reqs_b2, max_steps=2000)
    assert m["completed"] == 6
    assert {r.rid: r.generated for r in eng.completed} == want


def test_controller_actuates_reconfigure(dsv2_setup):
    """AutoScaler.actuate applies its (n_a, n_e) decision to a live disagg
    engine — the scaling loop is closed, not advisory."""
    from repro.core.scaling import EvalResult, PerfModel
    from repro.serving.controller import AutoScaler

    cfg, params, layout = dsv2_setup
    eng = ServingEngine(cfg, params, max_batch=4, cache_len=64, layout=layout,
                        scheduler="aebs", capacity_tokens=64,
                        executor="disagg", n_attn=2)
    eng.run(_requests(cfg, 3), max_steps=2000)

    ctrl = AutoScaler(PerfModel(cfg, slots_per_instance=3, s_ctx=64), slo=0.2)
    decision = EvalResult(n_a=3, n_e=2, batch=4, tpot=0.1, t_attn=0, t_moe=0,
                          t_comm=0, a_max=1, tpg=1.0, feasible=True)
    ctrl.scaler.scale = lambda lam, slo: decision  # pin the decision
    best = ctrl.actuate(eng, now=0.0)
    assert (best.n_a, best.n_e) == (3, 2)
    assert len(eng.disagg.pools.attn_devices) == 3
    assert eng.disagg.relower_log[-1] == {"attn": True, "moe": False, "prefill": False}
    m = eng.run(_requests(cfg, 2, seed=9), max_steps=2000)
    assert m["completed"] == 5


def test_engine_mono_rejects_reconfigure(dsv2_setup):
    cfg, params, layout = dsv2_setup
    eng = ServingEngine(cfg, params, max_batch=2, cache_len=32, layout=layout,
                        scheduler="aebs", capacity_tokens=64)
    with pytest.raises(NotImplementedError):
        eng.reconfigure(n_attn=2)


# ---------------------------------------------------------------------------
# Real multi-device end-to-end (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.request import WorkloadSpec, sample_requests

assert len(jax.devices()) == 8
cfg = get_config("dsv2-lite-reduced")
params = model_mod.init_params(cfg, 0)
layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)

spec = WorkloadSpec(mean_input=5, mean_output=8, vocab_size=cfg.vocab_size,
                    max_input=8, max_output=8, seed=0)
def reqs():
    return sample_requests(spec, np.linspace(0, 0.05, 4), with_prompts=True)

outs = {}
for name, kw in [("mono", dict(executor="mono")),
                 ("disagg", dict(executor="disagg", n_attn=2)),
                 ("disagg_prefill", dict(executor="disagg", n_attn=2,
                                         n_prefill=2, prefill_chunk=3))]:
    eng = ServingEngine(cfg, params, max_batch=4, cache_len=32, layout=layout,
                        scheduler="aebs", capacity_tokens=64, **kw)
    m = eng.run(reqs(), max_steps=500)
    assert m["completed"] == 4, m
    outs[name] = {r.rid: tuple(r.tokens_out) for r in eng.completed}
    if name != "mono":
        # the pools must be real, disjoint devices
        ds = eng.disagg.pools
        n_p = len(ds.prefill_devices)
        assert len({d.id for d in ds.attn_devices + ds.moe_devices
                    + ds.prefill_devices}) == 4 + n_p
        assert m["regime_counts"] and m["transfer_bytes_total"] > 0
    if name == "disagg_prefill":
        assert m["prefill_chunks"] >= 4 and m["decode_stall_time"] == 0.0
assert outs["mono"] == outs["disagg"] == outs["disagg_prefill"], outs
print("DISAGG_OK", {k: len(v) for k, v in outs["disagg"].items()})
"""


def run_forced_device_subprocess(script, timeout=540, marker="OK"):
    """Run a forced-host-device script in a child process with an explicit
    deadline: on a hang the child is killed (``subprocess.run`` sends
    SIGKILL on expiry) and whatever it printed before stalling is surfaced —
    a hung multi-device exchange must fail loudly with its partial output,
    not eat the suite's whole timeout budget silently."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
           "HOME": "/root"}
    try:
        r = subprocess.run(
            [sys.executable, "-c", script],
            capture_output=True, text=True, env=env, cwd="/root/repo",
            timeout=timeout,
        )
    except subprocess.TimeoutExpired as e:
        def _txt(b):
            return b.decode(errors="replace") if isinstance(b, bytes) else (b or "")
        pytest.fail(
            f"multi-device subprocess hung past {timeout}s and was killed\n"
            f"--- captured stdout ---\n{_txt(e.stdout)}\n"
            f"--- captured stderr ---\n{_txt(e.stderr)}"
        )
    assert r.returncode == 0 and marker in r.stdout, (
        f"subprocess exited rc={r.returncode}\n--- stdout ---\n{r.stdout}\n"
        f"--- stderr ---\n{r.stderr}"
    )
    return r


@pytest.mark.subprocess
def test_disagg_multidevice_subprocess():
    run_forced_device_subprocess(SCRIPT, marker="DISAGG_OK")
