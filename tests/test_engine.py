"""Continuous-batching serving engine: end-to-end behaviour."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.amax import make_routing_trace
from repro.core.placement import build_layout
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.request import WorkloadSpec, sample_requests
from repro.serving.trace import poisson_arrivals


@pytest.fixture(scope="module")
def moe_setup():
    cfg = get_config("qwen2-moe-a2.7b-reduced")
    params = model_mod.init_params(cfg, 0)
    trace = make_routing_trace(512, cfg.num_experts, cfg.top_k, skew=0.8, seed=0)
    layout = build_layout(trace, cfg.num_experts, num_instances=2, capacity=3)
    return cfg, params, layout


def _requests(cfg, n=8, seed=0):
    spec = WorkloadSpec(mean_input=6, mean_output=10, vocab_size=cfg.vocab_size,
                        max_input=16, max_output=16, seed=seed)
    arr = poisson_arrivals(100.0, n / 100.0, seed=seed)[:n]
    if len(arr) < n:
        arr = np.linspace(0, 0.1, n)
    return sample_requests(spec, arr, with_prompts=True)


def test_engine_completes_all_requests(moe_setup):
    cfg, params, layout = moe_setup
    reqs = _requests(cfg, 6)
    eng = ServingEngine(cfg, params, max_batch=3, cache_len=64, layout=layout, scheduler="aebs")
    m = eng.run(reqs, max_steps=2000)
    assert m["completed"] == 6
    assert m["tokens"] == sum(r.generated for r in eng.completed)
    assert m["tpot_mean"] > 0
    for r in eng.completed:
        assert r.generated >= 1
        assert len(r.token_times) == r.generated + 1  # prefill token + decodes


def test_scheduler_does_not_change_tokens(moe_setup):
    """AEBS only relocates replica computation — greedy decode tokens must be
    identical with and without scheduling (numerical transparency, e2e)."""
    cfg, params, layout = moe_setup
    outs = {}
    for sched in ("none", "aebs"):
        reqs = _requests(cfg, 4, seed=3)
        eng = ServingEngine(
            cfg, params, max_batch=2, cache_len=64,
            layout=layout if sched != "none" else None,
            scheduler=sched, capacity_tokens=64,
        )
        eng.run(reqs, max_steps=1000)
        outs[sched] = [r.generated for r in sorted(eng.completed, key=lambda r: r.rid)]
    assert outs["none"] == outs["aebs"]


def test_engine_dense_arch():
    cfg = get_config("phi4-mini-3.8b-reduced")
    params = model_mod.init_params(cfg, 0)
    reqs = _requests(cfg, 4)
    eng = ServingEngine(cfg, params, max_batch=2, cache_len=64, scheduler="none")
    m = eng.run(reqs, max_steps=1000)
    assert m["completed"] == 4


def test_engine_modeled_clock(moe_setup):
    """step_time_fn drives the clock deterministically (simulation mode)."""
    cfg, params, layout = moe_setup
    reqs = _requests(cfg, 4)
    eng = ServingEngine(
        cfg, params, max_batch=4, cache_len=64, layout=layout,
        step_time_fn=lambda n_active: 0.01,
    )
    m = eng.run(reqs, max_steps=1000)
    gaps = np.diff(eng.completed[0].token_times)
    assert np.allclose(gaps, 0.01, atol=1e-9)
