"""Sharding-rule validity: every PartitionSpec divides its dimension, for
every architecture × mesh, without touching device state (abstract only)."""

from types import SimpleNamespace

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, REGISTRY, SHAPES, input_specs, shape_supported
from repro.launch.steps import abstract_params, serving_layout
from repro.sharding.rules import input_pspecs, param_pspecs

MESHES = {
    "16x16": SimpleNamespace(shape={"data": 16, "model": 16}),
    "2x16x16": SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16}),
}


def _axis_size(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def _check_spec(shape, spec, mesh, what):
    entries = tuple(spec)
    assert len(entries) <= len(shape), (what, shape, spec)
    for dim, entry in zip(shape, entries):
        k = _axis_size(mesh, entry)
        assert dim % k == 0, f"{what}: dim {dim} not divisible by {k} ({spec})"


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_param_pspecs_divisible(arch, mesh_name):
    cfg = REGISTRY[arch]
    mesh = MESHES[mesh_name]
    params = abstract_params(cfg)
    for fsdp in (False, True):
        specs = param_pspecs(cfg, params, mesh, fsdp=fsdp)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for leaf, spec in zip(flat_p, flat_s):
            _check_spec(leaf.shape, spec, mesh, f"{arch} fsdp={fsdp}")


@pytest.mark.parametrize("mesh_name", list(MESHES))
@pytest.mark.parametrize("arch", sorted(ASSIGNED))
@pytest.mark.parametrize("shape_name", sorted(SHAPES))
def test_input_pspecs_divisible(arch, shape_name, mesh_name):
    cfg, shape = REGISTRY[arch], SHAPES[shape_name]
    if not shape_supported(cfg, shape)[0]:
        pytest.skip("unsupported combo")
    mesh = MESHES[mesh_name]
    specs = input_specs(cfg, shape)
    pspecs = input_pspecs(cfg, shape, specs, mesh)
    for name, s in specs.items():
        _check_spec(s.shape, pspecs[name], mesh, f"{arch}/{shape_name}/{name}")


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "phi3.5-moe-42b-a6.6b"])
def test_serving_layout_slots_divisible(arch):
    cfg = REGISTRY[arch]
    for n in (16, 256):
        layout = serving_layout(cfg, n)
        assert layout.total_slots % n == 0
        assert layout.total_slots >= cfg.num_experts
        assert (layout.replica_counts >= 1).all()
        # headroom: at least one expert replicated
        assert layout.total_slots > cfg.num_experts
