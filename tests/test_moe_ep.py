"""Expert-parallel shard_map MoE vs single-device reference.

Needs >1 device, so the actual check runs in a subprocess with
``--xla_force_host_platform_device_count=8`` (the test process itself must
stay single-device per the dry-run contract)."""

import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.launch.mesh import use_mesh
from repro.models import moe as moe_mod
from repro.models.moe_ep import moe_layer_ep

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("qwen2-moe-a2.7b-reduced")  # 4 experts top-2
params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32) * 0.3

# reference: single-device einsum dispatch, ample capacity
y_ref = moe_mod.moe_layer(params, x, cfg, capacity=64)

with use_mesh(mesh):
    # logical EP mode (training path) — scatter and grouped bodies
    for disp in ("scatter", "grouped"):
        y_ep = jax.jit(lambda p, xx: moe_layer_ep(
            p, xx, cfg, mesh=mesh, dp_axes=("data",), model_axis="model",
            mode="logical", dispatch=disp, capacity_factor=8.0))(params, x)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_ep), atol=2e-4, rtol=2e-3)

    # scheduled EP mode (serving path): slots divisible by model axis
    layout = ReplicaLayout.round_robin(cfg.num_experts, 4, 2)
    stx = jnp.asarray(layout.slot_to_expert.reshape(-1))
    for disp in ("scatter", "grouped"):
        y_sched = jax.jit(lambda p, xx: moe_layer_ep(
            p, xx, cfg, mesh=mesh, dp_axes=("data",), model_axis="model",
            mode="scheduled", dispatch=disp, scheduler=aebs_assign,
            layout_tables=layout.device_tables(), slot_to_expert=stx,
            num_instances=4, capacity_factor=8.0))(params, x)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sched), atol=2e-4, rtol=2e-3)

    # scheduled + grouped with weights pinned at deployment (identity map)
    pinned = dict(params)
    for n in ("w_gate", "w_up", "w_down"):
        pinned[n] = params[n][jnp.maximum(stx, 0)]
    y_pin = jax.jit(lambda p, xx: moe_layer_ep(
        p, xx, cfg, mesh=mesh, dp_axes=("data",), model_axis="model",
        mode="scheduled", dispatch="grouped", scheduler=aebs_assign,
        layout_tables=layout.device_tables(), slot_to_expert=stx,
        num_instances=4, capacity_factor=8.0))(pinned, x)
    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_pin), atol=2e-4, rtol=2e-3)

    # gradients flow through the EP path
    def loss(p):
        return jnp.sum(moe_layer_ep(
            p, x, cfg, mesh=mesh, dp_axes=("data",), model_axis="model",
            mode="logical", capacity_factor=8.0) ** 2)
    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
print("EP_OK")
"""


@pytest.mark.subprocess
def test_ep_matches_reference_subprocess():
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "JAX_PLATFORMS": "cpu",
             "HOME": "/root"},
        cwd="/root/repo",
        timeout=600,
    )
    assert "EP_OK" in r.stdout, r.stdout + "\n" + r.stderr
