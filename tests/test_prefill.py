"""Chunked prefill + the prefill-pool worker.

Model level: iterated ``prefill_chunk`` must reproduce whole-prompt
``prefill`` bit-exactly (caches and last-token logits) for attention+FFN
stacks — that equivalence is what lets the prefill pool stream KV chunks
into live decode caches without touching decode numerics.

Worker level: the admission pipeline (queue → per-device chunk streaming →
completion stamps on the concurrent pool timeline) and the whole-prompt
fallback for non-chunkable architectures.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_mod
from repro.serving.kv_cache import scatter_prefill_caches, scatter_prefill_chunk_caches
from repro.serving.prefill import PrefillWorker
from repro.serving.request import Request


@pytest.fixture(scope="module")
def dsv2():
    cfg = get_config("dsv2-lite-reduced")
    return cfg, model_mod.init_params(cfg, 0)


def _run_chunked(cfg, params, toks, cache_len, chunk, extra=None):
    caches = model_mod.init_decode_caches(cfg, toks.shape[0], cache_len)
    logits = None
    pos = 0
    while pos < toks.shape[1]:
        c = min(chunk, toks.shape[1] - pos)
        logits, caches = model_mod.prefill_chunk(
            params, toks[:, pos : pos + c], caches, jnp.int32(pos), cfg, extra=extra
        )
        pos += c
    return logits, caches


# ---------------------------------------------------------------------------
# model level: bit-equivalence with whole-prompt prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("chunk", [1, 4, 5, 13, 32])
def test_chunked_prefill_matches_whole_prompt_exactly(dsv2, chunk):
    """Any chunking of the prompt (even ragged tails) produces bit-identical
    caches and last-token logits to one whole-prompt prefill call."""
    cfg, params = dsv2
    S, CL = 13, 32
    toks = jax.random.randint(jax.random.PRNGKey(0), (1, S), 0, cfg.vocab_size)
    extra = {"moe_ctx": {"capacity": 64}}  # ample: no capacity drops either way
    want_logits, want = model_mod.prefill(params, toks, cfg, cache_len=CL, extra=extra)
    got_logits, got = _run_chunked(cfg, params, toks, CL, chunk, extra=extra)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(got_logits), np.asarray(want_logits))


@pytest.mark.parametrize(
    "S,CL,chunk",
    [
        (12, 32, 5),    # prompt inside the window, ragged chunks
        (100, 128, 16), # prompt wraps the 64-token rolling window
        (100, 128, 7),  # wrap + chunks straddling the wrap point
    ],
)
def test_chunked_prefill_sliding_window_arch(S, CL, chunk):
    """dense_local layers (rolling-window KV layout) chunk correctly,
    including prompts *longer than the window* — the regime where the rolling
    buffer wraps, slot indices diverge from absolute positions, and a chunk's
    own keys overwrite predecessors its earlier queries still need (attended
    from the fresh segment, never the overwritten slot)."""
    cfg = get_config("gemma2-2b-reduced")
    params = model_mod.init_params(cfg, 0)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    want_logits, want = model_mod.prefill(params, toks, cfg, cache_len=CL)
    got_logits, got = _run_chunked(cfg, params, toks, CL, chunk=chunk)
    for k in want:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(want[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(got_logits), np.asarray(want_logits))


def test_chunk_larger_than_window_rejected():
    """attention_prefill_chunk refuses chunks wider than the rolling window
    (they would overwrite keys their own queries need); the PrefillWorker
    clamps its chunk size for windowed stacks instead."""
    from repro.serving.prefill import PrefillWorker

    cfg = get_config("gemma2-2b-reduced")
    params = model_mod.init_params(cfg, 0)
    CL = 128
    toks = jax.random.randint(jax.random.PRNGKey(0), (1, 100), 0, cfg.vocab_size)
    with pytest.raises(ValueError, match="must not exceed the window"):
        _run_chunked(cfg, params, toks, CL, chunk=100)
    w = PrefillWorker(cfg, params, [], cache_len=CL, chunk=256)
    assert w.chunk == cfg.sliding_window


def test_chunked_prefill_then_decode_consistent(dsv2):
    """Decode continues seamlessly from chunk-built caches: same tokens as
    decode from whole-prompt caches."""
    cfg, params = dsv2
    S, CL = 9, 32
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, S + 4), 0, cfg.vocab_size)
    _, c_whole = model_mod.prefill(params, toks[:, :S], cfg, cache_len=CL)
    _, c_chunk = _run_chunked(cfg, params, toks[:, :S], CL, chunk=4)
    for t in range(4):
        l1, c_whole = model_mod.decode_step(params, toks[:, S + t : S + t + 1], c_whole, jnp.int32(S + t), cfg)
        l2, c_chunk = model_mod.decode_step(params, toks[:, S + t : S + t + 1], c_chunk, jnp.int32(S + t), cfg)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_unsupported_arch_raises():
    cfg = get_config("falcon-mamba-7b-reduced")
    assert not model_mod.supports_chunked_prefill(cfg)
    params = model_mod.init_params(cfg, 0)
    caches = model_mod.init_decode_caches(cfg, 1, 16)
    toks = jnp.zeros((1, 4), jnp.int32)
    with pytest.raises(ValueError, match="chunked prefill"):
        model_mod.prefill_chunk(params, toks, caches, jnp.int32(0), cfg)


def test_kv_quant_configs_chunk_deterministically():
    """Quantised caches chunk now: each chunk's K/V is quantised exactly once
    (per-token absmax — independent of the chunk grid), earlier chunks are
    attended through the int8 round-trip, and raw keys are never re-read
    across a chunk boundary.  The result is *chunk-grid invariant* for
    non-window stacks — the determinism the serving paths (streamed hand-off,
    replay) rely on — though not bit-equal to whole-prompt ``prefill``,
    which attends raw fp keys."""
    import dataclasses

    cfg = get_config("dsv2-lite-reduced")
    qcfg = dataclasses.replace(cfg, kv_quant=True)
    assert model_mod.supports_chunked_prefill(qcfg)
    params = model_mod.init_params(qcfg, 0)
    S, CL = 13, 32
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, S), 0, cfg.vocab_size)
    extra = {"moe_ctx": {"capacity": 64}}
    # (No comparison against whole-prompt caches: already at layer 1 the keys
    # depend on layer 0's attention output, which saw round-tripped — not
    # raw — keys, so the two paths diverge by design below the top layer.)
    l5, c5 = _run_chunked(qcfg, params, toks, CL, chunk=5, extra=extra)
    l13, c13 = _run_chunked(qcfg, params, toks, CL, chunk=13, extra=extra)
    for k in c5:
        np.testing.assert_array_equal(np.asarray(c5[k]), np.asarray(c13[k]), err_msg=k)
    np.testing.assert_array_equal(np.asarray(l5), np.asarray(l13))


# ---------------------------------------------------------------------------
# worker level: pipeline, streaming sink, pool timeline
# ---------------------------------------------------------------------------


def _mk_req(rid, prompt):
    return Request(rid=rid, arrival=0.0, input_len=len(prompt), output_len=4,
                   prompt=np.asarray(prompt, np.int32), token_times=[])


def test_worker_streams_chunks_and_matches_bulk_scatter(dsv2):
    """Chunks streamed through the sink compose to exactly the bulk
    whole-prompt scatter, and the completion's first token matches the
    blocking path's."""
    cfg, params = dsv2
    CL, B = 32, 2
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=11, dtype=np.int32)
    req = _mk_req(0, prompt)

    batch = model_mod.init_decode_caches(cfg, B, CL)
    state = {"caches": batch, "chunks": []}

    def sink(slot, start, length, one_caches):
        assert length > 0  # chunked arch: never the bulk fallback
        state["chunks"].append((start, length))
        state["caches"] = scatter_prefill_chunk_caches(
            state["caches"], one_caches, slot, start, length
        )

    # ample shared capacity: the per-chunk and whole-prompt MoE calls must see
    # the same (zero) drop behaviour — exactly what ServingEngine wires in
    extra = {"moe_ctx": {"capacity": 64}}
    w = PrefillWorker(cfg, params, [], cache_len=CL, chunk=4, extra=extra,
                      prefill_time_fn=lambda n: 0.01 * n)
    w.submit(req, slot=1, now=0.0)
    events = []
    for _ in range(10):
        events += w.poll(sink)
        if events:
            break
    assert len(events) == 1 and w.num_pending == 0
    ev = events[0]
    assert ev.slot == 1 and ev.finish_t == pytest.approx(0.01 * 11)
    assert state["chunks"] == [(0, 4), (4, 4), (8, 3)]

    # blocking-path reference
    logits, one = model_mod.prefill(params, jnp.asarray(prompt)[None, :], cfg,
                                    cache_len=CL, extra=extra)
    want = scatter_prefill_caches(model_mod.init_decode_caches(cfg, B, CL), one, 1)
    for k in want:
        np.testing.assert_array_equal(np.asarray(state["caches"][k]), np.asarray(want[k]), err_msg=k)
    assert ev.first_token == int(np.argmax(np.asarray(logits[0])))


def test_worker_streams_windowed_arch_past_wrap():
    """Streaming hand-off on a sliding-window arch with a prompt longer than
    the window: rolling (`_local`) cache rows wrap (`chunk_rows`), so the
    streamed result must still equal the bulk whole-prompt scatter."""
    cfg = get_config("gemma2-2b-reduced")
    params = model_mod.init_params(cfg, 0)
    CL, B, S = 128, 2, 100
    assert S > cfg.sliding_window  # the wrap regime is the point of this test
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=S, dtype=np.int32)

    state = {"caches": model_mod.init_decode_caches(cfg, B, CL)}

    def sink(slot, start, length, one_caches):
        assert length > 0
        state["caches"] = scatter_prefill_chunk_caches(
            state["caches"], one_caches, slot, start, length
        )

    w = PrefillWorker(cfg, params, [], cache_len=CL, chunk=16,
                      prefill_time_fn=lambda n: 0.01)
    w.submit(_mk_req(0, prompt), slot=1, now=0.0)
    events = []
    while not events:
        events = w.poll(sink)

    _, one = model_mod.prefill(params, jnp.asarray(prompt)[None, :], cfg, cache_len=CL)
    want = scatter_prefill_caches(model_mod.init_decode_caches(cfg, B, CL), one, 1)
    for k in want:
        np.testing.assert_array_equal(
            np.asarray(state["caches"][k]), np.asarray(want[k]), err_msg=k
        )


def test_worker_pool_timeline_serialises_per_device(dsv2):
    """One device: queued requests serialise on the pool timeline (FIFO);
    two devices: they overlap.  The decode clock is never involved."""
    cfg, params = dsv2
    CL = 32
    dev = jax.devices()[0]
    mk = lambda rid: _mk_req(rid, np.arange(8) % cfg.vocab_size)
    sink = lambda *a: None

    def drain(w):
        evs = []
        for _ in range(50):
            evs += w.poll(sink)
            if len(evs) == 2:
                return evs
        raise AssertionError("did not drain")

    w1 = PrefillWorker(cfg, params, [dev], cache_len=CL, chunk=4,
                       prefill_time_fn=lambda n: 0.01 * n)
    w1.submit(mk(0), slot=0, now=0.0)
    w1.submit(mk(1), slot=1, now=0.0)
    e1 = drain(w1)
    assert e1[0].finish_t == pytest.approx(0.08)
    assert e1[1].finish_t == pytest.approx(0.16)  # waited for the device

    w2 = PrefillWorker(cfg, params, [dev, dev], cache_len=CL, chunk=4,
                       prefill_time_fn=lambda n: 0.01 * n)
    w2.submit(mk(0), slot=0, now=0.0)
    w2.submit(mk(1), slot=1, now=0.0)
    e2 = drain(w2)
    assert all(ev.finish_t == pytest.approx(0.08) for ev in e2)  # parallel pools


def test_worker_whole_prompt_fallback():
    """Recurrent stacks can't chunk: the worker falls back to one
    whole-prompt prefill on the pool device, handed off with length=-1."""
    cfg = get_config("falcon-mamba-7b-reduced")
    params = model_mod.init_params(cfg, 0)
    w = PrefillWorker(cfg, params, [], cache_len=32, chunk=4,
                      prefill_time_fn=lambda n: 0.001 * n)
    assert not w.chunked
    calls = []
    w.submit(_mk_req(0, np.arange(6) % cfg.vocab_size), slot=0, now=0.0)
    evs = w.poll(lambda slot, start, length, caches: calls.append((slot, start, length)))
    assert len(evs) == 1 and calls == [(0, 0, -1)]
