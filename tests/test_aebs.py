"""AEBS (Algorithm 1) unit + property tests: the three implementations agree
and the scheduler's invariants hold on arbitrary routing patterns."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.core.aebs import ReplicaLayout, aebs_assign, aebs_numpy
from repro.core.amax import make_routing_trace
from repro.core.baselines import random_numpy, token_hash_numpy
from repro.core.placement import build_layout


def _layout(E, n_e, C, seed=0):
    trace = make_routing_trace(512, E, min(4, E), skew=0.7, seed=seed)
    return build_layout(trace, E, n_e, C)


@st.composite
def routing_case(draw):
    E = draw(st.integers(4, 48))
    n_e = draw(st.integers(2, 8))
    C = draw(st.integers((E + n_e - 1) // n_e, 2 * ((E + n_e - 1) // n_e) + 1))
    T = draw(st.integers(1, 64))
    k = draw(st.integers(1, min(4, E)))
    seed = draw(st.integers(0, 2**16))
    rng = np.random.default_rng(seed)
    eids = np.stack([rng.choice(E, size=k, replace=False) for _ in range(T)]).astype(np.int32)
    return E, n_e, C, eids, seed


@given(routing_case())
@settings(max_examples=40, deadline=None)
def test_aebs_invariants(case):
    E, n_e, C, eids, seed = case
    layout = _layout(E, n_e, C, seed)
    slots, load, act_rep = aebs_numpy(eids, layout)
    activated = np.unique(eids)
    # 1. every activated expert got exactly one replica; others none
    assert (act_rep[activated] >= 0).all()
    inact = np.setdiff1d(np.arange(E), activated)
    assert (act_rep[inact] == -1).all()
    # 2. the chosen slot actually hosts that expert
    for e in activated:
        g, c = divmod(int(act_rep[e]), layout.capacity)
        assert layout.slot_to_expert[g, c] == e
    # 3. load accounting: sums to the number of distinct activated experts
    assert load.sum() == len(activated)
    # 4. a_max lower bound: can't beat perfect balance over hosting options
    assert load.max() >= int(np.ceil(len(activated) / n_e))
    # 5. token rewrite consistency
    assert (slots == act_rep[eids]).all()


@given(routing_case())
@settings(max_examples=25, deadline=None)
def test_jnp_matches_numpy(case):
    E, n_e, C, eids, seed = case
    layout = _layout(E, n_e, C, seed)
    s_np, load_np, rep_np = aebs_numpy(eids, layout)
    s_j, load_j, rep_j = aebs_assign(jnp.asarray(eids), layout.device_tables(), n_e)
    assert np.array_equal(np.asarray(s_j), s_np)
    assert np.array_equal(np.asarray(load_j), load_np)


@pytest.mark.parametrize("skew", [0.0, 0.7, 1.2])
@pytest.mark.parametrize("batch", [32, 128, 512])
def test_aebs_beats_baselines_on_average(skew, batch):
    """The paper's Fig. 13/14 claim: AEBS lowers a_max vs random / token-hash
    scheduling (statistically, over many batches)."""
    E, n_e, C, k = 64, 8, 12, 6
    trace = make_routing_trace(8192, E, k, skew=skew, seed=1)
    layout = build_layout(trace, E, n_e, C)
    rng = np.random.default_rng(2)
    a_aebs, a_rand, a_tok = [], [], []
    for trial in range(10):
        idx = rng.integers(0, trace.shape[0], size=batch)
        sample = trace[idx]
        a_aebs.append(aebs_numpy(sample, layout)[1].max())
        a_rand.append(random_numpy(sample, layout, rng)[1].max())
        a_tok.append(token_hash_numpy(sample, layout)[1].max())
    assert np.mean(a_aebs) <= np.mean(a_rand) + 1e-9
    assert np.mean(a_aebs) <= np.mean(a_tok) + 1e-9


def test_deterministic_sync_free():
    """Identical inputs → identical schedule (the §3.4 redundant-compute
    trick requires bitwise determinism)."""
    E, n_e, C = 32, 4, 10
    layout = _layout(E, n_e, C)
    eids = make_routing_trace(64, E, 4, skew=0.5, seed=3)
    runs = [aebs_numpy(eids, layout)[0] for _ in range(3)]
    assert all(np.array_equal(runs[0], r) for r in runs)


def test_single_replica_forced_assignment():
    """Experts with one replica must land on their unique host (pass 1)."""
    stx = np.array([[0, 1, 2], [3, 4, 0]], np.int32)  # expert 0 replicated
    layout = ReplicaLayout.build(stx, 5)
    eids = np.array([[1, 3], [2, 4], [0, 1]], np.int32)
    _, load, rep = aebs_numpy(eids, layout)
    assert rep[1] == 1 and rep[2] == 2  # slots on instance 0
    assert rep[3] == 3 and rep[4] == 4  # slots on instance 1
    # expert 0 (2 replicas) goes to the least-loaded instance; both have 2 →
    # tie-break to the first host in the table
    assert rep[0] in (0, 5)
