"""MoE dispatch-path equivalence + scheduling-transparency properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs import get_config
from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.models import moe as moe_mod


def _rand_weights(keys, E, d, f, scale=0.05):
    return {
        "w_gate": jax.random.normal(keys[0], (E, d, f), jnp.float32) * scale,
        "w_up": jax.random.normal(keys[1], (E, d, f), jnp.float32) * scale,
        "w_down": jax.random.normal(keys[2], (E, f, d), jnp.float32) * scale,
    }


@st.composite
def dispatch_case(draw):
    T = draw(st.integers(1, 48))
    k = draw(st.integers(1, 4))
    E = draw(st.integers(k, 16))
    d = draw(st.sampled_from([32, 64]))
    f = draw(st.sampled_from([64, 128]))
    cap = draw(st.integers(1, T * k))
    seed = draw(st.integers(0, 999))
    return T, k, E, d, f, cap, seed


@given(dispatch_case())
@settings(max_examples=25, deadline=None)
def test_dispatch_equivalence(case):
    """All three dispatch implementations are semantically identical,
    including capacity-overflow dropping."""
    T, k, E, d, f, cap, seed = case
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(keys[0], (T, d), jnp.float32)
    ids = jax.random.randint(keys[1], (T, k), 0, E)
    gates = jax.nn.softmax(jax.random.normal(keys[2], (T, k), jnp.float32))
    w = _rand_weights(keys[3:], E, d, f)
    y1 = moe_mod.capacity_dispatch_ffn(x, ids, gates, E, cap, w)
    y2 = moe_mod.scatter_dispatch_ffn(x, ids, gates, E, cap, w)
    y3 = moe_mod.grouped_dispatch_ffn(x, ids, gates, E, cap, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y3), atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Grouped dispatch (sort-based, slot-indirect) — the production hot path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("top_k", [1, 2, 4])
def test_grouped_matches_einsum_oracle(top_k):
    """Grouped dispatch equals the einsum oracle across top_k, at a capacity
    that forces some overflow drops."""
    T, E, d, f = 40, 8, 32, 64
    keys = jax.random.split(jax.random.PRNGKey(top_k), 6)
    x = jax.random.normal(keys[0], (T, d), jnp.float32)
    ids = jax.random.randint(keys[1], (T, top_k), 0, E)
    gates = jax.nn.softmax(jax.random.normal(keys[2], (T, top_k), jnp.float32))
    w = _rand_weights(keys[3:], E, d, f)
    cap = max(1, (T * top_k) // (2 * E))  # deliberately tight → drops
    y_oracle = moe_mod.capacity_dispatch_ffn(x, ids, gates, E, cap, w)
    y_grouped = moe_mod.grouped_dispatch_ffn(x, ids, gates, E, cap, w)
    np.testing.assert_allclose(
        np.asarray(y_oracle), np.asarray(y_grouped), atol=1e-5, rtol=1e-4
    )


@pytest.mark.parametrize("backend", ["stream", "kernel"])
def test_grouped_slot_indirect_backends(backend):
    """Slot-indirect grouped dispatch (replica slots → logical experts via a
    flat map, no weight materialisation) matches the oracle run on explicitly
    gathered weights, for both the stream loop and the Pallas kernel."""
    T, k, E, d, f = 24, 2, 6, 32, 64
    S, cap = 9, 6
    keys = jax.random.split(jax.random.PRNGKey(7), 6)
    x = jax.random.normal(keys[0], (T, d), jnp.float32)
    ids = jax.random.randint(keys[1], (T, k), 0, S)
    gates = jax.nn.softmax(jax.random.normal(keys[2], (T, k), jnp.float32))
    w = _rand_weights(keys[3:], E, d, f)
    s2e = jnp.asarray(np.array([0, 1, 2, 3, 4, 5, 0, 1, -1], np.int32))
    y = moe_mod.grouped_dispatch_ffn(
        x, ids, gates, S, cap, w, slot_to_expert=s2e, backend=backend
    )
    # oracle: gather per-slot weights (allowed off the hot path) and drop
    # items routed to the empty slot
    w_slots = moe_mod.gather_slot_weights(w, s2e)
    ids_masked = jnp.where(s2e[ids] >= 0, ids, -1)
    mask = (ids_masked >= 0).reshape(-1)
    y_oracle = moe_mod.capacity_dispatch_ffn(
        x, jnp.maximum(ids_masked, 0), gates, S, cap, w_slots, item_mask=mask
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_oracle), atol=1e-5, rtol=1e-4)


def test_grouped_bf16_matches_oracle():
    """bf16 production dtype: grouped output tracks the einsum oracle to
    ≤1e-2."""
    T, k, E, d, f, cap = 64, 2, 8, 64, 128, 12
    keys = jax.random.split(jax.random.PRNGKey(21), 6)
    x = (jax.random.normal(keys[0], (T, d), jnp.float32) * 0.5).astype(jnp.bfloat16)
    ids = jax.random.randint(keys[1], (T, k), 0, E)
    gates = jax.nn.softmax(jax.random.normal(keys[2], (T, k), jnp.float32)).astype(jnp.bfloat16)
    w = jax.tree.map(lambda a: a.astype(jnp.bfloat16), _rand_weights(keys[3:], E, d, f, scale=0.1))
    y_oracle = moe_mod.capacity_dispatch_ffn(x, ids, gates, E, cap, w)
    y_grouped = moe_mod.grouped_dispatch_ffn(x, ids, gates, E, cap, w)
    np.testing.assert_allclose(
        np.asarray(y_oracle, np.float32), np.asarray(y_grouped, np.float32),
        atol=1e-2, rtol=1e-2,
    )


def test_grouped_inactive_slots_zero_no_nans():
    """Buckets with no tokens and empty slots (-1) contribute exact zeros,
    and the output never contains NaNs."""
    T, k, E, d, f = 16, 1, 4, 16, 32
    S, cap = 8, 4
    keys = jax.random.split(jax.random.PRNGKey(11), 6)
    x = jax.random.normal(keys[0], (T, d), jnp.float32)
    ids = jnp.zeros((T, k), jnp.int32)  # everything → slot 0; slots 1.. idle
    gates = jnp.ones((T, k), jnp.float32)
    w = _rand_weights(keys[3:], E, d, f)
    s2e = jnp.asarray(np.array([2, 0, 1, 3, 2, -1, -1, -1], np.int32))
    for backend in ("stream", "kernel"):
        y = moe_mod.grouped_dispatch_ffn(
            x, ids, gates, S, cap, w, slot_to_expert=s2e, backend=backend
        )
        y = np.asarray(y)
        assert np.isfinite(y).all()
        assert np.abs(y[:cap]).max() > 0  # within capacity: served
        assert np.abs(y[cap:]).max() == 0  # overflow of slot 0: dropped


def test_grouped_moe_layer_with_and_without_shared_experts():
    """moe_layer(dispatch="grouped") equals the einsum default, with and
    without a shared-expert branch."""
    for name, has_shared in (
        ("qwen2-moe-a2.7b-reduced", True),
        ("phi3.5-moe-42b-a6.6b-reduced", False),
    ):
        cfg = get_config(name)
        params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
        assert ("shared" in params) == has_shared
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32) * 0.3
        y_e = moe_mod.moe_layer(params, x, cfg, capacity=64)
        y_g = moe_mod.moe_layer(params, x, cfg, dispatch="grouped", capacity=64)
        np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_g), atol=1e-5, rtol=1e-4)


def test_grouped_scheduled_no_weight_materialization(monkeypatch):
    """The grouped serving path must never call gather_slot_weights — that
    [S_total, d, f] copy is exactly what it exists to remove."""
    cfg = get_config("qwen2-moe-a2.7b-reduced")
    params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32) * 0.3
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
    kw = dict(
        layout_tables=layout.device_tables(),
        slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
        num_instances=2,
        scheduler=aebs_assign,
        capacity=64,
    )

    calls = []
    real = moe_mod.gather_slot_weights
    monkeypatch.setattr(
        moe_mod, "gather_slot_weights", lambda *a, **k: calls.append(1) or real(*a, **k)
    )
    y_scatter = moe_mod.moe_layer(params, x, cfg, dispatch="scatter", **kw)
    assert calls, "scatter path is expected to materialise slot weights"
    calls.clear()
    y_grouped = moe_mod.moe_layer(params, x, cfg, dispatch="grouped", **kw)
    assert not calls, "grouped path must not materialise slot weights"
    np.testing.assert_allclose(
        np.asarray(y_scatter), np.asarray(y_grouped), atol=1e-5, rtol=1e-4
    )


def test_sort_plan_matches_onehot_positions():
    """The argsort-based position computation reproduces the one-hot/cumsum
    arrival-order semantics, including masked items."""
    rng = np.random.default_rng(3)
    flat = jnp.asarray(rng.integers(0, 7, size=64).astype(np.int32))
    mask = jnp.asarray(rng.random(64) < 0.7)
    plan = moe_mod.sort_dispatch_plan(flat, 7, capacity=5, item_mask=mask)
    pos_ref = moe_mod._positions_in_bucket(flat, 7, mask)
    got = np.asarray(plan["pos"])
    want = np.asarray(pos_ref)
    keep = np.asarray(mask)
    assert np.array_equal(got[keep], want[keep])


# ---------------------------------------------------------------------------
# Scheduling transparency
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dispatch", ["einsum", "grouped"])
def test_scheduling_is_numerically_transparent(dispatch):
    """Rewriting logical experts to replica slots must not change the layer's
    output (replicas are exact copies): the Janus scheduled path equals the
    plain logical path when capacity is ample."""
    cfg = get_config("qwen2-moe-a2.7b-reduced")
    key = jax.random.PRNGKey(0)
    params = moe_mod.init_moe(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32) * 0.3

    y_plain = moe_mod.moe_layer(params, x, cfg, capacity=64)

    layout = ReplicaLayout.round_robin(cfg.num_experts, num_instances=2, capacity=3)
    y_sched = moe_mod.moe_layer(
        params,
        x,
        cfg,
        dispatch=dispatch,
        layout_tables=layout.device_tables(),
        slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
        num_instances=2,
        scheduler=aebs_assign,
        capacity=64,
    )
    np.testing.assert_allclose(np.asarray(y_plain), np.asarray(y_sched), atol=1e-5, rtol=1e-4)


def test_scheduler_choice_transparent():
    """AEBS vs token-hash vs random: same numbers, different placement.

    On the grouped path this also exercises both FFN routes: AEBS/random
    collapse to logical experts, token-hash stays slot-indirect."""
    from repro.core import baselines

    cfg = get_config("qwen2-moe-a2.7b-reduced")
    params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, cfg.d_model), jnp.float32) * 0.3
    layout = ReplicaLayout.round_robin(cfg.num_experts, num_instances=2, capacity=4)
    for dispatch in ("scatter", "grouped"):
        outs = []
        for sched in (aebs_assign, baselines.random_assign, baselines.token_hash_assign):
            outs.append(
                moe_mod.moe_layer(
                    params, x, cfg,
                    dispatch=dispatch,
                    layout_tables=layout.device_tables(),
                    slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
                    num_instances=2, scheduler=sched, capacity=64,
                )
            )
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]), atol=1e-5, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]), atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("dispatch", ["einsum", "grouped"])
def test_capacity_drops_tokens(dispatch):
    """cap=1 with a hot expert: overflow items contribute nothing."""
    T, k, E, d, f = 8, 1, 2, 16, 32
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(keys[0], (T, d), jnp.float32)
    ids = jnp.zeros((T, 1), jnp.int32)  # all tokens → expert 0
    gates = jnp.ones((T, 1), jnp.float32)
    w = {
        "w_gate": jax.random.normal(keys[1], (E, d, f)) * 0.1,
        "w_up": jax.random.normal(keys[2], (E, d, f)) * 0.1,
        "w_down": jax.random.normal(keys[3], (E, f, d)) * 0.1,
    }
    fn = moe_mod.DISPATCH_FNS[dispatch]
    y = fn(x, ids, gates, E, 1, w)
    assert np.abs(np.asarray(y[0])).max() > 0  # first token served
    assert np.abs(np.asarray(y[1:])).max() == 0  # the rest dropped


def test_load_balance_loss_uniform_is_one():
    probs = jnp.full((64, 8), 1 / 8)
    eids = jnp.tile(jnp.arange(8), 8).reshape(64, 1)[:, :1]
    # uniform routing: loss ≈ E · Σ (1/E · 1/E) · E = 1
    loss = moe_mod.load_balance_loss(probs, eids, 8)
    assert 0.9 < float(loss) < 1.1
