"""MoE dispatch-path equivalence + scheduling-transparency properties."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.models import moe as moe_mod


@st.composite
def dispatch_case(draw):
    T = draw(st.integers(1, 48))
    k = draw(st.integers(1, 4))
    E = draw(st.integers(k, 16))
    d = draw(st.sampled_from([32, 64]))
    f = draw(st.sampled_from([64, 128]))
    cap = draw(st.integers(1, T * k))
    seed = draw(st.integers(0, 999))
    return T, k, E, d, f, cap, seed


@given(dispatch_case())
@settings(max_examples=25, deadline=None)
def test_einsum_scatter_equivalence(case):
    """The two dispatch implementations are semantically identical, including
    capacity-overflow dropping."""
    T, k, E, d, f, cap, seed = case
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(keys[0], (T, d), jnp.float32)
    ids = jax.random.randint(keys[1], (T, k), 0, E)
    gates = jax.nn.softmax(jax.random.normal(keys[2], (T, k), jnp.float32))
    w = {
        "w_gate": jax.random.normal(keys[3], (E, d, f), jnp.float32) * 0.05,
        "w_up": jax.random.normal(keys[4], (E, d, f), jnp.float32) * 0.05,
        "w_down": jax.random.normal(keys[5], (E, f, d), jnp.float32) * 0.05,
    }
    y1 = moe_mod.capacity_dispatch_ffn(x, ids, gates, E, cap, w)
    y2 = moe_mod.scatter_dispatch_ffn(x, ids, gates, E, cap, w)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5, rtol=1e-4)


def test_scheduling_is_numerically_transparent():
    """Rewriting logical experts to replica slots must not change the layer's
    output (replicas are exact copies): the Janus scheduled path equals the
    plain logical path when capacity is ample."""
    cfg = get_config("qwen2-moe-a2.7b-reduced")
    key = jax.random.PRNGKey(0)
    params = moe_mod.init_moe(cfg, key, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32) * 0.3

    y_plain = moe_mod.moe_layer(params, x, cfg, capacity=64)

    layout = ReplicaLayout.round_robin(cfg.num_experts, num_instances=2, capacity=3)
    y_sched = moe_mod.moe_layer(
        params,
        x,
        cfg,
        layout_tables=layout.device_tables(),
        slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
        num_instances=2,
        scheduler=aebs_assign,
        capacity=64,
    )
    np.testing.assert_allclose(np.asarray(y_plain), np.asarray(y_sched), atol=1e-5, rtol=1e-4)


def test_scheduler_choice_transparent():
    """AEBS vs token-hash vs random: same numbers, different placement."""
    from repro.core import baselines

    cfg = get_config("qwen2-moe-a2.7b-reduced")
    params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 12, cfg.d_model), jnp.float32) * 0.3
    layout = ReplicaLayout.round_robin(cfg.num_experts, num_instances=2, capacity=4)
    outs = []
    for sched in (aebs_assign, baselines.random_assign, baselines.token_hash_assign):
        outs.append(
            moe_mod.moe_layer(
                params, x, cfg,
                layout_tables=layout.device_tables(),
                slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
                num_instances=2, scheduler=sched, capacity=64,
            )
        )
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[1]), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(outs[2]), atol=1e-5, rtol=1e-4)


def test_capacity_drops_tokens():
    """cap=1 with a hot expert: overflow items contribute nothing."""
    T, k, E, d, f = 8, 1, 2, 16, 32
    keys = jax.random.split(jax.random.PRNGKey(3), 4)
    x = jax.random.normal(keys[0], (T, d), jnp.float32)
    ids = jnp.zeros((T, 1), jnp.int32)  # all tokens → expert 0
    gates = jnp.ones((T, 1), jnp.float32)
    w = {
        "w_gate": jax.random.normal(keys[1], (E, d, f)) * 0.1,
        "w_up": jax.random.normal(keys[2], (E, d, f)) * 0.1,
        "w_down": jax.random.normal(keys[3], (E, f, d)) * 0.1,
    }
    y = moe_mod.capacity_dispatch_ffn(x, ids, gates, E, 1, w)
    assert np.abs(np.asarray(y[0])).max() > 0  # first token served
    assert np.abs(np.asarray(y[1:])).max() == 0  # the rest dropped


def test_load_balance_loss_uniform_is_one():
    probs = jnp.full((64, 8), 1 / 8)
    eids = jnp.tile(jnp.arange(8), 8).reshape(64, 1)[:, :1]
    # uniform routing: loss ≈ E · Σ (1/E · 1/E) · E = 1
    loss = moe_mod.load_balance_loss(probs, eids, 8)
    assert 0.9 < float(loss) < 1.1
