"""Trace-replay harness: arrival-generator properties, the multi-tenant
TraceSpec workload file, Request SLO accounting, metrics() aggregation over
mixed terminal states, and the simulator/engine workload-drift pin.

Property tests import through the optional-hypothesis shim (tests/_hypo.py)
so the module collects cleanly when hypothesis is absent."""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))
from _hypo import given, settings, st

from repro.serving.request import (
    Request,
    WorkloadSpec,
    expected_tokens_per_request,
    sample_lengths,
    sample_requests,
)
from repro.serving.trace import (
    CLASS_PRESETS,
    TenantSpec,
    TraceSpec,
    arrivals_from_profile,
    bursty_arrivals,
    diurnal_rate_profile,
    poisson_arrivals,
)


# ---------------------------------------------------------------------------
# arrival-generator properties (satellite: hypothesis property tests)
# ---------------------------------------------------------------------------


def _check_arrivals(arr, duration):
    assert np.all(np.diff(arr) >= 0), "arrivals must be sorted"
    if len(arr):
        assert arr[0] >= 0.0 and arr[-1] < duration + 1e-9


@settings(max_examples=25, deadline=None)
@given(
    rate=st.floats(min_value=0.5, max_value=200.0),
    duration=st.floats(min_value=1.0, max_value=50.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_poisson_arrivals_properties(rate, duration, seed):
    a = poisson_arrivals(rate, duration, seed=seed)
    b = poisson_arrivals(rate, duration, seed=seed)
    np.testing.assert_array_equal(a, b)  # seed-deterministic
    _check_arrivals(a, duration)


@settings(max_examples=25, deadline=None)
@given(
    rate=st.floats(min_value=0.5, max_value=100.0),
    burstiness=st.floats(min_value=0.5, max_value=8.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_bursty_arrivals_properties(rate, burstiness, seed):
    duration = 40.0
    a = bursty_arrivals(rate, duration, burstiness=burstiness, epoch=5.0, seed=seed)
    b = bursty_arrivals(rate, duration, burstiness=burstiness, epoch=5.0, seed=seed)
    np.testing.assert_array_equal(a, b)
    assert np.all(np.diff(a) >= 0) and (len(a) == 0 or a[0] >= 0.0)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_arrivals_from_profile_properties(seed):
    t, rates = diurnal_rate_profile(hours=2.0, step_minutes=10.0, mean_rate=5.0,
                                    seed=seed)
    a = arrivals_from_profile(t, rates, seed=seed)
    b = arrivals_from_profile(t, rates, seed=seed)
    np.testing.assert_array_equal(a, b)
    _check_arrivals(a, t[-1] + (t[1] - t[0]))


def test_poisson_mean_rate_within_tolerance():
    # law of large numbers at a fixed seed: a long window lands within a few
    # percent of the requested rate
    rate, duration = 50.0, 400.0
    arr = poisson_arrivals(rate, duration, seed=3)
    assert len(arr) / duration == pytest.approx(rate, rel=0.05)


def test_bursty_mean_rate_within_tolerance():
    rate, duration = 30.0, 1000.0
    arr = bursty_arrivals(rate, duration, burstiness=2.0, epoch=10.0, seed=3)
    arr = arr[arr < duration]
    assert len(arr) / duration == pytest.approx(rate, rel=0.15)


def test_diurnal_profile_period_compression():
    # period_hours compresses a full sinusoidal day into a short trace: the
    # profile must actually sweep trough → peak (non-constant) and average
    # to the requested mean
    t, rates = diurnal_rate_profile(hours=0.1, step_minutes=0.0625,
                                    mean_rate=20.0, n_bursts=0, seed=0,
                                    period_hours=0.1)
    assert rates.mean() == pytest.approx(20.0, rel=1e-6)
    assert rates.max() / rates.min() > 2.0  # full diurnal swing, not a slice


# ---------------------------------------------------------------------------
# TraceSpec: the workload file
# ---------------------------------------------------------------------------


def _two_tenant_spec():
    return TraceSpec(
        duration=5.0,
        seed=3,
        tenants=[
            TenantSpec(name="chat", klass="chat", rate=4.0, arrival="bursty",
                       priority=5, ttft_slo=0.05, tpot_slo=0.01, deadline=2.0),
            TenantSpec(name="batch", klass="batch-offline", rate=2.0,
                       arrival="poisson", priority=0,
                       workload=dict(mean_output=32.0, max_output=64)),
        ],
    )


def test_trace_spec_json_round_trip():
    spec = _two_tenant_spec()
    back = TraceSpec.from_json(spec.to_json())
    assert back == spec


def test_trace_spec_build_deterministic_and_stamped():
    spec = _two_tenant_spec()
    a = spec.build(vocab_size=1000)
    b = spec.build(vocab_size=1000)
    assert [(r.rid, r.arrival, r.input_len, r.output_len, r.tenant) for r in a] == [
        (r.rid, r.arrival, r.input_len, r.output_len, r.tenant) for r in b
    ]
    assert [r.rid for r in a] == list(range(len(a)))  # global rid reassignment
    assert all(a[i].arrival <= a[i + 1].arrival for i in range(len(a) - 1))
    chat = [r for r in a if r.tenant == "chat"]
    batch = [r for r in a if r.tenant == "batch"]
    assert chat and batch
    assert all(r.priority == 5 and r.ttft_slo == 0.05 and r.tpot_slo == 0.01
               for r in chat)
    assert all(r.deadline == pytest.approx(r.arrival + 2.0) for r in chat)
    assert all(r.priority == 0 and r.ttft_slo is None and r.deadline is None
               for r in batch)
    # workload overrides win over the class preset
    assert max(r.output_len for r in batch) <= 64


def test_trace_spec_diurnal_tenant_and_validation():
    spec = TraceSpec(duration=10.0, seed=1, tenants=[
        TenantSpec(name="d", klass="long-context", rate=3.0, arrival="diurnal",
                   workload=dict(max_input=64, mean_input=16.0)),
    ])
    reqs = spec.build(vocab_size=500)
    assert reqs and all(r.arrival < 10.0 for r in reqs)
    assert all(r.klass == "long-context" for r in reqs)
    with pytest.raises(ValueError, match="unknown request class"):
        TenantSpec(name="x", klass="nope").workload_spec(100, 0)
    with pytest.raises(ValueError, match="unknown arrival process"):
        TenantSpec(name="x", arrival="nope").arrivals(1.0, 0)


def test_class_presets_cover_the_three_request_classes():
    assert set(CLASS_PRESETS) == {"chat", "long-context", "batch-offline"}


# ---------------------------------------------------------------------------
# Request.tpot_p edge cases + slo_ok (satellite: coverage)
# ---------------------------------------------------------------------------


def _req(**kw):
    base = dict(rid=0, arrival=0.0, input_len=4, output_len=8, token_times=[])
    base.update(kw)
    return Request(**base)


def test_tpot_p_edge_cases():
    assert _req(token_times=None).tpot_p(99.0) == 0.0
    assert _req(token_times=[]).tpot_p(99.0) == 0.0
    assert _req(token_times=[0.5]).tpot_p(99.0) == 0.0  # one stamp: no gap
    r = _req(token_times=[0.0, 0.1, 0.3])
    assert r.tpot_p(0.0) == pytest.approx(0.1)  # min gap
    assert r.tpot_p(100.0) == pytest.approx(0.2)  # max gap
    assert 0.1 <= r.tpot_p(50.0) <= 0.2


def test_tpot_wait_split_excludes_preemption_spans():
    """decode_gaps subtracts an off-batch preemption wait from exactly the
    gap it interrupted: a preempted request's TPOT percentiles measure decode
    latency, not scheduling, and slo_ok composes with preemption."""
    # tokens at 0.0, 0.1, then spilled [0.1, 0.5), restored, token at 0.6
    r = _req(token_times=[0.0, 0.1, 0.6], wait_spans=[(0.1, 0.5)])
    gaps = r.decode_gaps()
    assert gaps == pytest.approx([0.1, 0.1])  # 0.5 raw gap minus 0.4 wait
    assert r.tpot_p(100.0) == pytest.approx(0.1)
    # the same request without the span annotation blows its TPOT SLO …
    blown = _req(token_times=[0.0, 0.1, 0.6], tpot_slo=0.2, prefill_done=0.0)
    assert blown.slo_ok() is False
    # … and meets it once the wait is split out
    split = _req(token_times=[0.0, 0.1, 0.6], wait_spans=[(0.1, 0.5)],
                 tpot_slo=0.2, prefill_done=0.0)
    assert split.slo_ok() is True
    # a wait longer than its containing gap clamps to zero, never negative
    clamped = _req(token_times=[0.0, 0.3], wait_spans=[(0.0, 0.4)])
    assert clamped.decode_gaps() == pytest.approx([0.0])
    # spans outside the decode window are ignored
    outside = _req(token_times=[1.0, 1.2], wait_spans=[(0.0, 0.5)])
    assert outside.decode_gaps() == pytest.approx([0.2])


def test_slo_ok_cases():
    assert _req().slo_ok() is None  # no SLO → not measured
    r = _req(ttft_slo=0.1)
    assert r.slo_ok() is False  # never served
    r.rejected = True
    assert r.slo_ok() is False
    ok = _req(ttft_slo=0.1, prefill_done=0.05, token_times=[0.05])
    assert ok.ttft() == pytest.approx(0.05) and ok.slo_ok() is True
    late = _req(ttft_slo=0.1, prefill_done=0.2)
    assert late.slo_ok() is False
    slow = _req(tpot_slo=0.01, prefill_done=0.0, token_times=[0.0, 0.5])
    assert slow.slo_ok() is False


# ---------------------------------------------------------------------------
# metrics() aggregation over rejected/truncated/preempted mixes
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_engine():
    from repro.configs import get_config
    from repro.models import model as model_mod
    from repro.serving.engine import ServingEngine

    cfg = get_config("phi4-mini-3.8b-reduced")
    return ServingEngine(cfg, model_mod.init_params(cfg, 0), max_batch=2,
                         cache_len=64, scheduler="none",
                         step_time_fn=lambda n: 1e-3)


def test_metrics_aggregation_mixed_terminal_states(tiny_engine):
    eng = tiny_engine
    eng.completed = [
        _req(rid=0, generated=4, finished=1.0, prefill_done=0.1,
             token_times=[0.1, 0.2, 0.3, 0.4, 0.5], ttft_slo=0.5,
             tenant="chat", preemptions=1),
        _req(rid=1, generated=2, finished=1.2, prefill_done=0.9,
             token_times=[0.9, 1.0, 1.2], ttft_slo=0.5, tenant="chat",
             truncated=True),
    ]
    rej = _req(rid=2, ttft_slo=0.5, tenant="batch")
    rej.rejected = True
    eng.rejected = [rej]
    eng.preempt_count, eng.restore_count = 2, 1
    try:
        m = eng.metrics()
        assert m["completed"] == 2 and m["tokens"] == 6
        assert m["truncated"] == 1 and m["rejected"] == 1
        assert m["preemptions"] == 2 and m["restores"] == 1
        # SLO aggregation counts the rejected request as a measured miss
        assert m["slo"]["measured"] == 3 and m["slo"]["attained"] == 1
        assert m["slo"]["attainment"] == pytest.approx(1 / 3)
        assert m["slo"]["per_tenant"] == {"batch": 0.0, "chat": 0.5}
        assert m["ttft_mean"] == pytest.approx((0.1 + 0.9) / 2)
        assert m["throughput_tok_s"] > 0
    finally:
        eng.completed, eng.rejected = [], []
        eng.preempt_count = eng.restore_count = 0


def test_metrics_no_slo_requests_has_no_slo_block(tiny_engine):
    eng = tiny_engine
    eng.completed = [_req(rid=0, generated=1, finished=0.2, prefill_done=0.1,
                          token_times=[0.1, 0.2])]
    try:
        m = eng.metrics()
        assert "slo" not in m
        assert m["preemptions"] == 0 and m["restores"] == 0
    finally:
        eng.completed = []


# ---------------------------------------------------------------------------
# simulator/engine workload drift (satellite: shared WorkloadSpec path)
# ---------------------------------------------------------------------------


def test_sample_requests_lengths_come_from_shared_sampler():
    spec = WorkloadSpec(mean_input=12.0, mean_output=40.0, max_input=64,
                        max_output=128, seed=11)
    arrivals = np.linspace(0, 1.0, 200)
    reqs = sample_requests(spec, arrivals)
    rng = np.random.default_rng(spec.seed)
    ins, outs = sample_lengths(spec, len(arrivals), rng)
    # the exact pin: sample_requests draws through sample_lengths, so the
    # request lengths equal a direct call with the same fresh rng
    np.testing.assert_array_equal([r.input_len for r in reqs], ins)
    np.testing.assert_array_equal([r.output_len for r in reqs], outs)


def test_expected_tokens_matches_engine_workload():
    spec = WorkloadSpec(mean_input=12.0, mean_output=40.0, max_input=64,
                        max_output=128, seed=11)
    tpr = expected_tokens_per_request(spec)
    reqs = sample_requests(spec, np.linspace(0, 1.0, 3000))
    empirical = np.mean([r.output_len for r in reqs])
    # same sampler, same clipping → the analytic scalar tracks what the
    # engine actually serves (distribution-level agreement)
    assert tpr == pytest.approx(empirical, rel=0.1)


def test_simulator_spec_path_equals_measured_scalar():
    from repro.serving.simulator import ClusterSimulator

    class _FlatModel:
        # minimal PerfModel stand-in: the demand path is what's under test
        class cfg:
            has_moe = False
            num_experts = 0

        def tpot(self, batch, n_a, n_e, scheme="2pc"):
            raise AssertionError("not exercised")

    spec = WorkloadSpec(mean_output=40.0, seed=11)
    sim = ClusterSimulator.__new__(ClusterSimulator)
    tpr = sim._tokens_per_req(None, spec)
    assert tpr == expected_tokens_per_request(spec)
    assert sim._tokens_per_req(256.0, None) == 256.0
    with pytest.raises(ValueError, match="tokens_per_req or a WorkloadSpec"):
        sim._tokens_per_req(None, None)


def test_window_demand_bins_actual_lengths():
    from repro.serving.simulator import ClusterSimulator

    reqs = [
        _req(rid=0, arrival=0.5, output_len=10),
        _req(rid=1, arrival=1.5, output_len=20),
        _req(rid=2, arrival=1.9, output_len=30),
    ]
    starts, lam = ClusterSimulator.window_demand(reqs, window_s=1.0)
    np.testing.assert_allclose(starts, [0.0, 1.0])
    np.testing.assert_allclose(lam, [10.0, 50.0])


@pytest.mark.slow
def test_simulator_replays_10k_requests():
    """The acceptance-gate scale check: ≥10k requests built from one
    TraceSpec replay through every scaling policy."""
    from repro.core.amax import MonteCarloAmax, make_routing_trace
    from repro.core.scaling import PerfModel
    from repro.configs import get_config
    from repro.serving.simulator import ClusterSimulator

    spec = TraceSpec(duration=100.0, seed=2, tenants=[
        TenantSpec(name="chat", klass="chat", rate=100.0, arrival="bursty",
                   burstiness=3.0),
        TenantSpec(name="batch", klass="batch-offline", rate=40.0,
                   workload=dict(mean_output=48.0, max_output=128)),
    ])
    reqs = spec.build(with_prompts=False)
    assert len(reqs) >= 10_000
    cfg = get_config("dsv2-lite")
    trace = make_routing_trace(1024, cfg.num_experts, cfg.top_k, skew=0.8, seed=0)
    pm = PerfModel(cfg, amax_estimator=MonteCarloAmax(trace, cfg.num_experts,
                                                      trials=4),
                   slots_per_instance=12, s_ctx=512)
    sim = ClusterSimulator(pm, slo=0.2, n_max=8)
    results = sim.replay(reqs, window_s=10.0)
    assert set(results) == {"janus", "sglang", "megascale", "xdeepserve"}
    n = len(results["janus"].records)
    assert n == 10 and all(len(r.records) == n for r in results.values())
    for res in results.values():
        assert 0.0 <= res.slo_attainment <= 1.0
        assert res.slo_per_device <= res.slo_attainment / max(res.mean_gpus, 1)
        + 1e-9
