"""Fault injection + recovery: the headline robustness claim is that you can
kill any pool mid-run and the surviving system emits bit-identical token
streams.

Layer 1 (unit, no model): plan construction/serialisation, retry policy,
watchdog semantics, runtime state machine, slot-lifecycle detour.

Layer 2 (engine, dsv2-lite-reduced on degenerate single-host pools — the
established in-process idiom from test_disagg): seeded device-loss plans in
each pool type, transient retry/backoff under a fake (modeled) clock,
degrade-to-mono last resorts, admission deadlines + backpressure, and the
controller seeing lost capacity.
"""

import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.core.placement import layout_for_survivors
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.faults import (
    DEVICE_LOSS,
    EXCHANGE_DELAY,
    EXCHANGE_TIMEOUT,
    PREFILL_CHUNK_FAIL,
    FaultPlan,
    FaultRuntime,
    FaultSpec,
    PoolFault,
    RetryPolicy,
    Watchdog,
)
from repro.serving.request import Request, WorkloadSpec, sample_requests


# ---------------------------------------------------------------------------
# Layer 1: units
# ---------------------------------------------------------------------------


def test_fault_spec_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor_strike")
    with pytest.raises(ValueError, match="unknown pool"):
        FaultSpec(DEVICE_LOSS, pool="gpu")
    with pytest.raises(ValueError, match="permanent by definition"):
        FaultSpec(DEVICE_LOSS, pool="attn", transient=True)


def test_fault_plan_seeded_and_json_round_trip():
    a = FaultPlan.random(seed=7, n_faults=4, max_step=20)
    b = FaultPlan.random(seed=7, n_faults=4, max_step=20)
    c = FaultPlan.random(seed=8, n_faults=4, max_step=20)
    assert a.faults == b.faults  # same seed → same schedule, always
    assert a.faults != c.faults
    back = FaultPlan.from_json(a.to_json())
    assert back.faults == a.faults and back.seed == a.seed
    # a bare JSON list of specs is accepted too (hand-written plans)
    bare = FaultPlan.from_json(json.dumps([{"kind": DEVICE_LOSS, "pool": "moe"}]))
    assert bare.faults == [FaultSpec(DEVICE_LOSS, pool="moe")]


def test_retry_policy_exponential_backoff():
    pol = RetryPolicy(base_delay_s=0.1, factor=3.0, max_retries=4)
    assert pol.delay(1) == pytest.approx(0.1)
    assert pol.delay(2) == pytest.approx(0.3)
    assert pol.delay(3) == pytest.approx(0.9)


def test_runtime_transient_exchange_heals_after_fail_count():
    plan = FaultPlan(faults=[FaultSpec(EXCHANGE_TIMEOUT, at_step=2,
                                       transient=True, fail_count=2)])
    rt = FaultRuntime(plan)
    rt.advance_to_step(1)
    rt.exchange_hook("exchange", 0, 0)  # not fired yet: no-op
    rt.advance_to_step(2)
    for _ in range(2):
        with pytest.raises(PoolFault) as ei:
            rt.exchange_hook("exchange", 0, 0)
        assert ei.value.transient and ei.value.kind == EXCHANGE_TIMEOUT
    rt.exchange_hook("exchange", 3, 1)  # healed after fail_count hits
    assert rt.stats.injected == 1 and rt.stats.detected == 2


def test_runtime_watchdog_delay_vs_timeout():
    wd = Watchdog(exchange_deadline_s=0.5)
    # sub-deadline delay: charged as latency, not a fault
    rt = FaultRuntime(FaultPlan(faults=[FaultSpec(EXCHANGE_DELAY, at_step=0,
                                                  delay_s=0.2)]), watchdog=wd)
    rt.advance_to_step(0)
    rt.exchange_hook("exchange", 0, 0)
    assert rt.consume_delay() == pytest.approx(0.2)
    assert rt.stats.detected == 0
    # at/above the deadline: the transfer is cancelled at the deadline and
    # surfaced as a transient timeout — the charge is the deadline, not 30s
    rt = FaultRuntime(FaultPlan(faults=[FaultSpec(EXCHANGE_DELAY, at_step=0,
                                                  delay_s=30.0)]), watchdog=wd)
    rt.advance_to_step(0)
    with pytest.raises(PoolFault) as ei:
        rt.exchange_hook("exchange", 0, 0)
    assert ei.value.transient and ei.value.kind == EXCHANGE_TIMEOUT
    assert rt.consume_delay() == pytest.approx(0.5)


def test_runtime_health_poll_and_out_of_range_loss():
    plan = FaultPlan(faults=[FaultSpec(DEVICE_LOSS, pool="moe", index=3, at_step=0),
                             FaultSpec(DEVICE_LOSS, pool="attn", index=0, at_step=0)])
    rt = FaultRuntime(plan)
    rt.advance_to_step(0)
    f = rt.poll_health({"attn": 2, "moe": 2, "prefill": 0})
    # the moe loss targets index 3 of a 2-device pool: marked handled, the
    # attn loss is the one detected
    assert f is not None and (f.pool, f.index) == ("attn", 0)
    rt.mark_handled(f)
    assert rt.poll_health({"attn": 2, "moe": 2, "prefill": 0}) is None
    assert rt.stats.detected == 1


def test_layout_for_survivors_seats_every_expert():
    lay = layout_for_survivors(8, 3)
    seated = set(lay.slot_to_expert.reshape(-1)[lay.slot_to_expert.reshape(-1) >= 0])
    assert seated == set(range(8)) and lay.num_instances == 3
    with pytest.raises(ValueError, match="degrade to mono"):
        layout_for_survivors(8, 0)


# ---------------------------------------------------------------------------
# Layer 2: engine end-to-end (dsv2-lite, degenerate in-process pools)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dsv2():
    cfg = get_config("dsv2-lite-reduced")
    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
    return cfg, params, layout


def _reqs(cfg, n=5):
    spec = WorkloadSpec(mean_input=6, mean_output=24, vocab_size=cfg.vocab_size,
                        max_input=16, max_output=32, seed=3)
    # packed arrivals: the batch must be full when the fault lands, so the
    # recovery paths (replay / requeue) actually carry live state
    return sample_requests(spec, np.linspace(0, 0.005, n), with_prompts=True)


def _engine(cfg, params, layout, plan=None, n_attn=2, **kw):
    return ServingEngine(
        cfg, params, max_batch=4, cache_len=64, layout=layout,
        scheduler="aebs", capacity_tokens=64,
        executor="disagg", n_attn=n_attn, n_prefill=1, prefill_chunk=4,
        step_time_fn=lambda n: 2e-3,  # fake clock: deterministic timing
        fault_plan=plan, retry_policy=RetryPolicy(recovery_charge_s=0.01),
        **kw,
    )


@pytest.fixture(scope="module")
def fault_free_streams(dsv2):
    cfg, params, layout = dsv2
    eng = _engine(cfg, params, layout)
    m = eng.run(_reqs(cfg), max_steps=2000)
    assert m["completed"] == 5
    return {r.rid: list(r.tokens_out) for r in eng.completed}


@pytest.mark.parametrize(
    "name,spec,check",
    [
        ("attn", FaultSpec(DEVICE_LOSS, pool="attn", index=1, at_step=6),
         lambda f: f["replayed_slots"] >= 1),
        ("moe", FaultSpec(DEVICE_LOSS, pool="moe", index=0, at_step=6),
         lambda f: f["recoveries"] == 1),
        ("prefill", FaultSpec(DEVICE_LOSS, pool="prefill", index=0, at_step=2),
         lambda f: f["requeued"] >= 1),
    ],
)
def test_device_loss_streams_bit_identical(dsv2, fault_free_streams, name, spec, check):
    """Kill one device in each pool type mid-run: the engine detects it on
    the next heartbeat, recovers (re-plan / deterministic replay / requeue),
    and the final token streams are bit-identical to the fault-free run."""
    cfg, params, layout = dsv2
    eng = _engine(cfg, params, layout, plan=FaultPlan(faults=[spec]))
    m = eng.run(_reqs(cfg), max_steps=2000)
    got = {r.rid: list(r.tokens_out) for r in eng.completed}
    assert got == fault_free_streams, f"{name}-pool loss diverged the streams"
    f = m["faults"]
    assert f["injected"] == 1 and f["detected"] == 1 and f["recoveries"] == 1
    assert f["degraded"] == 0 and check(f)
    assert f["recovery_latency_max_s"] > 0
    if name == "moe":
        # recovery re-planned placement onto the single survivor
        assert len(eng.disagg.pools.moe_devices) == 1
        assert eng.layout.num_instances == 1
    if name == "attn":
        assert len(eng.disagg.pools.attn_devices) == 1


def test_attn_loss_under_speculation_streams_bit_identical(dsv2, fault_free_streams):
    """Kill an attention device mid-run with speculation on: deterministic
    replay rebuilds the lost shard's KV from the accepted token history
    (draft state rebuilds the same way), and the streams stay bit-identical
    to the fault-free *non-speculative* run — speculation and recovery
    compose without touching the output."""
    cfg, params, layout = dsv2
    spec = FaultSpec(DEVICE_LOSS, pool="attn", index=1, at_step=3)
    eng = _engine(cfg, params, layout, plan=FaultPlan(faults=[spec]),
                  draft_config=cfg, spec_k=2)
    m = eng.run(_reqs(cfg), max_steps=2000)
    got = {r.rid: list(r.tokens_out) for r in eng.completed}
    assert got == fault_free_streams
    f = m["faults"]
    assert f["injected"] == 1 and f["recoveries"] == 1 and f["degraded"] == 0
    assert m["spec"]["accepted_per_step"] > 1.0  # kept speculating after recovery


def test_transient_exchange_retry_backoff_fake_clock(dsv2, fault_free_streams):
    """A transient exchange timeout retries the idempotent decode step under
    exponential backoff; with a modeled clock the charged stall is exactly
    the policy's delays (0.05 + 0.1), bit-for-bit reproducible."""
    cfg, params, layout = dsv2
    plan = FaultPlan(faults=[FaultSpec(EXCHANGE_TIMEOUT, at_step=4,
                                       transient=True, fail_count=2)])
    eng = _engine(cfg, params, layout, plan=plan)
    m = eng.run(_reqs(cfg), max_steps=2000)
    got = {r.rid: list(r.tokens_out) for r in eng.completed}
    assert got == fault_free_streams
    f = m["faults"]
    assert f["retries"] == 2 and f["recoveries"] == 0 and f["degraded"] == 0
    assert f["fault_stall_s"] == pytest.approx(0.05 + 0.10)


def test_degrade_to_mono_last_resorts(dsv2, fault_free_streams):
    """Last-resort ladder: losing the only attention device degrades to the
    mono executor and rebuilds *every* slot by replay; a never-healing
    exchange fault exhausts the retry budget and degrades too.  Both keep
    the streams bit-identical."""
    cfg, params, layout = dsv2
    # lost the last attention device → degrade + full replay
    plan = FaultPlan(faults=[FaultSpec(DEVICE_LOSS, pool="attn", index=0, at_step=5)])
    eng = _engine(cfg, params, layout, plan=plan, n_attn=1)
    m = eng.run(_reqs(cfg), max_steps=2000)
    assert eng.disagg is None and eng.executor_name == "mono"
    assert {r.rid: list(r.tokens_out) for r in eng.completed} == fault_free_streams
    f = m["faults"]
    assert f["degraded"] == 1 and f["replayed_slots"] >= 1
    assert "attention" in m["degraded_reason"]

    # retry budget exhausted on a persistent "transient" fault → degrade
    plan = FaultPlan(faults=[FaultSpec(EXCHANGE_TIMEOUT, at_step=5,
                                       transient=True, fail_count=99)])
    eng = _engine(cfg, params, layout, plan=plan)
    m = eng.run(_reqs(cfg), max_steps=2000)
    assert eng.disagg is None
    assert {r.rid: list(r.tokens_out) for r in eng.completed} == fault_free_streams
    assert m["faults"]["degraded"] == 1
    assert m["faults"]["retries"] == eng.faults.policy.max_retries + 1


def test_controller_sees_lost_capacity(dsv2):
    """The AutoScaler subscribes to engine fault events: a permanent device
    loss shrinks the bounds its next decision may propose."""
    from repro.core.scaling import PerfModel
    from repro.serving.controller import AutoScaler

    cfg, params, layout = dsv2
    ctrl = AutoScaler(PerfModel(cfg, slots_per_instance=3, s_ctx=64), slo=0.2,
                      n_max=4, n_prefill_max=2)
    plan = FaultPlan(faults=[FaultSpec(DEVICE_LOSS, pool="moe", index=0, at_step=6)])
    eng = _engine(cfg, params, layout, plan=plan)
    ctrl.attach(eng)
    eng.run(_reqs(cfg), max_steps=2000)
    assert ctrl.scaler.n_max == 3  # decode capacity shrank
    assert ctrl.device_losses and ctrl.device_losses[0][1] == "moe"
    # prefill losses shrink the prefill bound instead
    ctrl.on_device_loss("prefill", now=1.0)
    assert ctrl.n_prefill_max == 1


def test_reconfigure_validates_pool_sizes(dsv2):
    """Satellite: reconfigure rejects impossible pool sizes with an error
    naming the offending pool, before any executor state mutates."""
    cfg, params, layout = dsv2
    eng = _engine(cfg, params, layout)
    with pytest.raises(ValueError, match="attention pool"):
        eng.reconfigure(n_attn=0)
    with pytest.raises(ValueError, match="MoE pool"):
        eng.reconfigure(n_moe=0)
    with pytest.raises(ValueError, match="prefill pool"):
        eng.reconfigure(n_prefill=-1)
    # exceeds-available check (skipped for degenerate aliased test pools —
    # exercise the real-device path by pinning the universe)
    ex = eng.disagg
    ex._aliased = False
    ex._all_devices = list(ex.pools.attn_devices[:1])
    with pytest.raises(ValueError, match="exceed"):
        eng.reconfigure(n_attn=5)
    # a failed validation left the pools untouched
    assert len(ex.pools.attn_devices) == 2


def test_admission_deadline_rejection(dsv2):
    """A request whose deadline lapses while the engine is saturated is
    rejected without ever holding a slot, and counted in metrics."""
    cfg, params, layout = dsv2
    eng = ServingEngine(
        cfg, params, max_batch=1, cache_len=64, layout=layout,
        scheduler="aebs", capacity_tokens=64,
        step_time_fn=lambda n: 1.0,
    )
    spec = WorkloadSpec(mean_input=4, mean_output=8, vocab_size=cfg.vocab_size,
                        max_input=8, max_output=8, seed=0)
    reqs = sample_requests(spec, [0.0, 0.1], with_prompts=True)
    reqs[1].deadline = 2.0  # the single slot stays busy for ~8 modeled seconds
    m = eng.run(reqs, max_steps=200)
    assert m["completed"] == 1 and m["rejected"] == 1
    assert reqs[1].rejected and reqs[1].slot == -1
    assert eng.rejected == [reqs[1]]


def test_admission_backpressure_bounds_prefill_queue(dsv2):
    """max_prefill_queue caps how many prompts may wait in the prefill
    queue; admission defers instead of flooding, and everything still
    completes."""
    cfg, params, layout = dsv2
    with pytest.raises(ValueError, match="max_prefill_queue"):
        ServingEngine(cfg, params, max_batch=4, cache_len=64, layout=layout,
                      scheduler="aebs", capacity_tokens=64, max_prefill_queue=0)
    eng = ServingEngine(
        cfg, params, max_batch=4, cache_len=64, layout=layout,
        scheduler="aebs", capacity_tokens=64,
        admission="pipelined", prefill_chunk=4,
        step_time_fn=lambda n: 2e-3, max_prefill_queue=1,
    )
    pending_at_submit = []
    orig = eng.prefill_worker.submit

    def spy(req, slot, now, **kw):
        pending_at_submit.append(eng.prefill_worker.num_pending)
        return orig(req, slot, now=now, **kw)

    eng.prefill_worker.submit = spy
    m = eng.run(_reqs(cfg, n=4), max_steps=2000)
    assert m["completed"] == 4 and m["rejected"] == 0
    assert pending_at_submit and max(pending_at_submit) == 0  # bound held


# ---------------------------------------------------------------------------
# Real multi-device recovery (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

FAULT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.faults import DEVICE_LOSS, FaultPlan, FaultSpec, RetryPolicy
from repro.serving.request import WorkloadSpec, sample_requests

assert len(jax.devices()) == 8
cfg = get_config("dsv2-lite-reduced")
params = model_mod.init_params(cfg, 0)
layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
spec = WorkloadSpec(mean_input=5, mean_output=10, vocab_size=cfg.vocab_size,
                    max_input=8, max_output=12, seed=0)

def engine(plan=None):
    return ServingEngine(cfg, params, max_batch=4, cache_len=32, layout=layout,
                         scheduler="aebs", capacity_tokens=64,
                         executor="disagg", n_attn=2, n_prefill=1,
                         prefill_chunk=3, step_time_fn=lambda n: 2e-3,
                         fault_plan=plan,
                         retry_policy=RetryPolicy(recovery_charge_s=0.01))

def reqs():
    return sample_requests(spec, np.linspace(0, 0.005, 4), with_prompts=True)

base = engine()
base.run(reqs(), max_steps=500)
ref = {r.rid: tuple(r.tokens_out) for r in base.completed}
assert len(ref) == 4

# one seeded plan killing a real, physically distinct device in every pool
plan = FaultPlan(faults=[
    FaultSpec(DEVICE_LOSS, pool="prefill", index=0, at_step=2),
    FaultSpec(DEVICE_LOSS, pool="attn", index=1, at_step=5),
    FaultSpec(DEVICE_LOSS, pool="moe", index=0, at_step=9),
], seed=0)
eng = engine(plan)
m = eng.run(reqs(), max_steps=500)
got = {r.rid: tuple(r.tokens_out) for r in eng.completed}
assert got == ref, "streams diverged after triple pool loss"
f = m["faults"]
assert f["detected"] == 3 and f["recoveries"] == 3 and f["degraded"] == 0, f
# the dead devices are physically excluded from the executor's universe
pools = eng.disagg.pools
assert len(pools.attn_devices) == 1 and len(pools.moe_devices) == 1
alive = {d.id for d in eng.disagg._all_devices}
assert len(alive) == 5  # 8 minus the 3 excluded casualties
print("FAULTS_OK", f)
"""


@pytest.mark.subprocess
def test_fault_recovery_multidevice_subprocess():
    """Real 8-device run: one plan kills a prefill, an attention and a MoE
    device at different steps; the engine recovers all three (requeue +
    replay + re-plan), the dead devices leave the physical universe, and the
    streams stay bit-identical to the fault-free baseline."""
    from tests.test_disagg import run_forced_device_subprocess

    run_forced_device_subprocess(FAULT_SCRIPT, marker="FAULTS_OK")


def test_prefill_chunk_fault_transient_requeue(dsv2, fault_free_streams):
    """A transient prefill-chunk failure retries in place (the hook fires
    before any compute); the streams still match the fault-free run."""
    cfg, params, layout = dsv2
    plan = FaultPlan(faults=[FaultSpec(PREFILL_CHUNK_FAIL, pool="prefill",
                                       at_step=2, transient=True, fail_count=2)])
    eng = _engine(cfg, params, layout, plan=plan)
    m = eng.run(_reqs(cfg), max_steps=2000)
    assert {r.rid: list(r.tokens_out) for r in eng.completed} == fault_free_streams
    f = m["faults"]
    assert f["retries"] == 2 and f["degraded"] == 0
