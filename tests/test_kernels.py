"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret mode on CPU; the kernels target TPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aebs import aebs_numpy
from repro.core.amax import make_routing_trace
from repro.core.placement import build_layout
from repro.kernels.aebs.ops import aebs_schedule
from repro.kernels.aebs.ref import aebs_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.expert_ffn.ops import expert_ffn
from repro.kernels.expert_ffn.ref import expert_ffn_ref


# ---------------------------------------------------------------------------
# AEBS kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,n_e,C,T,k", [
    (16, 4, 5, 64, 2),
    (64, 8, 12, 300, 6),   # non-multiple of block → padding path
    (60, 16, 4, 128, 4),   # qwen-like
    (256, 16, 17, 512, 8), # dsv3-scale routing
])
def test_aebs_kernel_vs_oracles(E, n_e, C, T, k):
    trace = make_routing_trace(max(T, 512), E, k, skew=0.8, seed=E)
    layout = build_layout(trace, E, n_e, C)
    eids = jnp.asarray(trace[:T])
    t = layout.device_tables()
    s_k, load_k, rep_k = aebs_schedule(eids, t, n_e, block_tokens=128)
    s_r, load_r, _ = aebs_ref(eids, t["expert_hosts"], t["replica_counts"], t["slot_of"])
    s_n, load_n, _ = aebs_numpy(np.asarray(eids), layout)
    assert np.array_equal(np.asarray(s_k), np.asarray(s_r))
    assert np.array_equal(np.asarray(load_k), np.asarray(load_r))
    assert np.array_equal(np.asarray(s_k), s_n)


def test_aebs_kernel_padding_neutral():
    """Padded items (-1) must not activate experts or affect loads."""
    E, n_e, C, k = 32, 4, 9, 4
    trace = make_routing_trace(512, E, k, skew=0.5, seed=9)
    layout = build_layout(trace, E, n_e, C)
    t = layout.device_tables()
    e1 = jnp.asarray(trace[:100])
    _, load_100, _ = aebs_schedule(e1, t, n_e, block_tokens=64)  # pads 100→128
    _, load_full, _ = aebs_schedule(jnp.asarray(trace[:128]), t, n_e, block_tokens=64)
    sub, _, _ = aebs_numpy(trace[:100], layout)
    assert np.array_equal(np.asarray(load_100), aebs_numpy(trace[:100], layout)[1])


# ---------------------------------------------------------------------------
# Expert FFN kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,CAP,d,f", [
    (4, 16, 128, 256),
    (8, 64, 256, 1024),
    (16, 8, 512, 1408),   # qwen expert dims (non-pow2 f)
    (3, 32, 256, 512),    # odd slot count
])
def test_expert_ffn_sweep(S, CAP, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S * f), 5)
    x = (jax.random.normal(ks[0], (S, CAP, d), jnp.float32) * 0.5).astype(dtype)
    wg = (jax.random.normal(ks[1], (S, d, f), jnp.float32) * 0.05).astype(dtype)
    wu = (jax.random.normal(ks[2], (S, d, f), jnp.float32) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[3], (S, f, d), jnp.float32) * 0.05).astype(dtype)
    act = jax.random.bernoulli(ks[4], 0.6, (S,)).astype(jnp.int32)
    got = expert_ffn(x, wg, wu, wd, act)
    want = expert_ffn_ref(x, wg, wu, wd, act)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )
    # inactive slots are exactly zero (no weight streaming)
    inact = np.asarray(act) == 0
    assert (np.asarray(got, np.float32)[inact] == 0).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("S,E,CAP,d,f", [
    (6, 4, 16, 128, 256),     # replica slots > experts
    (10, 3, 8, 256, 512),     # heavy replication + an empty slot
])
def test_expert_ffn_slot_indirect_sweep(S, E, CAP, d, f, dtype):
    """Slot-indirect form: logical [E, d, f] weights + flat slot→expert map
    as a scalar-prefetch operand — no stacked weight copy is ever built."""
    from repro.kernels.expert_ffn.ops import expert_ffn_grouped
    from repro.kernels.expert_ffn.ref import expert_ffn_grouped_ref

    ks = jax.random.split(jax.random.PRNGKey(S * f + 1), 5)
    x = (jax.random.normal(ks[0], (S, CAP, d), jnp.float32) * 0.5).astype(dtype)
    wg = (jax.random.normal(ks[1], (E, d, f), jnp.float32) * 0.05).astype(dtype)
    wu = (jax.random.normal(ks[2], (E, d, f), jnp.float32) * 0.05).astype(dtype)
    wd = (jax.random.normal(ks[3], (E, f, d), jnp.float32) * 0.05).astype(dtype)
    m = np.arange(S) % (E + 1)
    s2e = jnp.asarray(np.where(m == E, -1, m), jnp.int32)  # sprinkle empty slots
    act = jax.random.bernoulli(ks[4], 0.7, (S,)).astype(jnp.int32)
    got = expert_ffn_grouped(x, wg, wu, wd, s2e, act)
    want = expert_ffn_grouped_ref(x, wg, wu, wd, s2e, act)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )
    # inactive or empty slots are exactly zero
    dead = (np.asarray(act) == 0) | (np.asarray(s2e) < 0)
    assert (np.asarray(got, np.float32)[dead] == 0).all()


# ---------------------------------------------------------------------------
# Flash-decode attention kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,nh,nkv,hd,S", [
    (2, 8, 4, 64, 1024),
    (1, 16, 16, 128, 512),  # MHA
    (4, 8, 1, 64, 2048),    # MQA
    (2, 6, 6, 64, 768),     # whisper-like, non-pow2 seq
])
@pytest.mark.parametrize("frac", [0.3, 1.0])
def test_decode_attention_sweep(B, nh, nkv, hd, S, dtype, frac):
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = (jax.random.normal(ks[0], (B, nh, hd), jnp.float32)).astype(dtype)
    kc = (jax.random.normal(ks[1], (B, S, nkv, hd), jnp.float32)).astype(dtype)
    vc = (jax.random.normal(ks[2], (B, S, nkv, hd), jnp.float32)).astype(dtype)
    vl = jnp.int32(max(1, int(S * frac)))
    got = decode_attention(q, kc, vc, vl)
    want = decode_attention_ref(q, kc, vc, vl)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_decode_attention_softcap():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 8, 64), jnp.float32) * 3
    kc = jax.random.normal(ks[1], (2, 512, 4, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (2, 512, 4, 64), jnp.float32)
    got = decode_attention(q, kc, vc, jnp.int32(400), logit_cap=30.0)
    want = decode_attention_ref(q, kc, vc, jnp.int32(400), logit_cap=30.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# Kernel-as-scheduler integration: the Pallas AEBS kernel is a drop-in
# replacement for the jnp scheduler inside the scheduled MoE layer.
# ---------------------------------------------------------------------------


def test_aebs_kernel_drop_in_moe_layer():
    import jax
    from repro.configs import get_config
    from repro.core.aebs import ReplicaLayout, aebs_assign
    from repro.models import moe as moe_mod

    cfg = get_config("qwen2-moe-a2.7b-reduced")
    params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model), jnp.float32) * 0.3
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
    kw = dict(
        layout_tables=layout.device_tables(),
        slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
        num_instances=2,
        capacity=64,
    )
    y_jnp = moe_mod.moe_layer(params, x, cfg, scheduler=aebs_assign, **kw)
    y_krn = moe_mod.moe_layer(
        params, x, cfg, scheduler=lambda e, t, n: aebs_schedule(e, t, n), **kw
    )
    np.testing.assert_allclose(np.asarray(y_jnp), np.asarray(y_krn), atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# int8-KV flash-decode kernel (in-VMEM dequant — §Perf P3b)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,nh,nkv,hd,S", [
    (2, 8, 4, 64, 1024),
    (1, 16, 8, 128, 512),
    (2, 6, 6, 64, 768),
])
def test_decode_attention_int8_sweep(B, nh, nkv, hd, S):
    from repro.kernels.decode_attention.ops import decode_attention_int8
    from repro.kernels.decode_attention.ref import decode_attention_int8_ref
    from repro.models.attention import quantize_kv

    ks = jax.random.split(jax.random.PRNGKey(B * S + 1), 3)
    q = jax.random.normal(ks[0], (B, nh, hd), jnp.float32)
    kc_f = jax.random.normal(ks[1], (B, S, nkv, hd), jnp.float32)
    vc_f = jax.random.normal(ks[2], (B, S, nkv, hd), jnp.float32)
    kc, ksc = quantize_kv(kc_f)
    vc, vsc = quantize_kv(vc_f)
    vl = jnp.int32(int(0.7 * S))
    got = decode_attention_int8(q, kc, vc, ksc, vsc, vl)
    want = decode_attention_int8_ref(q, kc, vc, ksc, vsc, vl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-3)
    # and close to the unquantised full-precision result
    full = decode_attention_ref(q, kc_f, vc_f, vl)
    err = np.abs(np.asarray(got) - np.asarray(full)).max()
    assert err < 0.05
