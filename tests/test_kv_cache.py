"""Direct unit tests for the slot/cache substrate: ``SlotManager`` lifecycle
(including the pipelined-admission reserved/prefilling states) and the
prefill scatter helpers (whole-prompt and streamed per-chunk), which the
engine tests only exercise indirectly."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.kv_cache import (
    ACTIVE,
    FAILED,
    FREE,
    PREFILLING,
    REQUEUED,
    RESERVED,
    SlotManager,
    scatter_prefill_caches,
    scatter_prefill_chunk_caches,
    zero_slots,
)
from repro.serving.request import Request


def _req(rid, input_len=4):
    return Request(rid=rid, arrival=0.0, input_len=input_len, output_len=8,
                   token_times=[])


# ---------------------------------------------------------------------------
# SlotManager lifecycle
# ---------------------------------------------------------------------------


def test_slot_admit_advance_release_reuse():
    sm = SlotManager(max_batch=2, cache_len=16)
    assert sm.free_slots == [0, 1] and sm.num_active == 0
    r0 = _req(0, input_len=5)
    s = sm.admit(r0)
    assert s == 0 and r0.slot == 0
    assert sm.state[0] == ACTIVE and sm.positions[0] == 5
    sm.advance(0)
    assert sm.positions[0] == 6
    back = sm.release(0)
    assert back is r0
    assert sm.state[0] == FREE and sm.positions[0] == 15  # parked at scratch
    # freed slot is immediately reusable
    r1 = _req(1, input_len=2)
    assert sm.admit(r1) == 0 and sm.positions[0] == 2


def test_slot_reserved_prefilling_lifecycle():
    sm = SlotManager(max_batch=3, cache_len=32)
    r = _req(7, input_len=9)
    s = sm.reserve(r)
    assert sm.state[s] == RESERVED
    # reserved slots are owned (not free) but not decoded
    assert s not in sm.free_slots and s not in sm.active_slots
    assert sm.pending_slots == [s]
    assert sm.positions[s] == 31  # still parked: decode writes only scratch
    sm.start_prefill(s)
    assert sm.state[s] == PREFILLING and sm.pending_slots == [s]
    assert not sm.active_mask()[s]
    sm.activate(s)
    assert sm.state[s] == ACTIVE and sm.positions[s] == 9
    assert sm.pending_slots == [] and sm.active_slots == [s]
    sm.release(s)
    assert sm.state[s] == FREE


def test_slot_invalid_transitions_raise():
    sm = SlotManager(max_batch=1, cache_len=16)
    r = _req(0)
    sm.reserve(r)
    with pytest.raises(RuntimeError, match="no free slot"):
        sm.reserve(_req(1))
    sm.start_prefill(0)
    with pytest.raises(RuntimeError, match="expected reserved"):
        sm.start_prefill(0)  # already prefilling
    sm.activate(0)
    with pytest.raises(RuntimeError, match="cannot activate"):
        sm.activate(0)  # already active


def test_slot_fault_detour_fail_requeue():
    """Fault-recovery detour: a prefilling slot whose work is lost walks
    failed → requeued → prefilling and eventually activates as normal."""
    sm = SlotManager(max_batch=2, cache_len=32)
    r = _req(3, input_len=6)
    s = sm.reserve(r)
    sm.start_prefill(s)
    sm.fail(s)
    assert sm.state[s] == FAILED
    # failed slots are still owned (pending), never decoded
    assert sm.pending_slots == [s] and s not in sm.free_slots
    sm.requeue(s)
    assert sm.state[s] == REQUEUED and sm.pending_slots == [s]
    sm.start_prefill(s)  # restart at chunk 0
    sm.activate(s)
    assert sm.state[s] == ACTIVE and sm.positions[s] == 6
    # invalid detour transitions raise with the offending state named
    with pytest.raises(RuntimeError, match="cannot fail"):
        sm.fail(s)  # active slots don't fail through the prefill detour
    with pytest.raises(RuntimeError, match="expected failed"):
        sm.requeue(s)
    # a reserved slot may fail too (queue entry lost before any chunk ran)
    r2 = _req(4)
    s2 = sm.reserve(r2)
    sm.fail(s2)
    assert sm.state[s2] == FAILED


def test_zero_slots_destroys_only_named_rows():
    """zero_slots wipes the batch rows a dead shard hosted (enc_out on axis
    0, stacked caches on axis 1) and leaves every other row untouched."""
    caches = {k: v + 1.0 for k, v in _batch_caches().items()}
    out = zero_slots(caches, [0, 2])
    for k, v in out.items():
        got = np.asarray(v)
        if k == "enc_out":
            assert (got[[0, 2]] == 0).all() and (got[1] == 1.0).all()
        else:
            assert (got[:, [0, 2]] == 0).all() and (got[:, 1] == 1.0).all()
    assert zero_slots(caches, []) is caches  # no-op fast path


# ---------------------------------------------------------------------------
# scatter helpers
# ---------------------------------------------------------------------------


def _batch_caches(L=2, B=3, S=8, H=2, D=4):
    return {
        "kv_k": jnp.zeros((L, B, S, H, D), jnp.float32),
        "kv_v": jnp.zeros((L, B, S, H, D), jnp.float32),
        "enc_out": jnp.zeros((B, 5, 6), jnp.float32),
    }


def _one_caches(L=2, S=8, H=2, D=4, fill=1.0):
    return {
        "kv_k": jnp.full((L, 1, S, H, D), fill, jnp.float32),
        "kv_v": jnp.full((L, 1, S, H, D), 2 * fill, jnp.float32),
        "enc_out": jnp.full((1, 5, 6), 3 * fill, jnp.float32),
    }


def test_scatter_prefill_caches_axes():
    """Stacked caches scatter on batch axis 1; ``enc_out`` on axis 0."""
    out = scatter_prefill_caches(_batch_caches(), _one_caches(), slot=1)
    for k, ax in [("kv_k", 1), ("kv_v", 1)]:
        got = np.asarray(out[k])
        assert (got[:, 1] != 0).all()
        assert (got[:, [0, 2]] == 0).all(), k
    enc = np.asarray(out["enc_out"])
    assert (enc[1] == 3.0).all() and (enc[[0, 2]] == 0).all()


def test_scatter_prefill_chunk_rows():
    """Per-chunk streaming writes only the chunk's position rows of the one
    target slot, leaves every other row/slot untouched, and skips non-KV
    entries (they move with the final whole-prompt hand-off)."""
    batch = _batch_caches()
    one = _one_caches()
    out = scatter_prefill_chunk_caches(batch, one, slot=2, start=3, length=4)
    for k in ("kv_k", "kv_v"):
        got = np.asarray(out[k])
        assert (got[:, 2, 3:7] != 0).all(), k  # the chunk landed
        assert (got[:, 2, :3] == 0).all() and (got[:, 2, 7:] == 0).all()
        assert (got[:, [0, 1]] == 0).all()  # other slots untouched
    assert (np.asarray(out["enc_out"]) == 0).all()  # non-KV ignored


def test_scatter_chunks_compose_to_whole_prompt():
    """Streaming a prompt chunk-by-chunk composes to exactly the bulk
    whole-prompt scatter (over the prompt's rows)."""
    one = _one_caches()
    # give rows distinct values so ordering errors show
    one = {k: v * jnp.arange(1, v.shape[2] + 1, dtype=jnp.float32)[None, None, :, None, None]
           if k != "enc_out" else v for k, v in one.items()}
    bulk = scatter_prefill_caches(_batch_caches(), one, slot=0)
    streamed = _batch_caches()
    for start, length in [(0, 3), (3, 3), (6, 2)]:
        streamed = scatter_prefill_chunk_caches(streamed, one, 0, start, length)
    for k in ("kv_k", "kv_v"):
        np.testing.assert_array_equal(np.asarray(streamed[k]), np.asarray(bulk[k]))
