"""Paged KV cache: allocator invariants, paged↔contiguous bit-exactness
across executors (mono / disagg / fault replay / rolling-window stacks),
the paged Pallas decode kernel vs its oracle, and the operator surface
(CLI flag, page telemetry, autoscaler memory pressure).

The load-bearing claim everywhere: page indirection is *storage only* —
identical values land at identical unmasked positions, so greedy token
streams are bit-identical to the contiguous baseline by construction.
"""

import dataclasses
import sys

import jax.numpy as jnp
import numpy as np
import pytest
from _hypo import given, settings, st

from repro.configs import get_config
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import (
    NULL_PAGE,
    PAGED_KEYS,
    PageAllocator,
    PagedKVCache,
    depaginate_caches,
    make_paged_caches,
    paginate_caches,
    zero_slots,
)
from repro.serving.request import WorkloadSpec, sample_requests

PS = 16  # page size used throughout


# ---------------------------------------------------------------------------
# allocator / page-table invariants
# ---------------------------------------------------------------------------


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.integers(min_value=0, max_value=7), min_size=1, max_size=120),
    st.integers(min_value=2, max_value=9),
)
def test_page_allocator_never_leaks_or_double_assigns(ops, num_pages):
    """Any alloc/free interleaving: pages are never handed out twice, the
    null page is never handed out, and free + in-use always account for the
    whole pool (no leaks)."""
    alloc = PageAllocator(num_pages)
    held = []
    for op in ops:
        if op % 2 and held:
            alloc.free(held.pop(op % len(held)))
        else:
            try:
                p = alloc.alloc()
            except RuntimeError:
                assert alloc.num_free == 0
                continue
            assert p != NULL_PAGE and 1 <= p < num_pages
            assert p not in held  # double-assignment
            held.append(p)
        assert alloc.num_free + alloc.in_use == num_pages - 1
        assert alloc.in_use == len(held)
        assert alloc.peak_in_use >= alloc.in_use
    for p in held:
        alloc.free(p)
    assert alloc.in_use == 0 and alloc.num_free == num_pages - 1


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(min_value=0, max_value=3),
                  st.integers(min_value=0, max_value=47)),
        min_size=1, max_size=80,
    )
)
def test_paged_kv_cache_alloc_free_roundtrip(ops):
    """Random ensure/release over 4 slots: no page is ever owned by two
    slots, block tables mirror ownership exactly, and releasing everything
    returns the pool to empty."""
    pager = PagedKVCache(4, 48, 8)
    for slot, pos in ops:
        if pos % 5 == 0:
            pager.release(slot)
        else:
            pager.ensure(slot, pos)
        flat = list(pager.pages_of(range(4)))
        assert len(flat) == len(set(flat))  # no page owned by two slots
        for s in range(4):
            n = pager.slot_blocks(s)
            assert sorted(pager.tables[s, :n]) == sorted(pager.pages_of([s]))
            assert all(pager.tables[s, n:] == NULL_PAGE)
    for s in range(4):
        pager.release(s)
    st_ = pager.stats()
    assert st_["pages_in_use"] == 0
    assert st_["pages_free"] == st_["num_pages"] - 1


def test_paged_kv_cache_basics():
    pager = PagedKVCache(2, 64, PS, num_pages=5)
    assert pager.blocks_per_slot == 4
    changed = pager.ensure(0, 0)
    assert changed and list(pager.pages_of([0])) == [1]  # low ids first
    assert not pager.ensure(0, PS - 1)  # same page — nothing to do
    pager.ensure(0, 2 * PS - 1)
    pages, offs = pager.rows_of(0, PS - 2, 3)
    assert list(pages) == [1, 1, 2] and list(offs) == [PS - 2, PS - 1, 0]
    with pytest.raises(RuntimeError, match="not page-backed"):
        pager.rows_of(0, 2 * PS, 1)
    with pytest.raises(ValueError):
        pager.ensure(0, 64)  # past cache_len
    # pool exhaustion: 4 usable pages, slot 0 holds 2
    pager.ensure(1, 2 * PS - 1)
    with pytest.raises(RuntimeError, match="out of KV pages"):
        pager.ensure(1, 3 * PS - 1)
    st_ = pager.stats()
    assert st_["pages_in_use"] == 4 and st_["pages_peak"] == 4
    pager.release(0)
    assert pager.stats()["pages_in_use"] == 2
    with pytest.raises(ValueError, match="page boundaries"):
        PagedKVCache(2, 60, PS)


def test_make_paged_caches_requires_full_attention_kv():
    cfg = get_config("falcon-mamba-7b-reduced")  # recurrent: no kv_k cache
    caches = model_mod.init_decode_caches(cfg, 2, 32)
    with pytest.raises(ValueError, match="no full-attention KV cache"):
        make_paged_caches(caches, 2, 32, PS)


def test_paginate_depaginate_roundtrip_and_zero_slots():
    rng = np.random.default_rng(0)
    L, B, S, nkv, hd = 2, 3, 48, 2, 4
    dense = {
        "kv_k": jnp.asarray(rng.standard_normal((L, B, S, nkv, hd)), jnp.float32),
        "kv_v": jnp.asarray(rng.standard_normal((L, B, S, nkv, hd)), jnp.float32),
    }
    lengths = np.array([5, 0, 33])
    pager, paged = paginate_caches(dense, lengths, 8)
    assert "block_tables" in paged
    back = depaginate_caches(paged, pager)
    for k in dense:
        for b, ln in enumerate(lengths):
            np.testing.assert_array_equal(
                np.asarray(back[k][:, b, :ln]), np.asarray(dense[k][:, b, :ln]),
                err_msg=f"{k} slot {b}",
            )
    # zero_slots on a paged dict clears exactly the slot's pages
    paged = zero_slots(paged, [2], paged=pager)
    back2 = depaginate_caches(paged, pager)
    assert not np.asarray(back2["kv_k"][:, 2]).any()
    np.testing.assert_array_equal(
        np.asarray(back2["kv_k"][:, 0, :5]), np.asarray(dense["kv_k"][:, 0, :5])
    )


# ---------------------------------------------------------------------------
# engine-level bit-exactness: paged vs contiguous
# ---------------------------------------------------------------------------


def _reqs(cfg, n=5, seed=0, mean_out=8, max_in=16, max_out=12):
    spec = WorkloadSpec(mean_input=6, mean_output=mean_out, vocab_size=cfg.vocab_size,
                        max_input=max_in, max_output=max_out, seed=seed)
    return sample_requests(spec, np.linspace(0, 0.01, n), with_prompts=True)


def _streams(eng):
    return {r.rid: tuple(r.tokens_out) for r in eng.completed}


def _run_pair(cfg, reqs_fn, **kw):
    """Run the same workload paged and contiguous; return both engines."""
    engines = {}
    for name, extra in (("contig", {}), ("paged", {"kv_page_size": PS})):
        eng = ServingEngine(cfg, model_mod.init_params(cfg, 0), **kw, **extra)
        m = eng.run(reqs_fn(), max_steps=4000)
        assert m["completed"] == len(eng.completed) and m["completed"] > 0
        engines[name] = (eng, m)
    return engines


def test_mono_paged_streams_bit_identical_dense():
    cfg = get_config("phi4-mini-3.8b-reduced")
    engines = _run_pair(cfg, lambda: _reqs(cfg, 5), max_batch=3, cache_len=64,
                        scheduler="none", step_time_fn=lambda n: 2e-3)
    assert _streams(engines["paged"][0]) == _streams(engines["contig"][0])
    pages = engines["paged"][1]["kv_pages"]
    assert pages["pages_peak"] > 0
    assert pages["pages_in_use"] == 0  # free-on-release drained the pool
    assert "kv_pages" not in engines["contig"][1]


def test_mono_paged_streams_bit_identical_moe():
    """Scheduled-MoE mono path under ample capacity (paged inactive slots
    attend masked garbage — ample capacity keeps routing independent)."""
    from repro.core.amax import make_routing_trace
    from repro.core.placement import build_layout

    cfg = get_config("qwen2-moe-a2.7b-reduced")
    trace = make_routing_trace(512, cfg.num_experts, cfg.top_k, skew=0.8, seed=0)
    layout = build_layout(trace, cfg.num_experts, num_instances=2, capacity=3)
    engines = _run_pair(cfg, lambda: _reqs(cfg, 4), max_batch=2, cache_len=64,
                        layout=layout, scheduler="aebs", capacity_tokens=64,
                        step_time_fn=lambda n: 2e-3)
    assert _streams(engines["paged"][0]) == _streams(engines["contig"][0])


def test_window_arch_paged_wrap_streams_bit_identical():
    """gemma2 (dense_local/dense periods): the paged '' cache rides next to
    the *contiguous* rolling `_local` cache, with prompts long enough to wrap
    the 64-token window."""
    cfg = get_config("gemma2-2b-reduced")

    def reqs():
        spec = WorkloadSpec(mean_input=72, mean_output=6, vocab_size=cfg.vocab_size,
                            max_input=100, max_output=8, seed=2)
        rs = sample_requests(spec, np.linspace(0, 0.01, 3), with_prompts=True)
        assert any(r.input_len > cfg.sliding_window for r in rs)  # wrap regime
        return rs

    engines = _run_pair(cfg, reqs, max_batch=2, cache_len=128,
                        scheduler="none", step_time_fn=lambda n: 2e-3)
    assert _streams(engines["paged"][0]) == _streams(engines["contig"][0])


@pytest.fixture(scope="module")
def dsv2():
    cfg = get_config("dsv2-lite-reduced")
    from repro.core.aebs import ReplicaLayout

    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
    return cfg, params, layout


def _disagg_engine(cfg, params, layout, **kw):
    return ServingEngine(
        cfg, params, max_batch=4, cache_len=64, layout=layout,
        scheduler="aebs", capacity_tokens=64,
        executor="disagg", n_attn=2, n_prefill=1, prefill_chunk=4,
        step_time_fn=lambda n: 2e-3, **kw,
    )


def test_disagg_paged_streams_and_reconfigure_migration(dsv2):
    """Batch-sharded paged caches on the attention pool serve the same
    streams as contiguous disagg, including across a mid-run attention-pool
    re-shard (block tables migrate with their pages)."""
    cfg, params, layout = dsv2
    streams = {}
    for name, extra in (("contig", {}), ("paged", {"kv_page_size": PS})):
        eng = _disagg_engine(cfg, params, layout, **extra)
        m1 = eng.run(_reqs(cfg, 5), max_steps=2000)
        assert m1["completed"] == 5
        s1 = _streams(eng)
        # re-shard the attention pool mid-deployment, then serve more
        eng.reconfigure(n_attn=3)
        eng.completed.clear()
        m2 = eng.run(_reqs(cfg, 4, seed=7), max_steps=2000)
        assert m2["completed"] == 4
        streams[name] = (s1, _streams(eng))
        if name == "paged":
            assert m2["kv_pages"]["pages_in_use"] == 0
            assert m2["kv_pages"]["pages_peak"] > 0
    assert streams["paged"] == streams["contig"]


def test_disagg_paged_attn_loss_replay_bit_identical(dsv2):
    """The PR 4 attention-loss path on a paged deployment: a dead shard
    takes its pages with it; survivors re-shard (tables migrate), lost slots
    replay deterministically, and the streams stay bit-identical to both the
    fault-free paged run and the contiguous baseline."""
    from repro.serving.faults import DEVICE_LOSS, FaultPlan, FaultSpec, RetryPolicy

    cfg, params, layout = dsv2
    runs = {}
    plan = lambda: FaultPlan(
        faults=[FaultSpec(DEVICE_LOSS, pool="attn", index=1, at_step=6)]
    )
    for name, kw in (
        ("contig", {}),
        ("paged", {"kv_page_size": PS}),
        ("paged_fault", {"kv_page_size": PS, "fault_plan": plan(),
                         "retry_policy": RetryPolicy(recovery_charge_s=0.01)}),
    ):
        eng = _disagg_engine(cfg, params, layout, **kw)
        m = eng.run(_reqs(cfg, 5, mean_out=16, max_out=24), max_steps=2000)
        assert m["completed"] == 5
        runs[name] = (_streams(eng), m)
    assert runs["paged"][0] == runs["contig"][0]
    assert runs["paged_fault"][0] == runs["contig"][0]
    f = runs["paged_fault"][1]["faults"]
    assert f["recoveries"] == 1 and f["degraded"] == 0 and f["replayed_slots"] >= 1


# ---------------------------------------------------------------------------
# paged decode kernel vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["pallas", "jnp"])
@pytest.mark.parametrize("logit_cap", [0.0, 30.0])
def test_paged_kernel_matches_dense_reference(backend, logit_cap):
    """Both backends (interpreted Pallas kernel / jnp gather oracle) must
    reproduce the *dense* flash-decode reference on the gathered view —
    per-slot lengths, null-page padding and all."""
    from repro.kernels.decode_attention.ops import paged_decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    rng = np.random.default_rng(1)
    B, nh, nkv, hd, ps, P, nblk = 3, 4, 2, 8, 4, 13, 4
    q = jnp.asarray(rng.standard_normal((B, nh, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((P, ps, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((P, ps, nkv, hd)), jnp.float32)
    bt = jnp.asarray(
        rng.permutation(P - 1)[: B * nblk].reshape(B, nblk) + 1, jnp.int32
    )
    lens = jnp.asarray([1, 7, 16], jnp.int32)  # partial page / mid / full
    got = paged_decode_attention(q, k, v, bt, lens, logit_cap=logit_cap,
                                 backend=backend)
    dense_k = k[bt].reshape(B, nblk * ps, nkv, hd)
    dense_v = v[bt].reshape(B, nblk * ps, nkv, hd)
    for b in range(B):
        want = decode_attention_ref(
            q[b : b + 1], dense_k[b : b + 1], dense_v[b : b + 1],
            lens[b], logit_cap=logit_cap,
        )
        np.testing.assert_allclose(
            np.asarray(got[b : b + 1]), np.asarray(want), atol=1e-5, rtol=1e-5
        )


def test_paged_kernel_ignores_unbacked_tail():
    """Rows past `lengths` — including whole null-page blocks — must not
    leak into the output: two pools differing only in masked rows agree."""
    from repro.kernels.decode_attention.ops import paged_decode_attention

    rng = np.random.default_rng(2)
    B, nh, nkv, hd, ps, P, nblk = 2, 2, 1, 8, 4, 6, 3
    q = jnp.asarray(rng.standard_normal((B, nh, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((P, ps, nkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((P, ps, nkv, hd)), jnp.float32)
    bt = jnp.asarray([[1, 2, 0], [3, 0, 0]], jnp.int32)  # null-page tails
    lens = jnp.asarray([6, 3], jnp.int32)
    base = paged_decode_attention(q, k, v, bt, lens)
    # scribble over every masked row (null page + backed tails)
    k2 = np.asarray(k).copy()
    v2 = np.asarray(v).copy()
    k2[0] = 7.0
    v2[0] = -7.0
    k2[2, 2:] = 9.0
    v2[3, 3:] = -9.0
    got = paged_decode_attention(q, jnp.asarray(k2), jnp.asarray(v2), bt, lens)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(got))


def test_paged_decode_backend_dispatch(monkeypatch):
    """REPRO_PAGED_DECODE forces the read path; auto picks gather off-TPU
    (interpreted Pallas is debug-speed) and the kernel on TPU."""
    import jax

    from repro.models.attention import paged_decode_backend

    monkeypatch.setenv("REPRO_PAGED_DECODE", "kernel")
    assert paged_decode_backend() == "kernel"
    monkeypatch.setenv("REPRO_PAGED_DECODE", "gather")
    assert paged_decode_backend() == "gather"
    monkeypatch.delenv("REPRO_PAGED_DECODE")
    expect = "kernel" if jax.default_backend() == "tpu" else "gather"
    assert paged_decode_backend() == expect


def test_paged_decode_kernel_backend_streams_bit_identical(monkeypatch):
    """Serving through the paged Pallas decode kernel (interpreted off-TPU)
    emits the same greedy token streams as the jnp gather path: flash and
    dense softmax agree to float tolerance, and greedy argmax sees identical
    winners.  The env var is read at trace time — each engine jits its own
    decode closure, so forcing it per-run is effective."""
    cfg = get_config("phi4-mini-3.8b-reduced")
    params = model_mod.init_params(cfg, 0)
    streams = {}
    for backend in ("gather", "kernel"):
        monkeypatch.setenv("REPRO_PAGED_DECODE", backend)
        eng = ServingEngine(cfg, params, max_batch=2, cache_len=64,
                            scheduler="none", kv_page_size=PS,
                            step_time_fn=lambda n: 2e-3)
        m = eng.run(_reqs(cfg, 3, mean_out=4, max_out=6), max_steps=2000)
        assert m["completed"] == 3
        streams[backend] = _streams(eng)
    assert streams["kernel"] == streams["gather"]


def test_paged_int8_kernel_fallback_streams_bit_identical(monkeypatch):
    """int8 KV pools have no kernel read path, so forcing
    REPRO_PAGED_DECODE=kernel on a quantised cache must silently fall back
    to the gather path — and paged int8 serving stays bit-identical to
    contiguous int8 serving (same quantise-once-at-write numerics, only the
    page indirection differs)."""
    import dataclasses

    cfg = dataclasses.replace(get_config("phi4-mini-3.8b-reduced"), kv_quant=True)
    params = model_mod.init_params(cfg, 0)
    monkeypatch.setenv("REPRO_PAGED_DECODE", "kernel")
    streams = {}
    for name, extra in (("contig", {}), ("paged", {"kv_page_size": PS})):
        eng = ServingEngine(cfg, params, max_batch=2, cache_len=64,
                            scheduler="none", step_time_fn=lambda n: 2e-3,
                            **extra)
        m = eng.run(_reqs(cfg, 3, mean_out=4, max_out=6), max_steps=2000)
        assert m["completed"] == 3
        streams[name] = _streams(eng)
    assert streams["paged"] == streams["contig"]


# ---------------------------------------------------------------------------
# operator surface: CLI, telemetry → autoscaler
# ---------------------------------------------------------------------------


def test_serve_cli_kv_page_size(monkeypatch, capsys):
    from repro.launch import serve

    monkeypatch.setattr(
        sys, "argv",
        ["serve", "--arch", "phi4-mini-3.8b", "--scheduler", "none",
         "--rate", "50", "--duration", "0.04", "--max-batch", "2",
         "--cache-len", "64", "--kv-page-size", "16"],
    )
    serve.main()
    out = capsys.readouterr().out
    assert "kv_pages" in out


def test_autoscaler_kv_pressure_adds_attention_device():
    from repro.core.scaling import EvalResult, PerfModel
    from repro.serving.controller import AutoScaler

    cfg = get_config("dsv2-lite-reduced")
    ctrl = AutoScaler(PerfModel(cfg, slots_per_instance=3, s_ctx=64), slo=0.2,
                      n_max=8)
    decision = EvalResult(n_a=2, n_e=2, batch=4, tpot=0.1, t_attn=0, t_moe=0,
                          t_comm=0, a_max=1, tpg=1.0, feasible=True)
    ctrl.scaler.scale = lambda lam, slo: dataclasses.replace(decision)
    ctrl.observe(0.0, 16.0, kv_occupancy=0.5)
    assert ctrl.decide(1.0, demand=100.0).n_a == 2  # below threshold
    ctrl.observe(2.0, 16.0, kv_occupancy=0.95)
    assert ctrl.kv_pressure(3.0) == pytest.approx(0.95)
    assert ctrl.decide(3.0, demand=100.0).n_a == 3  # pressure adds one
    # pressure ages out of the window
    assert ctrl.decide(2.0 + ctrl.window + 1.0, demand=100.0).n_a == 2


def test_engine_metrics_feed_autoscaler_occupancy():
    """The mono paged engine exposes `kv_pages` occupancy; feeding it through
    observe() is what actuate() does on a live disagg engine."""
    cfg = get_config("phi4-mini-3.8b-reduced")
    eng = ServingEngine(cfg, model_mod.init_params(cfg, 0), max_batch=2,
                        cache_len=64, scheduler="none", kv_page_size=PS,
                        step_time_fn=lambda n: 2e-3)
    eng.run(_reqs(cfg, 3), max_steps=2000)
    stats = eng.metrics()["kv_pages"]
    assert set(stats) >= {"page_size", "num_pages", "pages_in_use",
                          "pages_peak", "pages_free", "occupancy",
                          "fragmentation"}
    assert 0.0 <= stats["occupancy"] <= 1.0
