"""Adaptive two-phase communication model (§3.3) behavioural tests."""

import pytest

from _hypo import given, settings, st

from repro.core.comm import (
    H100,
    TPU_V5E,
    CommConfig,
    adaptive_two_phase,
    agate_cost,
    layer_comm_time,
    one_phase_cost,
    two_phase_case1,
    two_phase_case2,
)


def _cfg(m, n, B=256, d=4096, hw=H100):
    return CommConfig(n_attn=m, n_moe=n, bytes_per_token=2 * d, batch=B, hw=hw)


def test_two_phase_beats_one_phase_at_scale():
    """§3.3: many small m×n transfers dominate — aggregation wins."""
    for m, n in [(8, 16), (16, 32), (4, 12)]:
        c = _cfg(m, n)
        t2, _ = adaptive_two_phase(c)
        assert t2 < one_phase_cost(c)


def test_adaptive_picks_min():
    for m, n in [(2, 2), (8, 8), (16, 64), (64, 8)]:
        c = _cfg(m, n)
        t, regime = adaptive_two_phase(c)
        assert t == min(two_phase_case1(c), two_phase_case2(c))
        assert regime in ("case1", "case2")


def test_case2_wins_with_many_destinations():
    """Fig. 6: large destination counts favour one-to-one + local multicast."""
    big = _cfg(32, 64, B=2048)
    assert two_phase_case2(big) < two_phase_case1(big)


def test_roundtrip_scales_with_batch():
    t_small = layer_comm_time(4, 8, 64, 4096, H100)
    t_big = layer_comm_time(4, 8, 4096, 4096, H100)
    assert t_big > t_small


def test_egate_vs_agate_regimes():
    """§5.3 / Fig. 12: with two-phase aggregation, MoE-side gating (full
    activations, no metadata) competes with attention-side gating even though
    it ships more bytes, because it avoids the per-destination messages."""
    c = _cfg(8, 16, B=128, d=5120)
    t_2pc_egate, _ = adaptive_two_phase(c)
    t_agate = agate_cost(c, top_k=8, num_experts=160)
    assert t_2pc_egate < t_agate * 2.5  # same order; aggregation pays for bytes


def test_tpu_constants_sane():
    assert TPU_V5E.peak_flops == 197e12
    assert TPU_V5E.hbm_bw == 819e9
    assert H100.fast_bw > H100.slow_bw


# ---------------------------------------------------------------------------
# Property sweeps: regime selection never regresses
# ---------------------------------------------------------------------------


@st.composite
def comm_case(draw, at_scale: bool = False):
    hw = draw(st.sampled_from([H100, TPU_V5E]))
    n_lo = 2 * hw.devices_per_node if at_scale else 1
    m = draw(st.integers(min_value=1, max_value=64))
    n = draw(st.integers(min_value=n_lo, max_value=128))
    batch = draw(st.integers(min_value=1, max_value=4096))
    d = draw(st.integers(min_value=64, max_value=8192))
    return CommConfig(n_attn=m, n_moe=n, bytes_per_token=2 * d, batch=batch, hw=hw)


@given(comm_case())
@settings(max_examples=120, deadline=None)
def test_adaptive_is_min_of_cases_prop(c):
    """adaptive_two_phase is exactly min(case1, case2), regime consistent."""
    t, regime = adaptive_two_phase(c)
    t1, t2 = two_phase_case1(c), two_phase_case2(c)
    assert t == min(t1, t2)
    assert regime == ("case1" if t1 <= t2 else "case2")
    assert t > 0.0


@given(comm_case(at_scale=True))
@settings(max_examples=120, deadline=None)
def test_adaptive_never_regresses_vs_one_phase_prop(c):
    """With ≥2 destination nodes, intra-node aggregation always pays:
    adaptive_two_phase(c)[0] <= min(one_phase, case1, case2) — the §3.3
    regression bound the strawman comparison benchmarks rely on."""
    t, _ = adaptive_two_phase(c)
    assert t <= min(one_phase_cost(c), two_phase_case1(c), two_phase_case2(c)) * (1 + 1e-12)


@given(comm_case(), st.integers(min_value=2, max_value=8))
@settings(max_examples=60, deadline=None)
def test_cost_monotone_in_batch_prop(c, factor):
    """More tokens never get cheaper to move (both regimes)."""
    import dataclasses

    bigger = dataclasses.replace(c, batch=c.batch * factor)
    assert adaptive_two_phase(bigger)[0] >= adaptive_two_phase(c)[0]
    assert one_phase_cost(bigger) >= one_phase_cost(c)
