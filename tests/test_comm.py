"""Adaptive two-phase communication model (§3.3) behavioural tests."""

import pytest

from repro.core.comm import (
    H100,
    TPU_V5E,
    CommConfig,
    adaptive_two_phase,
    agate_cost,
    layer_comm_time,
    one_phase_cost,
    two_phase_case1,
    two_phase_case2,
)


def _cfg(m, n, B=256, d=4096, hw=H100):
    return CommConfig(n_attn=m, n_moe=n, bytes_per_token=2 * d, batch=B, hw=hw)


def test_two_phase_beats_one_phase_at_scale():
    """§3.3: many small m×n transfers dominate — aggregation wins."""
    for m, n in [(8, 16), (16, 32), (4, 12)]:
        c = _cfg(m, n)
        t2, _ = adaptive_two_phase(c)
        assert t2 < one_phase_cost(c)


def test_adaptive_picks_min():
    for m, n in [(2, 2), (8, 8), (16, 64), (64, 8)]:
        c = _cfg(m, n)
        t, regime = adaptive_two_phase(c)
        assert t == min(two_phase_case1(c), two_phase_case2(c))
        assert regime in ("case1", "case2")


def test_case2_wins_with_many_destinations():
    """Fig. 6: large destination counts favour one-to-one + local multicast."""
    big = _cfg(32, 64, B=2048)
    assert two_phase_case2(big) < two_phase_case1(big)


def test_roundtrip_scales_with_batch():
    t_small = layer_comm_time(4, 8, 64, 4096, H100)
    t_big = layer_comm_time(4, 8, 4096, 4096, H100)
    assert t_big > t_small


def test_egate_vs_agate_regimes():
    """§5.3 / Fig. 12: with two-phase aggregation, MoE-side gating (full
    activations, no metadata) competes with attention-side gating even though
    it ships more bytes, because it avoids the per-destination messages."""
    c = _cfg(8, 16, B=128, d=5120)
    t_2pc_egate, _ = adaptive_two_phase(c)
    t_agate = agate_cost(c, top_k=8, num_experts=160)
    assert t_2pc_egate < t_agate * 2.5  # same order; aggregation pays for bytes


def test_tpu_constants_sane():
    assert TPU_V5E.peak_flops == 197e12
    assert TPU_V5E.hbm_bw == 819e9
    assert H100.fast_bw > H100.slow_bw
