"""Per-architecture smoke tests: REDUCED variant, one forward + one train
step on CPU, asserting output shapes and no NaNs (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import model as model_mod
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state

ARCHS = sorted(ASSIGNED)


def _extra(cfg, B):
    extra = {}
    if cfg.frontend == "audio_frames":
        extra["encoder_frames"] = jnp.full((B, cfg.encoder_seq, cfg.d_model), 0.01, jnp.bfloat16)
    if cfg.frontend == "vision_patches":
        extra["patch_embeds"] = jnp.full((B, cfg.num_patch_tokens, cfg.d_model), 0.01, jnp.bfloat16)
    return extra or None


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nans(name):
    cfg = get_config(name + "-reduced")
    params = model_mod.init_params(cfg, 0)
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, S), 0, cfg.vocab_size)
    logits, _ = model_mod.logits_fn(params, tokens, cfg, extra=_extra(cfg, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("name", ARCHS)
def test_one_train_step(name):
    cfg = get_config(name + "-reduced")
    params = model_mod.init_params(cfg, 0)
    opt = init_opt_state(params)
    B, S = 2, 16
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    extra = _extra(cfg, B)

    def loss(p):
        return model_mod.loss_fn(p, tokens, labels, cfg, extra=extra)

    (l, _), grads = jax.value_and_grad(loss, has_aux=True)(params)
    assert np.isfinite(float(l))
    new_params, new_opt, info = adamw_update(AdamWConfig(), params, grads, opt)
    assert np.isfinite(float(info["grad_norm"])) and float(info["grad_norm"]) > 0
    # params actually changed
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params),
    )
    assert moved


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step_shapes(name):
    cfg = get_config(name + "-reduced")
    params = model_mod.init_params(cfg, 0)
    B, S = 2, 24
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    extra = _extra(cfg, B)
    _, caches = model_mod.prefill(params, tokens, cfg, cache_len=S + 8, extra=extra)
    logits, caches2 = model_mod.decode_step(params, tokens[:, :1], caches, jnp.int32(S), cfg)
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert jax.tree.structure(caches) == jax.tree.structure(caches2)
