"""Prefill+decode must equal the full forward pass, per family (the KV-cache
/ recurrent-state substrate correctness test)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as model_mod

CASES = [
    ("phi4-mini-3.8b", 0.02),
    ("gemma-7b", 0.02),
    ("gemma2-2b", 0.02),       # sliding window + softcaps
    ("yi-34b", 0.02),
    ("qwen2-moe-a2.7b", 0.03),  # MoE (capacity default ample at this size)
    ("phi3.5-moe-42b-a6.6b", 0.03),
    ("falcon-mamba-7b", 0.03),  # mamba-1
    ("zamba2-2.7b", 0.04),      # mamba-2 + shared attention
    ("whisper-tiny", 0.02),     # enc-dec
    ("pixtral-12b", 0.02),
]


def _extra(cfg, B, key):
    # ample MoE capacity on both paths: capacity *dropping* differs between a
    # full forward (tokens compete within the whole sequence) and decode
    # (one token per sequence) — that divergence is expected MoE semantics,
    # not a cache bug, so the consistency test removes it.
    extra = {"moe_ctx": {"capacity": 512}} if cfg.has_moe else {}
    if cfg.frontend == "audio_frames":
        extra["encoder_frames"] = (
            jax.random.normal(key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1
        ).astype(jnp.bfloat16)
    if cfg.frontend == "vision_patches":
        extra["patch_embeds"] = (
            jax.random.normal(key, (B, cfg.num_patch_tokens, cfg.d_model), jnp.float32) * 0.1
        ).astype(jnp.bfloat16)
    return extra or None


@pytest.mark.parametrize("name,tol", CASES)
def test_prefill_decode_matches_forward(name, tol):
    cfg = get_config(name + "-reduced")
    params = model_mod.init_params(cfg, 0)
    B, S, D = 2, 24, 3
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (B, S + D), 0, cfg.vocab_size)
    extra = _extra(cfg, B, key)
    logits_full, _ = model_mod.logits_fn(params, tokens, cfg, extra=extra)
    _, caches = model_mod.prefill(params, tokens[:, :S], cfg, cache_len=S + D + 8, extra=extra)
    for t in range(D):
        got, caches = model_mod.decode_step(
            params, tokens[:, S + t : S + t + 1], caches, jnp.int32(S + t), cfg
        )
        want = np.asarray(logits_full[:, S + t], np.float32)
        err = np.abs(want - np.asarray(got, np.float32)).max() / (np.abs(want).max() + 1e-9)
        assert err < tol, (name, t, err)


def test_per_request_positions_match_scalar():
    """Vector cache_index (continuous batching) ≡ scalar when positions equal."""
    cfg = get_config("gemma2-2b-reduced")
    params = model_mod.init_params(cfg, 0)
    B, S = 3, 16
    tokens = jax.random.randint(jax.random.PRNGKey(0), (B, S + 1), 0, cfg.vocab_size)
    _, caches = model_mod.prefill(params, tokens[:, :S], cfg, cache_len=S + 8)
    g1, _ = model_mod.decode_step(params, tokens[:, S:], caches, jnp.int32(S), cfg)
    g2, _ = model_mod.decode_step(params, tokens[:, S:], caches, jnp.full((B,), S, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-3, rtol=1e-3)
