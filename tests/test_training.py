"""Training substrate units: optimizer, schedule, checkpoint resume, data."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.training.checkpoint import latest_checkpoint, load_checkpoint, save_checkpoint
from repro.training.data import DataConfig, TokenPipeline
from repro.training.optimizer import (
    AdamWConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_schedule,
)
from repro.training.train_loop import train


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= lrs[10] * 1.01  # warmup rises
    assert max(lrs) <= cfg.lr * 1.0001
    assert lrs[-1] < lrs[20]  # cosine decays
    assert lrs[-1] >= 0.09 * cfg.lr  # floor at 10%


def test_grad_clipping():
    cfg = AdamWConfig(grad_clip=1.0, lr=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros((4,), jnp.float32)}
    huge = {"w": jnp.full((4,), 100.0)}
    state = init_opt_state(params)
    new, state2, info = adamw_update(cfg, params, huge, state)
    assert float(info["grad_norm"]) == 200.0
    # post-clip first step: |update| ≤ lr (adam normalises) — just sanity-check finite & bounded
    assert np.isfinite(np.asarray(new["w"])).all()
    assert np.abs(np.asarray(new["w"])).max() < 10.0


def test_adamw_moves_toward_minimum():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=500, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_opt_state(params)
    for _ in range(120):
        grads = {"w": 2 * params["w"]}  # d/dw of w²
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert np.abs(np.asarray(params["w"])).max() < 1.0


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": jnp.full((4,), 2.0)}
    assert abs(float(global_norm(t)) - np.sqrt(3 + 16)) < 1e-5


def test_checkpoint_resume_exact():
    """Training N steps = training k, checkpointing, resuming for N−k steps
    (deterministic data pipeline keyed by step index)."""
    cfg = get_config("gemma2-2b-reduced")
    from repro.models import model as M
    from repro.training.optimizer import init_opt_state
    from repro.training.train_loop import make_train_step

    opt_cfg = AdamWConfig(total_steps=10)
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, 32, 2, seed=7))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=False))

    def run(params, opt, lo, hi):
        for s in range(lo, hi):
            t, l = pipe.batch(s)
            params, opt, _ = step_fn(params, opt, jnp.asarray(t), jnp.asarray(l))
        return params, opt

    p0 = M.init_params(cfg, 0)
    o0 = init_opt_state(p0)
    p_full, _ = run(p0, o0, 0, 6)

    p_half, o_half = run(M.init_params(cfg, 0), init_opt_state(p0), 0, 3)
    with tempfile.TemporaryDirectory() as d:
        f = save_checkpoint(d, 3, p_half, o_half)
        assert latest_checkpoint(d) == f
        p_load, opt_tree = load_checkpoint(f)
        from repro.training.optimizer import OptState

        o_load = OptState(opt_tree["step"], opt_tree["mu"], opt_tree["nu"])
        p_resumed, _ = run(p_load, o_load, 3, 6)
    same = jax.tree.all(
        jax.tree.map(lambda a, b: bool(jnp.allclose(a.astype(jnp.float32), b.astype(jnp.float32), atol=1e-6)), p_full, p_resumed)
    )
    assert same, "checkpoint resume diverged from continuous training"


def test_pipeline_deterministic_and_structured():
    cfg = DataConfig(vocab_size=1000, seq_len=64, batch_size=4, seed=3)
    p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
    t1, l1 = p1.batch(17)
    t2, l2 = p2.batch(17)
    assert np.array_equal(t1, t2) and np.array_equal(l1, l2)
    assert np.array_equal(t1[:, 1:], l1[:, :-1])  # labels = next tokens
    # zipf skew: token 0 much more frequent than median token
    toks = np.concatenate([p1.batch(s)[0].ravel() for s in range(20)])
    counts = np.bincount(toks, minlength=1000)
    assert counts[0] > 5 * np.median(counts[counts > 0])


def test_train_loop_reduces_loss_dense():
    cfg = get_config("phi4-mini-3.8b-reduced")
    res = train(cfg, steps=40, batch_size=4, seq_len=48, log_every=39, log_fn=lambda *_: None)
    assert res["final_loss"] < res["first_loss"]
