import os

# Tests run on the default single CPU device — the 512-device override is
# strictly scoped to the dry-run subprocesses (see repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(autouse=True)
def _seed():
    np.random.seed(0)
