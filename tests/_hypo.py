"""Optional-hypothesis shim for the property-test modules.

``from _hypo import given, settings, st`` behaves exactly like the real
hypothesis imports when the package is installed.  When it is not, the
decorators degrade to ``pytest.mark.skip`` so the property tests skip
cleanly while the rest of each module still collects and runs.
"""

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised when hypothesis is absent
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    class _StrategiesStub:
        """Stands in for ``hypothesis.strategies`` at module-import time.

        ``st.composite`` must return a callable (strategy factories are
        invoked inside ``@given(...)`` argument lists); every other strategy
        constructor just returns None — the bodies never execute because
        ``given`` skips the test.
        """

        @staticmethod
        def composite(_fn):
            return lambda *a, **k: None

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _StrategiesStub()
