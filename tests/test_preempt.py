"""Priority preemption via KV spill/restore.

The load-bearing claim: spilling is a block-table *detach* (ownership
transfer, no copy, no refcount traffic) and restoring re-attaches the same
pages, so a preempted request resumes mid-decode with its KV intact and its
greedy token stream bit-identical to an uninterrupted run — on the mono and
disagg executors, and even when an attention re-shard dissolves the
detached pages while the request waits (restore downgrades to the
deterministic replay path)."""

import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).parent))

from repro.configs import get_config
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import (
    ACTIVE,
    NULL_PAGE,
    RESERVED,
    PagedKVCache,
    SlotManager,
)
from repro.serving.request import Request, WorkloadSpec, sample_requests

PS = 16


# ---------------------------------------------------------------------------
# PagedKVCache spill / restore unit tests
# ---------------------------------------------------------------------------


def test_spill_is_ownership_transfer():
    pager = PagedKVCache(2, 64, PS, num_pages=9)
    pager.ensure(0, 35)  # 3 pages
    pages = pager.slot_pages(0)
    in_use = pager.allocator.in_use
    rec = pager.spill(0)
    assert rec.pages == pages and rec.tokens == 36
    assert pager.slot_pages(0) == [] and pager.hiwater[0] == 0
    assert all(pager.tables[0] == NULL_PAGE)
    assert pager.allocator.in_use == in_use  # no refcount traffic
    for p in pages:
        assert pager.allocator.refcount(p) == 1  # still held, by the record
    # restore lands on a *different* slot: block b → rec.pages[b] exactly
    pager.restore(1, rec)
    assert pager.slot_pages(1) == pages and pager.hiwater[1] == 36
    assert list(pager.tables[1, :3]) == pages
    rows_pages, _ = pager.rows_of(1, 0, 36)
    assert set(rows_pages) == set(pages)
    pager.release(1)
    assert pager.allocator.in_use == 0


def test_spill_composes_with_prefix_pins():
    """A page shared with the prefix index (extra refcount) spills and drops
    without disturbing the other holder — spill moves the slot's own pin."""
    pager = PagedKVCache(2, 64, PS)
    pager.ensure(0, 2 * PS - 1)
    p0 = pager.slot_pages(0)[0]
    pager.allocator.ref(p0)  # the prefix-index pin
    rec = pager.spill(0)
    assert pager.allocator.refcount(p0) == 2  # unchanged across spill
    pager.drop_spilled(rec)
    assert rec.pages == [] and rec.tokens == 0
    assert pager.allocator.refcount(p0) == 1  # survived via the index pin
    pager.allocator.free(p0)
    assert pager.allocator.in_use == 0


def test_restore_requires_fresh_slot():
    pager = PagedKVCache(2, 64, PS)
    empty = pager.spill(0)  # spilling an empty slot is a no-op record
    assert empty.pages == [] and empty.tokens == 0
    pager.ensure(0, 0)
    pager.ensure(1, 0)
    rec = pager.spill(0)
    with pytest.raises(RuntimeError, match="fresh slot"):
        pager.restore(1, rec)  # slot 1 still owns a page
    pager.restore(0, rec)  # back onto the slot it left is fine
    pager.release(0)
    pager.release(1)
    assert pager.allocator.in_use == 0


def test_slot_manager_reserve_at_and_resume():
    sm = SlotManager(3, 64)
    req = Request(rid=0, arrival=0.0, input_len=4, output_len=8)
    req.generated = 3
    assert sm.reserve(req, slot=2) == 2 and sm.state[2] == RESERVED
    sm.resume(2)
    # resumed decode continues at input_len + generated, not input_len
    assert sm.state[2] == ACTIVE and sm.positions[2] == 7
    with pytest.raises(RuntimeError, match="not free"):
        sm.reserve(Request(rid=1, arrival=0.0, input_len=2, output_len=2), slot=2)
    with pytest.raises(RuntimeError, match="cannot resume"):
        sm.resume(2)
    sm.release(2)


def test_engine_rejects_unknown_sched():
    cfg = get_config("phi4-mini-3.8b-reduced")
    with pytest.raises(ValueError, match="unknown admission scheduler"):
        ServingEngine(cfg, model_mod.init_params(cfg, 0), max_batch=2,
                      cache_len=64, scheduler="none", sched="sjf")


# ---------------------------------------------------------------------------
# engine-level bit-exactness: preempted streams == uninterrupted streams
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mono():
    cfg = get_config("phi4-mini-3.8b-reduced")
    return cfg, model_mod.init_params(cfg, 0)


def _streams(eng):
    return {r.rid: tuple(r.tokens_out) for r in eng.completed}


def _mono_contended_reqs(cfg, n_low=2, n_high=2, high_ttft=0.012):
    """Low-priority batch requests saturating every slot when a high-priority
    chat burst lands 10 ms in — the preemption-forcing workload."""
    spec = WorkloadSpec(mean_input=6, mean_output=24, vocab_size=cfg.vocab_size,
                        max_input=12, max_output=30, seed=0)
    rs = sample_requests(spec, np.linspace(0, 0.001, n_low + n_high),
                         with_prompts=True)
    for r in rs[:n_low]:
        r.priority, r.tenant, r.ttft_slo = 0, "batch", 10.0
    for r in rs[n_low:]:
        r.priority, r.tenant, r.ttft_slo = 5, "chat", high_ttft
        r.arrival += 0.01
    return rs


def test_mono_preempted_streams_bit_identical(mono):
    cfg, params = mono
    runs = {}
    for sched in ("fifo", "priority"):
        eng = ServingEngine(cfg, params, max_batch=2, cache_len=64,
                            scheduler="none", step_time_fn=lambda n: 2e-3,
                            kv_page_size=PS, sched=sched)
        m = eng.run(_mono_contended_reqs(cfg), max_steps=4000)
        assert m["completed"] == 4
        runs[sched] = (m, _streams(eng), eng)
    m_fifo, s_fifo, _ = runs["fifo"]
    m_prio, s_prio, eng_prio = runs["priority"]
    assert m_fifo["preemptions"] == 0  # fifo is the uninterrupted baseline
    assert m_prio["preemptions"] >= 1 and m_prio["restores"] >= 1
    assert s_prio == s_fifo  # spill/restore is lossless
    # the preemptions bought the chat tenant its tight TTFT SLO
    assert m_prio["slo"]["per_tenant"]["chat"] > m_fifo["slo"]["per_tenant"]["chat"]
    assert m_prio["slo"]["attainment"] > m_fifo["slo"]["attainment"]
    assert any(r.preemptions > 0 for r in eng_prio.completed)
    # free-on-release + drop-on-restore drained the pool completely
    assert m_prio["kv_pages"]["pages_in_use"] == 0


def test_mono_preempted_spec_streams_bit_identical(mono):
    """Speculation composes with preemption: the high-priority burst spills a
    slot mid-draft, the spilled request later restores with its draft stream
    rebuilt from the accepted history, and the output streams stay
    bit-identical to the uninterrupted non-speculative FIFO run."""
    cfg, params = mono
    runs = {}
    for name, kw in (
        ("fifo_base", dict(sched="fifo")),
        ("prio_spec", dict(sched="priority", draft_config=cfg, spec_k=2)),
    ):
        eng = ServingEngine(cfg, params, max_batch=2, cache_len=64,
                            scheduler="none", step_time_fn=lambda n: 2e-3,
                            kv_page_size=PS, **kw)
        m = eng.run(_mono_contended_reqs(cfg), max_steps=4000)
        assert m["completed"] == 4
        runs[name] = (m, _streams(eng))
    m_base, s_base = runs["fifo_base"]
    m_spec, s_spec = runs["prio_spec"]
    assert m_base["preemptions"] == 0  # uninterrupted baseline
    assert m_spec["preemptions"] >= 1 and m_spec["restores"] >= 1
    assert s_spec == s_base  # spill mid-draft + restore is lossless
    assert m_spec["spec"]["accepted_per_step"] > 1.0  # still speculating


def test_mono_priority_without_paged_kv_orders_but_never_preempts(mono):
    """Contiguous KV cannot spill; the priority scheduler still reorders
    admission (high priority first among the waiting) but never preempts,
    and everything completes."""
    cfg, params = mono
    eng = ServingEngine(cfg, params, max_batch=2, cache_len=64,
                        scheduler="none", step_time_fn=lambda n: 2e-3,
                        sched="priority")
    m = eng.run(_mono_contended_reqs(cfg), max_steps=4000)
    assert m["completed"] == 4 and m["preemptions"] == 0


def test_spilled_deadline_drop_frees_pages(mono):
    """A spilled request whose deadline lapses off-batch is rejected and its
    detached pages return to the pool (no leak, no restore)."""
    cfg, params = mono
    spec = WorkloadSpec(mean_input=6, mean_output=24, vocab_size=cfg.vocab_size,
                        max_input=12, max_output=30, seed=0)
    rs = sample_requests(spec, [0.0, 0.005], with_prompts=True)
    rs[0].priority, rs[0].deadline = 0, 0.02  # dies while spilled
    rs[1].priority = 5
    eng = ServingEngine(cfg, params, max_batch=1, cache_len=64,
                        scheduler="none", step_time_fn=lambda n: 2e-3,
                        kv_page_size=PS, sched="priority")
    m = eng.run(rs, max_steps=4000)
    assert m["preemptions"] == 1 and m["restores"] == 0
    assert m["completed"] == 1 and m["rejected"] == 1
    assert rs[0].rejected and rs[0].preemptions == 1
    assert m["kv_pages"]["pages_in_use"] == 0


# ---------------------------------------------------------------------------
# disagg executor: shard-affine spill/restore
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def dsv2():
    cfg = get_config("dsv2-lite-reduced")
    from repro.core.aebs import ReplicaLayout

    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
    return cfg, params, layout


def _disagg_engine(cfg, params, layout, sched, **kw):
    return ServingEngine(
        cfg, params, max_batch=4, cache_len=64, layout=layout,
        scheduler="aebs", capacity_tokens=64,
        executor="disagg", n_attn=2, n_prefill=1, prefill_chunk=4,
        step_time_fn=lambda n: 2e-3, kv_page_size=PS, sched=sched, **kw,
    )


def _disagg_contended_reqs(cfg):
    spec = WorkloadSpec(mean_input=6, mean_output=24, vocab_size=cfg.vocab_size,
                        max_input=12, max_output=30, seed=0)
    rs = sample_requests(spec, np.linspace(0, 0.001, 6), with_prompts=True)
    for r in rs[:4]:
        r.priority, r.tenant, r.ttft_slo = 0, "batch", 10.0
    for r in rs[4:]:
        r.priority, r.tenant, r.ttft_slo = 5, "chat", 0.015
        r.arrival += 0.01
    return rs


def test_disagg_preempted_streams_bit_identical(dsv2):
    """Spill/restore across the batch-sharded attention pool: restores are
    shard-affine (page ids are pool-local), and streams match the
    uninterrupted FIFO run bit-for-bit."""
    cfg, params, layout = dsv2
    runs = {}
    for sched in ("fifo", "priority"):
        eng = _disagg_engine(cfg, params, layout, sched)
        m = eng.run(_disagg_contended_reqs(cfg), max_steps=4000)
        assert m["completed"] == 6
        runs[sched] = (m, _streams(eng))
    m_prio, s_prio = runs["priority"]
    assert m_prio["preemptions"] >= 1 and m_prio["restores"] >= 1
    assert s_prio == runs["fifo"][1]
    assert m_prio["slo"]["attainment"] > runs["fifo"][0]["slo"]["attainment"]
    assert m_prio["kv_pages"]["pages_in_use"] == 0


def test_disagg_attn_loss_while_spilled_replays_bit_identical(dsv2):
    """An attention-shard loss lands *while requests sit spilled*: the
    re-shard rebuilds the page pools, dissolving the detached payloads, so
    restores downgrade to the deterministic replay path — streams still
    bit-identical to the uninterrupted fault-free baseline."""
    from repro.serving.faults import DEVICE_LOSS, FaultPlan, FaultSpec, RetryPolicy

    cfg, params, layout = dsv2
    base = _disagg_engine(cfg, params, layout, "fifo")
    base.run(_disagg_contended_reqs(cfg), max_steps=4000)
    ref = _streams(base)
    assert len(ref) == 6

    # the chat burst preempts around step 5 (clock 0.01 / 2 ms steps) and
    # holds the spill until ~step 35 — step 12 is mid-spill-window
    plan = FaultPlan(faults=[FaultSpec(DEVICE_LOSS, pool="attn", index=1,
                                       at_step=12)], seed=0)
    eng = _disagg_engine(cfg, params, layout, "priority", fault_plan=plan,
                         retry_policy=RetryPolicy(recovery_charge_s=0.01))
    m = eng.run(_disagg_contended_reqs(cfg), max_steps=4000)
    assert m["completed"] == 6
    assert m["preemptions"] >= 1 and m["restores"] >= 1
    assert m.get("spill_replays", 0) >= 1  # the payloads really dissolved
    assert m["faults"]["detected"] == 1 and m["faults"]["recoveries"] == 1
    assert _streams(eng) == ref


# ---------------------------------------------------------------------------
# Real multi-device variant (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

PREEMPT_FAULT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.faults import DEVICE_LOSS, FaultPlan, FaultSpec, RetryPolicy
from repro.serving.request import WorkloadSpec, sample_requests

assert len(jax.devices()) == 8
cfg = get_config("dsv2-lite-reduced")
params = model_mod.init_params(cfg, 0)
layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
spec = WorkloadSpec(mean_input=6, mean_output=24, vocab_size=cfg.vocab_size,
                    max_input=12, max_output=30, seed=0)

def reqs():
    rs = sample_requests(spec, np.linspace(0, 0.001, 6), with_prompts=True)
    for r in rs[:4]:
        r.priority, r.tenant = 0, "batch"
    for r in rs[4:]:
        r.priority, r.tenant = 5, "chat"
        r.arrival += 0.01
    return rs

def engine(sched, plan=None):
    return ServingEngine(cfg, params, max_batch=4, cache_len=64, layout=layout,
                         scheduler="aebs", capacity_tokens=64,
                         executor="disagg", n_attn=2, n_prefill=1,
                         prefill_chunk=4, step_time_fn=lambda n: 2e-3,
                         kv_page_size=16, sched=sched, fault_plan=plan,
                         retry_policy=RetryPolicy(recovery_charge_s=0.01))

base = engine("fifo")
base.run(reqs(), max_steps=4000)
ref = {r.rid: tuple(r.tokens_out) for r in base.completed}
assert len(ref) == 6

# kill a real attention device mid-spill-window: detached payloads dissolve
# and the preempted requests restore by deterministic replay
plan = FaultPlan(faults=[FaultSpec(DEVICE_LOSS, pool="attn", index=1,
                                   at_step=12)], seed=0)
eng = engine("priority", plan)
m = eng.run(reqs(), max_steps=4000)
got = {r.rid: tuple(r.tokens_out) for r in eng.completed}
assert got == ref, "preempted streams diverged after attention loss"
assert m["preemptions"] >= 1 and m["restores"] >= 1, m
assert m.get("spill_replays", 0) >= 1, m
assert m["faults"]["detected"] == 1 and m["faults"]["recoveries"] == 1, m["faults"]
print("PREEMPT_FAULTS_OK", m["preemptions"], m["restores"], m["spill_replays"])
"""


@pytest.mark.subprocess
def test_preempt_attn_kill_multidevice_subprocess():
    """8 physically distinct devices: priority preemption spills KV on a real
    sharded attention pool, the shard hosting the spill dies, and every
    stream still matches the uninterrupted single-pool-loss-free baseline."""
    from tests.test_disagg import run_forced_device_subprocess

    run_forced_device_subprocess(PREEMPT_FAULT_SCRIPT, marker="PREEMPT_FAULTS_OK")
