"""Config registry, parameter accounting (Table 1), input specs, shape skips."""

import jax.numpy as jnp
import pytest

from repro.configs import (
    ASSIGNED,
    REGISTRY,
    SHAPES,
    get_config,
    input_specs,
    shape_supported,
)

EXPECTED = {
    "gemma-7b": dict(family="dense", num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16, d_ff=24576, vocab_size=256_000),
    "yi-34b": dict(family="dense", num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8, d_ff=20480, vocab_size=64_000),
    "pixtral-12b": dict(family="vlm", num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, d_ff=14336, vocab_size=131_072),
    "falcon-mamba-7b": dict(family="ssm", num_layers=64, d_model=4096, ssm_state=16, vocab_size=65_024),
    "gemma2-2b": dict(family="dense", num_layers=26, d_model=2304, num_heads=8, num_kv_heads=4, d_ff=9216, vocab_size=256_000),
    "phi4-mini-3.8b": dict(family="dense", num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8, d_ff=8192, vocab_size=200_064),
    "qwen2-moe-a2.7b": dict(family="moe", num_layers=24, d_model=2048, num_heads=16, num_kv_heads=16, d_ff_expert=1408, vocab_size=151_936, num_experts=60, top_k=4, num_shared_experts=4),
    "zamba2-2.7b": dict(family="hybrid", num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32_000, ssm_state=64),
    "whisper-tiny": dict(family="audio", num_layers=4, d_model=384, num_heads=6, num_kv_heads=6, d_ff=1536, vocab_size=51_865),
    "phi3.5-moe-42b-a6.6b": dict(family="moe", num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, d_ff_expert=6400, vocab_size=32_064, num_experts=16, top_k=2),
}


def test_all_assigned_present():
    assert set(EXPECTED) == set(ASSIGNED)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_exact_dims(name):
    cfg = REGISTRY[name]
    for k, v in EXPECTED[name].items():
        assert getattr(cfg, k) == v, (name, k, getattr(cfg, k), v)
    assert cfg.source  # every config cites its source


def test_expert_memory_dominates():
    """Table 1: expert params dominate MoE model memory (≥85% for big MoE)."""
    assert REGISTRY["phi3.5-moe-42b-a6.6b"].expert_param_fraction() > 0.9
    assert REGISTRY["qwen2-moe-a2.7b"].expert_param_fraction() > 0.85
    assert REGISTRY["scaled-ds-2"].expert_param_fraction() > 0.95
    assert REGISTRY["yi-34b"].expert_param_fraction() == 0.0


@pytest.mark.parametrize("name", sorted(ASSIGNED))
def test_reduced_constraints(name):
    r = get_config(name + "-reduced")
    assert r.num_layers <= 2
    assert r.d_model <= 512
    assert r.num_experts <= 4
    assert r.family == REGISTRY[name].family


def test_long_context_skips():
    long = SHAPES["long_500k"]
    runs = [a for a in ASSIGNED if shape_supported(REGISTRY[a], long)[0]]
    assert sorted(runs) == ["falcon-mamba-7b", "gemma2-2b", "zamba2-2.7b"]
    ok, why = shape_supported(REGISTRY["yi-34b"], long)
    assert not ok and "sub-quadratic" in why


def test_combo_count():
    n = sum(
        1
        for a in ASSIGNED
        for s in SHAPES.values()
        if shape_supported(REGISTRY[a], s)[0]
    )
    assert n == 33  # 10×3 + 3 long-context


@pytest.mark.parametrize("name", sorted(ASSIGNED))
@pytest.mark.parametrize("shape_name", sorted(SHAPES))
def test_input_specs_abstract(name, shape_name):
    cfg, shape = REGISTRY[name], SHAPES[shape_name]
    if not shape_supported(cfg, shape)[0]:
        pytest.skip("unsupported combo")
    specs = input_specs(cfg, shape)
    assert "tokens" in specs
    B = shape.global_batch
    if shape.kind == "decode":
        assert specs["tokens"].shape == (B, 1)
        assert any(k.startswith(("kv_", "ssm_")) for k in specs)
    else:
        assert specs["tokens"].shape == (B, shape.seq_len)
    for v in specs.values():
        assert isinstance(v, type(specs["tokens"]))  # ShapeDtypeStruct: no allocation
    if cfg.family == "audio" and shape.kind != "decode":
        assert specs["encoder_frames"].shape == (B, cfg.encoder_seq, cfg.d_model)
    if cfg.attn_pattern == "local_global" and shape.kind == "decode":
        W = min(shape.seq_len, cfg.sliding_window)
        assert specs["kv_k_local"].shape[2] == W


def test_kv_bytes_per_token():
    cfg = REGISTRY["yi-34b"]
    assert cfg.kv_bytes_per_token() == 60 * 2 * 8 * 128 * 2
    assert REGISTRY["falcon-mamba-7b"].kv_bytes_per_token() == 0
