"""Speculative multi-token decode: acceptance bookkeeping, verify-step
bit-exactness, and stream equality across executors.

The invariant under test everywhere: with greedy acceptance, the emitted
stream is *bit-identical to non-speculative greedy decode by construction*,
whatever the draft model proposes.  Property tests pin the host-side
bookkeeping (accepted length, paged high-water marks); the model-level tests
pin ``decode_step_verify`` against k sequential ``decode_step`` calls on a
dense and an MoE config; the engine tests pin end-to-end streams with
speculation on vs off, mono and disaggregated.

Property tests import through the optional-hypothesis shim (tests/_hypo.py)
so the module collects cleanly when hypothesis is absent."""

import jax.numpy as jnp
import numpy as np
import pytest

from _hypo import given, settings, st

from repro.configs import get_config
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import WorkloadSpec, sample_requests

DENSE = "phi4-mini-3.8b-reduced"
MOE = "dsv2-lite-reduced"


# ---------------------------------------------------------------------------
# acceptance bookkeeping (hypothesis properties)
# ---------------------------------------------------------------------------
def _accept(drafts, greedy, w):
    """Reference acceptance rule (the engine's inline loop): longest prefix
    of drafts matching the verify argmaxes, capped at ``w - 1`` — a verify
    round always emits at least 1 and at most ``w`` tokens."""
    a = 0
    while a < w - 1 and drafts[a] == greedy[a]:
        a += 1
    return a


@settings(max_examples=200, deadline=None)
@given(st.data())
def test_accepted_length_is_longest_common_prefix(data):
    w = data.draw(st.integers(1, 8), label="w")
    drafts = data.draw(st.lists(st.integers(0, 3), min_size=w - 1, max_size=w - 1))
    greedy = data.draw(st.lists(st.integers(0, 3), min_size=w, max_size=w))
    a = _accept(drafts, greedy, w)
    # independent spec: first index where draft and verify argmax disagree
    lcp = next((i for i in range(w - 1) if drafts[i] != greedy[i]), w - 1)
    assert a == lcp
    assert 1 <= a + 1 <= w
    # emitted tokens are verify argmaxes only — never raw draft proposals
    emitted = greedy[: a + 1]
    assert len(emitted) == a + 1
    for j in range(a):  # accepted drafts agree with what was emitted
        assert emitted[j] == drafts[j]


@settings(max_examples=100, deadline=None)
@given(st.data())
def test_bookkeeping_invariants_over_accept_reject_sequences(data):
    """Drive the real paged bookkeeping through arbitrary accept/reject
    rounds: after every round the high-water mark equals
    ``input_len + generated`` exactly (ensure covers the verify extent,
    truncate clamps back past the rejected rows), and ``generated`` is
    strictly monotone — every verify round emits at least one token."""
    cache_len, page, k = 64, 8, 3
    paged = PagedKVCache(max_batch=2, cache_len=cache_len, page_size=page)
    slot = data.draw(st.integers(0, 1), label="slot")
    input_len = data.draw(st.integers(1, 16), label="input_len")
    paged.ensure(slot, input_len - 1)
    pos, generated = input_len, 0
    rounds = data.draw(st.integers(1, 12), label="rounds")
    for _ in range(rounds):
        if pos >= cache_len - 2:
            break
        w = data.draw(st.integers(1, min(k + 1, cache_len - 2 - pos)))
        a = data.draw(st.integers(0, w - 1))  # accepted draft count
        paged.ensure(slot, pos + w - 1)  # back every verify row up front
        gained = a + 1
        prev = generated
        generated += gained
        pos += gained
        paged.truncate(slot, pos)  # clamp past the rejected rows
        assert generated > prev  # monotone: every round emits >= 1
        assert paged.hiwater[slot] == input_len + generated == pos
    paged.release(slot)
    assert paged.hiwater[slot] == 0


def test_truncate_rejects_negative():
    paged = PagedKVCache(max_batch=1, cache_len=32, page_size=8)
    with pytest.raises(ValueError):
        paged.truncate(0, -1)


# ---------------------------------------------------------------------------
# decode_step_verify vs k sequential decode_step calls (model level)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", [DENSE, MOE])
def test_verify_matches_sequential_decode(arch):
    """One verify call over ``[t0, d1..dk]`` must reproduce the k+1
    sequential ``decode_step`` results bit-for-bit when the drafts are the
    true greedy continuation (full accept): identical greedy tokens at every
    position and identical KV rows written."""
    cfg = get_config(arch)
    assert model_mod.supports_speculative_decode(cfg)
    params = model_mod.init_params(cfg, 0)
    cache_len, prompt_len, c = 32, 6, 4
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size, size=(1, prompt_len), dtype=np.int32)
    logits0, caches = model_mod.prefill(params, jnp.asarray(prompt), cfg, cache_len)
    t0 = int(model_mod.greedy_token(logits0)[0])

    seq_caches = caches
    seq_logits, stream, cur = [], [t0], t0
    for j in range(c):
        lg, seq_caches = model_mod.decode_step(
            params, jnp.asarray([[cur]], jnp.int32), seq_caches,
            jnp.asarray([prompt_len + j]), cfg,
        )
        seq_logits.append(np.asarray(lg[0]))
        cur = int(model_mod.greedy_token(lg)[0])
        stream.append(cur)

    vtokens = jnp.asarray([stream[:c]], jnp.int32)  # [t0, g1, g2, g3]
    vlogits, vcaches = model_mod.decode_step_verify(
        params, vtokens, caches, jnp.asarray([prompt_len]), cfg,
        widths=jnp.asarray([c]),
    )
    vgreedy = np.asarray(jnp.argmax(vlogits, axis=-1))[0]
    assert list(vgreedy) == stream[1:], (list(vgreedy), stream)
    for j in range(c):
        np.testing.assert_allclose(
            np.asarray(vlogits[0, j], np.float32), seq_logits[j].astype(np.float32),
            rtol=2e-2, atol=2e-2,
        )
    # the KV rows the verify wrote equal the sequentially written ones
    upto = prompt_len + c
    for key in ("kv_k", "kv_v"):
        if key in vcaches:
            np.testing.assert_array_equal(
                np.asarray(vcaches[key][:, :, :upto]),
                np.asarray(seq_caches[key][:, :, :upto]),
            )


@pytest.mark.parametrize("arch", [DENSE, MOE])
def test_verify_prefix_valid_under_rejection(arch):
    """With deliberately wrong drafts from position j on, verify rows up to
    and including j still argmax to the true greedy tokens — the acceptance
    scan can trust every row it reads up to the first mismatch."""
    cfg = get_config(arch)
    params = model_mod.init_params(cfg, 0)
    cache_len, prompt_len, c = 32, 5, 4
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=(1, prompt_len), dtype=np.int32)
    logits0, caches = model_mod.prefill(params, jnp.asarray(prompt), cfg, cache_len)
    t0 = int(model_mod.greedy_token(logits0)[0])

    seq_caches, stream, cur = caches, [t0], t0
    for j in range(c):
        lg, seq_caches = model_mod.decode_step(
            params, jnp.asarray([[cur]], jnp.int32), seq_caches,
            jnp.asarray([prompt_len + j]), cfg,
        )
        cur = int(model_mod.greedy_token(lg)[0])
        stream.append(cur)

    # drafts: first one right, rest deliberately wrong (greedy + 1 mod V)
    bad = [(t + 1) % cfg.vocab_size for t in stream[2:c]]
    vtokens = jnp.asarray([[t0, stream[1]] + bad], jnp.int32)
    vlogits, _ = model_mod.decode_step_verify(
        params, vtokens, caches, jnp.asarray([prompt_len]), cfg,
        widths=jnp.asarray([c]),
    )
    vgreedy = np.asarray(jnp.argmax(vlogits, axis=-1))[0]
    # rows 0 and 1 read only true stream tokens -> must match greedy exactly
    assert int(vgreedy[0]) == stream[1]
    assert int(vgreedy[1]) == stream[2]
    a = _accept(list(np.asarray(vtokens[0, 1:])), list(vgreedy), c)
    assert a == 1  # draft 0 accepted, draft 1 (deliberately wrong) rejected


# ---------------------------------------------------------------------------
# engine-level stream equality (mono, in-process)
# ---------------------------------------------------------------------------
def _chat_reqs(cfg, n=4):
    spec = WorkloadSpec(
        mean_input=6, mean_output=12, vocab_size=cfg.vocab_size, seed=3
    )
    return sample_requests(spec, np.linspace(0, 0.005, n), with_prompts=True)


def _mono(cfg, params, **kw):
    eng = ServingEngine(
        cfg, params, max_batch=4, cache_len=64, scheduler="none",
        n_prefill=1, prefill_chunk=4, step_time_fn=lambda n: 2e-3, **kw,
    )
    m = eng.run(_chat_reqs(cfg))
    return {r.rid: tuple(r.tokens_out) for r in eng.completed}, m


@pytest.fixture(scope="module")
def dense_pair():
    cfg = get_config(DENSE)
    return cfg, model_mod.init_params(cfg, 0)


def test_spec_streams_match_greedy_mono(dense_pair):
    cfg, params = dense_pair
    base, mb = _mono(cfg, params)
    spec, ms = _mono(cfg, params, draft_config=cfg, spec_k=3)
    assert spec == base
    assert ms["spec"]["k"] == 3 and ms["spec"]["steps"] > 0
    # self-draft: every draft token accepted, >1 token gained per slot-step
    assert ms["spec"]["acceptance_rate"] == 1.0
    assert 1.0 < ms["spec"]["accepted_per_step"] <= 4.0
    # speculation takes fewer verify rounds than greedy takes decode steps
    assert ms["spec"]["steps"] < mb["tokens"]


def test_spec_streams_match_greedy_paged(dense_pair):
    cfg, params = dense_pair
    base, _ = _mono(cfg, params)
    spec, _ = _mono(cfg, params, draft_config=cfg, spec_k=3, kv_page_size=16)
    assert spec == base


def test_cross_architecture_draft_still_bit_exact(dense_pair):
    """A different-architecture draft (independently initialised — terrible
    acceptance) changes speed only, never the stream."""
    cfg, params = dense_pair
    dcfg = get_config(MOE)
    assert dcfg.vocab_size == cfg.vocab_size
    base, _ = _mono(cfg, params)
    spec, m = _mono(cfg, params, draft_config=dcfg, spec_k=2)
    assert spec == base
    assert m["spec"]["acceptance_rate"] < 1.0  # random draft: rejections real


def test_spec_requires_draft_and_verify_support(dense_pair):
    cfg, params = dense_pair
    with pytest.raises(ValueError):
        ServingEngine(cfg, params, max_batch=2, cache_len=32, spec_k=2)
    with pytest.raises(ValueError):
        ServingEngine(
            cfg, params, max_batch=2, cache_len=32, spec_k=-1, draft_config=cfg
        )


# ---------------------------------------------------------------------------
# autoscaler: acceptance rate feeds decode demand
# ---------------------------------------------------------------------------
def test_autoscaler_demand_tracks_acceptance_rate():
    """Halving the speculative acceptance rate must raise the observed
    decode demand: emitted tokens are discounted by tokens-per-verify-step,
    so the same token throughput at half the acceptance means twice the
    decode steps the pools must provision for."""
    from repro.core.scaling import PerfModel
    from repro.serving.controller import AutoScaler

    pm = PerfModel(get_config("dsv2-lite"), s_ctx=512)

    def demand_at(acc):
        sc = AutoScaler(pm, slo=0.1, window=100.0)
        for t in range(10):
            sc.observe(float(t), tokens=32.0, accepted_per_step=acc)
        return sc.demand(10.0)

    d4, d2, d1 = demand_at(4.0), demand_at(2.0), demand_at(1.0)
    assert d2 == pytest.approx(2 * d4)
    assert d1 == pytest.approx(2 * d2)
    # no speculation (0.0) is the undiscounted baseline, same as acceptance 1
    assert demand_at(0.0) == pytest.approx(d1)
    # engine-sampled fallback: actuate() stores metrics()["spec"] acceptance,
    # which then discounts observations that carry no per-step rate
    sc = AutoScaler(pm, slo=0.1, window=100.0)
    sc._spec_accept_rate = 4.0
    for t in range(10):
        sc.observe(float(t), tokens=32.0)
    assert sc.demand(10.0) == pytest.approx(d4)


# ---------------------------------------------------------------------------
# disaggregated executor (forced 8-device subprocess)
# ---------------------------------------------------------------------------
SPEC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
from repro.configs import get_config
from repro.core.aebs import ReplicaLayout
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.request import WorkloadSpec, sample_requests

cfg = get_config("dsv2-lite-reduced")
params = model_mod.init_params(cfg, 0)
layout = ReplicaLayout.round_robin(cfg.num_experts, 2, 3)
spec = WorkloadSpec(mean_input=6, mean_output=12, vocab_size=cfg.vocab_size, seed=3)

def run(executor, **kw):
    eng = ServingEngine(
        cfg, params, layout=layout, max_batch=4, cache_len=64,
        scheduler="aebs", capacity_tokens=64, executor=executor,
        n_attn=2 if executor == "disagg" else 1, n_prefill=1,
        prefill_chunk=4, step_time_fn=lambda n: 2e-3, **kw,
    )
    reqs = sample_requests(spec, np.linspace(0, 0.005, 4), with_prompts=True)
    m = eng.run(reqs)
    return {r.rid: tuple(r.tokens_out) for r in eng.completed}, m

base_mono, _ = run("mono")
base_dis, _ = run("disagg")
spec_dis, md = run("disagg", draft_config=cfg, spec_k=3)
spec_mono, _ = run("mono", draft_config=cfg, spec_k=3)
assert base_dis == base_mono, "disagg greedy diverged from mono"
assert spec_dis == base_dis, "disagg speculation changed the stream"
assert spec_mono == base_mono, "mono speculation changed the stream"
assert md["spec"]["accepted_per_step"] > 1.0, md["spec"]
assert md["transfer_bytes_per_step"] > 0, "verify exchange not measured"
print("SPEC_DISAGG_OK", md["spec"])
"""


@pytest.mark.subprocess
def test_spec_disagg_streams_subprocess():
    """MoE + two-pool executor: speculative streams bit-identical to greedy
    on both executors, verify exchange telemetry live."""
    from tests.test_disagg import run_forced_device_subprocess

    run_forced_device_subprocess(SPEC_SCRIPT, marker="SPEC_DISAGG_OK")
