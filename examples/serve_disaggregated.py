"""Disaggregated serving on 8 (host) devices — the Janus architecture live.

Two demonstrations, both REAL multi-device execution on CPU host devices:

A. **Three-pool engine (pool mode)** — ``ServingEngine(executor="disagg")``
   serves a continuous-batching request stream with chunked prompt prefill
   on a 2-device prefill pool, attention stages on a 2-device attention
   pool, and expert stages on a 4-device MoE pool.  Admission is pipelined:
   each prompt streams chunk-by-chunk from the prefill pool into the
   attention pool's batch-sharded KV caches (slot lifecycle reserved →
   prefilling → active), so decode never stalls on a long prompt; every
   decode layer performs the explicit activation hand-off whose pattern
   (case-1 / case-2) is chosen per step by the adaptive two-phase model.
   Telemetry shows the regime, bytes moved, AEBS ``a_max``, TTFT and the
   (zero) decode-stall time.  Mid-run the autoscaling path is exercised for
   real: one ``reconfigure`` call rescales 2P2A4E → 1P3A4E — the prefill
   pool shrinks, the attention pool grows, the MoE pool (and its pinned
   expert weights) stays untouched, and the in-flight KV caches are
   preserved.

B. **SPMD deployment (full model)** — the production mapping (DESIGN.md §2):
   a (data=2, model=4) mesh where the model axis is the MoE pool; the
   scheduled expert-parallel decode step serves a token stream end-to-end.

Run:  PYTHONPATH=src python examples/serve_disaggregated.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.models import model as model_mod
from repro.launch.mesh import use_mesh
from repro.serving.engine import ServingEngine
from repro.serving.request import WorkloadSpec, sample_requests
from repro.serving.trace import poisson_arrivals


def pool_mode_demo():
    print("=== A. three-pool engine: 2 prefill + 2 attention + 4 MoE devices ===")
    cfg = get_config("dsv2-lite-reduced")
    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 4, 2)

    eng = ServingEngine(
        cfg, params, max_batch=6, cache_len=64, layout=layout,
        scheduler="aebs", capacity_tokens=64,
        executor="disagg", n_attn=2, n_prefill=2, prefill_chunk=8,
    )
    pools = eng.disagg.pools
    print(f"  pools: prefill={[d.id for d in pools.prefill_devices]} "
          f"attn={[d.id for d in pools.attn_devices]} "
          f"moe={[d.id for d in pools.moe_devices]} (admission={eng.admission})")
    spec = WorkloadSpec(mean_input=12, mean_output=12, vocab_size=cfg.vocab_size,
                        max_input=32, max_output=16, seed=0)
    reqs = sample_requests(spec, poisson_arrivals(100.0, 0.12, seed=0)[:12], with_prompts=True)

    t0 = time.perf_counter()
    m = eng.run(reqs[:6])
    print(f"  phase 1 (2P2A4E): served 6 requests in {time.perf_counter()-t0:.1f}s wall "
          f"({m.get('prefill_chunks', 0)} prompt chunks streamed, "
          f"decode stall {m['decode_stall_time']:.3f}s)")

    # one call, three independent pools: prefill shrinks, attention grows,
    # MoE (and its pinned expert weights) untouched
    relower = eng.reconfigure(n_attn=3, n_prefill=1)
    print(f"  reconfigure 2P2A4E → 1P3A4E: re-lowered pools {relower} "
          "(KV caches re-sharded in place, expert weights untouched)")

    t0 = time.perf_counter()
    m = eng.run(reqs[6:])
    print(f"  phase 2 (1P3A4E): served 6 more in {time.perf_counter()-t0:.1f}s wall")
    print(f"  telemetry: regimes={m['regime_counts']} "
          f"bytes/step={m['transfer_bytes_per_step']:.0f} "
          f"a_max mean={m['amax_mean']:.2f} max={m['amax_max']}")
    print(f"  completed={m['completed']} tokens={m['tokens']} "
          f"ttft_mean={m['ttft_mean']*1e3:.1f}ms "
          f"tpot_mean={m['tpot_mean']*1e3:.1f}ms truncated={m['truncated']}")


def spmd_mode_demo():
    print("=== B. SPMD deployment (full reduced model, 2×4 mesh) ===")
    cfg = get_config("qwen2-moe-a2.7b-reduced")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 4, 2)
    moe_ctx = dict(
        dispatch="ep",
        ep_ctx=dict(mesh=mesh, dp_axes=("data",), model_axis="model", mode="scheduled"),
        scheduler=aebs_assign,
        layout_tables=layout.device_tables(),
        slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
        num_instances=4,
    )
    B, S = 4, 32
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    with use_mesh(mesh):
        _, caches = model_mod.prefill(params, tokens, cfg, cache_len=S + 16)
        step = jax.jit(
            lambda p, t, c, i: model_mod.decode_step(p, t, c, i, cfg, extra={"moe_ctx": moe_ctx})
        )
        t = tokens[:, -1:]
        t0 = time.perf_counter()
        toks = []
        for i in range(8):
            logits, caches = step(params, t, caches, jnp.int32(S + i))
            t = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            toks.append(int(t[0, 0]))
        jax.block_until_ready(t)
        wall = time.perf_counter() - t0
    print(f"  decoded 8 tokens/seq on {len(jax.devices())} devices in {wall*1e3:.0f} ms")
    print(f"  sample continuation (seq 0): {toks}")


if __name__ == "__main__":
    pool_mode_demo()
    spmd_mode_demo()
