"""Disaggregated serving on 8 (host) devices — the Janus architecture live.

Two demonstrations, both REAL multi-device execution on CPU host devices:

A. **Pool-mode m-to-n exchange (one MoE layer)** — m attention devices hold
   the hidden states; each of n MoE devices holds its expert replica slots.
   Activations are explicitly transferred attention→MoE (EGate: full
   activations, no routing metadata), every MoE device runs the SAME AEBS
   schedule (synchronisation-free redundancy, §3.4), computes only its local
   slots, and partial outputs are combined back on the attention side.  The
   script reports per-instance activated-expert counts and bytes moved, for
   AEBS vs random scheduling, and the two-phase comm model's predicted cost.

B. **SPMD deployment (full model)** — the production mapping (DESIGN.md §2):
   a (data=2, model=4) mesh where the model axis is the MoE pool; the
   scheduled expert-parallel decode step serves a token stream end-to-end.

Run:  PYTHONPATH=src python examples/serve_disaggregated.py
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.aebs import ReplicaLayout, aebs_assign, aebs_numpy
from repro.core.baselines import random_numpy
from repro.core.comm import H100, CommConfig, adaptive_two_phase, one_phase_cost
from repro.core.disagg import DevicePools
from repro.models import model as model_mod
from repro.models import moe as moe_mod
from repro.launch.mesh import use_mesh
from repro.models.moe_ep import moe_layer_ep


def pool_mode_demo():
    print("=== A. pool-mode m-to-n exchange (explicit transfers) ===")
    cfg = get_config("qwen2-moe-a2.7b-reduced")
    m, n = 2, 4  # 2 attention instances, 4 MoE instances
    pools = DevicePools.split(m, n)
    layout = ReplicaLayout.round_robin(cfg.num_experts, n, 2)  # 4 experts, 8 slots
    params = moe_mod.init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    slot_w = moe_mod.gather_slot_weights(params, jnp.asarray(layout.slot_to_expert.reshape(-1)))

    # expert slot weights pinned per MoE device
    C = layout.capacity
    w_per_dev = [
        {k: jax.device_put(v[g * C : (g + 1) * C], pools.moe_devices[g]) for k, v in slot_w.items()}
        for g in range(n)
    ]
    # hidden states live on the attention devices
    T, d = 24, cfg.d_model
    x_parts = [
        jax.device_put(
            jax.random.normal(jax.random.PRNGKey(1 + i), (T // m, d), jnp.float32) * 0.3,
            pools.attn_devices[i],
        )
        for i in range(m)
    ]

    @jax.jit
    def gate_and_schedule(x):
        gates, eids, _ = moe_mod.route(params["router"], x, cfg.top_k)
        slot_ids, load, _ = aebs_assign(eids, layout.device_tables(), n)
        return gates, slot_ids, load

    @jax.jit
    def expert_partial(x, gates, slot_ids, w, g):
        local = (slot_ids // C) == g
        return moe_mod.scatter_dispatch_ffn(
            x, slot_ids % C, gates.astype(x.dtype), C, 16, w,
            item_mask=local.reshape(-1),
        )

    bytes_moved = 0
    t0 = time.perf_counter()
    # phase 1 analogue: aggregate the attention instances' activations
    x_full = jnp.concatenate([jax.device_put(xp, pools.attn_devices[0]) for xp in x_parts])
    partials = []
    for g in range(n):
        # EGate: ship FULL activations to MoE instance g (no metadata)
        x_on_g = jax.device_put(x_full, pools.moe_devices[g])
        bytes_moved += x_full.size * x_full.dtype.itemsize
        gates, slot_ids, load = gate_and_schedule(x_on_g)  # redundant per instance
        partials.append(expert_partial(x_on_g, gates, slot_ids, w_per_dev[g], g))
    # combine back on the attention side
    y = sum(jax.device_put(p, pools.attn_devices[0]) for p in partials)
    y.block_until_ready()
    wall = time.perf_counter() - t0
    load_np = np.asarray(load)
    print(f"  m={m} attn × n={n} MoE devices; {bytes_moved/1e3:.0f} KB moved, {wall*1e3:.0f} ms wall")
    print(f"  per-instance activated experts (AEBS): {load_np.tolist()}  a_max={load_np.max()}")
    rng = np.random.default_rng(0)
    eids_host = np.asarray(
        moe_mod.route(params["router"], np.asarray(x_full), cfg.top_k)[1]
    )
    _, load_r, _ = random_numpy(eids_host, layout, rng)
    print(f"  per-instance activated experts (random): {load_r.tolist()}  a_max={load_r.max()}")

    c = CommConfig(n_attn=m, n_moe=n, bytes_per_token=2 * cfg.d_model, batch=T, hw=H100)
    t2, regime = adaptive_two_phase(c)
    print(f"  comm model: one-phase={one_phase_cost(c)*1e6:.1f}us  "
          f"two-phase={t2*1e6:.1f}us ({regime})")


def spmd_mode_demo():
    print("=== B. SPMD deployment (full reduced model, 2×4 mesh) ===")
    cfg = get_config("qwen2-moe-a2.7b-reduced")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = model_mod.init_params(cfg, 0)
    layout = ReplicaLayout.round_robin(cfg.num_experts, 4, 2)
    moe_ctx = dict(
        dispatch="ep",
        ep_ctx=dict(mesh=mesh, dp_axes=("data",), model_axis="model", mode="scheduled"),
        scheduler=aebs_assign,
        layout_tables=layout.device_tables(),
        slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
        num_instances=4,
    )
    B, S = 4, 32
    tokens = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0, cfg.vocab_size)
    with use_mesh(mesh):
        _, caches = model_mod.prefill(params, tokens, cfg, cache_len=S + 16)
        step = jax.jit(
            lambda p, t, c, i: model_mod.decode_step(p, t, c, i, cfg, extra={"moe_ctx": moe_ctx})
        )
        t = tokens[:, -1:]
        t0 = time.perf_counter()
        toks = []
        for i in range(8):
            logits, caches = step(params, t, caches, jnp.int32(S + i))
            t = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            toks.append(int(t[0, 0]))
        jax.block_until_ready(t)
        wall = time.perf_counter() - t0
    print(f"  decoded 8 tokens/seq on {len(jax.devices())} devices in {wall*1e3:.0f} ms")
    print(f"  sample continuation (seq 0): {toks}")


if __name__ == "__main__":
    pool_mode_demo()
    spmd_mode_demo()
