"""Fig. 11 reproduction: a 24-hour diurnal trace driven through the Janus
autoscaler vs SGLang / MegaScale-Infer / xDeepServe scaling policies.

Run:  PYTHONPATH=src python examples/autoscale_trace.py
"""

import numpy as np

from repro.configs import get_config
from repro.core.amax import MonteCarloAmax, make_routing_trace
from repro.core.comm import H100
from repro.core.scaling import PerfModel
from repro.serving.simulator import ClusterSimulator
from repro.serving.trace import diurnal_rate_profile


def sparkline(vals, width=72):
    blocks = "▁▂▃▄▅▆▇█"
    vals = np.asarray(vals, float)
    if len(vals) > width:
        idx = np.linspace(0, len(vals) - 1, width).astype(int)
        vals = vals[idx]
    lo, hi = vals.min(), vals.max()
    span = (hi - lo) or 1.0
    return "".join(blocks[int((v - lo) / span * (len(blocks) - 1))] for v in vals)


def main():
    cfg = get_config("dsv2-lite")
    trace = make_routing_trace(4096, cfg.num_experts, cfg.top_k, skew=1.0, seed=0)
    mc = MonteCarloAmax(trace, cfg.num_experts, trials=6)
    pm = PerfModel(cfg, hw=H100, amax_estimator=mc, slots_per_instance=12, s_ctx=512)
    sim = ClusterSimulator(pm, slo=0.2, n_max=32)

    t, rates = diurnal_rate_profile(
        hours=24, step_minutes=15.0, mean_rate=30.0, burst_peak_over_mean=7.5, seed=0
    )
    print("demand  (req/s):", sparkline(rates))
    res = sim.compare(t, rates, tokens_per_req=256.0)
    for name, r in res.items():
        gpus = [rec.total_gpus for rec in r.records]
        print(f"{name:11s} gpus:", sparkline(gpus))
    print()
    print(f"{'system':12s} {'gpu-hours':>10s} {'slo-attain':>10s} {'gpu range':>10s}")
    for name, r in res.items():
        gpus = [rec.total_gpus for rec in r.records]
        print(f"{name:12s} {r.gpu_hours:10.0f} {r.slo_attainment*100:9.0f}% {min(gpus):>4d}-{max(gpus)}")
    base = res["janus"].gpu_hours
    for name in ("sglang", "megascale", "xdeepserve"):
        print(f"janus saves {100*(1-base/res[name].gpu_hours):.0f}% GPU-hours vs {name}")


if __name__ == "__main__":
    main()
