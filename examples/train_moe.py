"""End-to-end driver: train a ~100M-parameter DeepSeek-style MoE for a few
hundred steps on CPU (synthetic structured data, full substrate: pipeline →
model → optimizer → checkpointing).

Run:  PYTHONPATH=src python examples/train_moe.py [--steps 300]
"""

import argparse
import dataclasses

from repro.configs import get_config
from repro.training.train_loop import train


def make_100m_config():
    """~100M-param MoE in the dsv2 family (8 experts, top-2, 4 layers)."""
    base = get_config("dsv2-lite")
    return dataclasses.replace(
        base,
        name="dsv2-100m",
        num_layers=4,
        d_model=512,
        num_heads=8,
        num_kv_heads=8,
        head_dim=64,
        vocab_size=32_000,
        num_experts=8,
        num_shared_experts=1,
        top_k=2,
        d_ff_expert=1024,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_100m")
    args = ap.parse_args()

    cfg = make_100m_config()
    print(f"training {cfg.name}: {cfg.total_params()/1e6:.0f}M params "
          f"({cfg.expert_param_fraction()*100:.0f}% in experts), "
          f"{args.steps} steps × {args.batch}×{args.seq} tokens")
    res = train(
        cfg,
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(50, args.steps // 4),
        log_every=10,
    )
    print(f"loss {res['first_loss']:.3f} → {res['final_loss']:.3f} "
          f"({res['wall_s']:.0f}s, {args.steps*args.batch*args.seq/res['wall_s']:.0f} tok/s)")
    assert res["final_loss"] < res["first_loss"], "training failed to reduce loss"


if __name__ == "__main__":
    main()
