"""Quickstart: the Janus pipeline end-to-end in two minutes on CPU.

1. Build a replica layout from a routing trace (placement, Alg. 3).
2. Schedule a decode batch with AEBS vs baselines (Alg. 1) — see a_max drop.
3. Ask the SLO scaler for the cheapest (n_a, n_e) deployment (Alg. 2).
4. Serve a few requests through the continuous-batching engine.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.configs import get_config
from repro.core.aebs import aebs_numpy
from repro.core.amax import MonteCarloAmax, amax_bound, make_routing_trace
from repro.core.baselines import random_numpy, token_hash_numpy
from repro.core.placement import build_layout
from repro.core.scaling import PerfModel, SLOScaler
from repro.models import model as model_mod
from repro.serving.engine import ServingEngine
from repro.serving.request import WorkloadSpec, sample_requests
from repro.serving.trace import poisson_arrivals


def main():
    print("=== 1. expert placement from a routing trace ===")
    cfg = get_config("dsv2-lite")
    E, k, n_e, C = cfg.num_experts, cfg.top_k, 8, 12
    trace = make_routing_trace(8192, E, k, skew=1.0, seed=0)
    layout = build_layout(trace, E, n_e, C)
    print(f"  {E} experts → {n_e} instances × {C} slots; "
          f"replicas per expert: min={layout.replica_counts.min()} max={layout.replica_counts.max()}")

    print("=== 2. AEBS vs baselines (batch of 256 tokens) ===")
    rng = np.random.default_rng(1)
    batch = trace[rng.integers(0, len(trace), 256)]
    a_aebs = aebs_numpy(batch, layout)[1].max()
    a_rand = random_numpy(batch, layout, rng)[1].max()
    a_tok = token_hash_numpy(batch, layout)[1].max()
    bound = amax_bound(n_e, 256, E, k, C)
    print(f"  a_max:  AEBS={a_aebs}  random={a_rand}  token-hash={a_tok}  (Eq.5 bound={bound})")

    print("=== 3. SLO-aware scaling ===")
    mc = MonteCarloAmax(trace, E, trials=6)
    pm = PerfModel(cfg, amax_estimator=mc, slots_per_instance=C, s_ctx=512)
    sc = SLOScaler(pm, n_max=16)
    for demand in (1000.0, 8000.0):
        best = sc.scale(demand, slo=0.2)
        print(f"  demand={demand:7.0f} tok/s → {best.n_a}A{best.n_e}E  "
              f"B*={best.batch:.0f}  TPOT={best.tpot*1000:.1f}ms  TPG={best.tpg:.0f} tok/s/gpu")

    print("=== 4. serve a small MoE with the scheduled path ===")
    rcfg = get_config("qwen2-moe-a2.7b-reduced")
    params = model_mod.init_params(rcfg, 0)
    rtrace = make_routing_trace(1024, rcfg.num_experts, rcfg.top_k, skew=0.8, seed=0)
    rlayout = build_layout(rtrace, rcfg.num_experts, 2, 3)
    spec = WorkloadSpec(mean_input=8, mean_output=16, vocab_size=rcfg.vocab_size,
                        max_input=24, max_output=32)
    reqs = sample_requests(spec, poisson_arrivals(40.0, 0.25, seed=2), with_prompts=True)
    eng = ServingEngine(rcfg, params, max_batch=4, cache_len=96, layout=rlayout, scheduler="aebs")
    m = eng.run(reqs)
    print(f"  served {m['completed']} requests, {m['tokens']} tokens, "
          f"TPOT mean={m['tpot_mean']*1000:.0f}ms p99={m['tpot_p99']*1000:.0f}ms")


if __name__ == "__main__":
    main()
