"""Baseline expert schedulers and system scaling policies (Janus §5.1).

Schedulers (drop-in replacements for :func:`repro.core.aebs.aebs_assign`):

* ``random_assign``      — MegaScale-Infer-style: uniformly random replica per
  activated expert (the paper implements MegaScale's scheduling as "random
  expert scheduling, a common strategy used in existing systems incl. EPLB").
* ``token_hash_assign``  — token balancing: each (token, choice) item picks a
  replica by hash/round-robin, equalising *token counts* per instance but not
  distinct activated-expert counts — the foil of §2.2/R2.

System scaling policies (used by the cluster simulator / Fig. 11):

* ``MonolithicPolicy``   — SGLang-style: scales in whole-model tiers.
* ``CoupledPolicy``      — MegaScale-Infer-style: restricts (n_a, n_e) to
  plans balancing attention and MoE side times (ratio-matched), coarser grid.
* ``FixedUnitPolicy``    — xDeepServe-style: scales in fixed 4-GPU units.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aebs import ReplicaLayout


# ---------------------------------------------------------------------------
# Scheduler baselines — jnp (jit-friendly; same signature as aebs_assign plus
# an optional key for the stochastic one)
# ---------------------------------------------------------------------------


def random_assign(
    eids: jax.Array,
    tables: Dict[str, jax.Array],
    num_instances: int,
    key: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Uniformly random replica per activated expert (deterministic per-step
    given ``key``; defaults to a fixed key so it stays sync-free)."""
    hosts = tables["expert_hosts"]  # [E, R]
    counts = tables["replica_counts"]
    slot_of = tables["slot_of"]
    E, R = hosts.shape
    if key is None:
        key = jax.random.PRNGKey(0)
    u = jax.random.uniform(key, (E,))
    sel = jnp.floor(u * counts.astype(jnp.float32)).astype(jnp.int32)
    sel = jnp.clip(sel, 0, jnp.maximum(counts - 1, 0))
    g = jnp.take_along_axis(hosts, sel[:, None], axis=1)[:, 0]  # [E]
    act_rep = slot_of[jnp.arange(E), jnp.maximum(g, 0)]
    slot_ids = act_rep[eids]
    load = _activated_load(eids, g, num_instances, E)
    return slot_ids, load, act_rep


# one replica per activated expert (chosen at random) → collapse-eligible
random_assign.single_active_replica = True


def token_hash_assign(
    eids: jax.Array,
    tables: Dict[str, jax.Array],
    num_instances: int,
    key: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Token balancing: item i of expert e takes replica (i mod R(e)).

    Tokens spread evenly over replicas, but every replica of an activated
    expert tends to be touched → the distinct-expert load is *not* minimised.
    """
    hosts = tables["expert_hosts"]
    counts = tables["replica_counts"]
    slot_of = tables["slot_of"]
    E = hosts.shape[0]
    T, k = eids.shape
    item = jnp.arange(T * k).reshape(T, k)
    sel = item % jnp.maximum(counts[eids], 1)
    g = jnp.take_along_axis(hosts[eids.reshape(-1)], sel.reshape(-1, 1), axis=1)[:, 0]
    slot_ids = slot_of[eids.reshape(-1), jnp.maximum(g, 0)].reshape(T, k)
    # load = distinct (expert, instance) activations per instance
    pair = eids.reshape(-1).astype(jnp.int64) * num_instances + g.astype(jnp.int64)
    pair_mask = jnp.zeros((E * num_instances,), bool).at[pair].set(True)
    load = pair_mask.reshape(E, num_instances).sum(axis=0).astype(jnp.int32)
    return slot_ids, load, jnp.full((E,), -1, jnp.int32)


def _activated_load(eids, g_of_expert, num_instances, E):
    act = jnp.zeros((E,), bool).at[eids.reshape(-1)].set(True)
    return (
        jnp.zeros((num_instances,), jnp.int32)
        .at[jnp.maximum(g_of_expert, 0)]
        .add(act.astype(jnp.int32))
    )


# ---------------------------------------------------------------------------
# Scheduler baselines — numpy (simulator fast path)
# ---------------------------------------------------------------------------


def random_numpy(eids: np.ndarray, layout: ReplicaLayout, rng: np.random.Generator):
    E, n_e = layout.num_experts, layout.num_instances
    act = np.zeros(E, bool)
    act[np.asarray(eids).reshape(-1)] = True
    act_rep = -np.ones(E, np.int64)
    load = np.zeros(n_e, np.int64)
    for e in np.nonzero(act)[0]:
        hs = layout.expert_hosts[e]
        hs = hs[hs >= 0]
        g = int(rng.choice(hs))
        act_rep[e] = layout.slot_of[e, g]
        load[g] += 1
    return act_rep[np.asarray(eids)], load, act_rep


def token_hash_numpy(eids: np.ndarray, layout: ReplicaLayout):
    eids = np.asarray(eids)
    T, k = eids.shape
    flat = eids.reshape(-1)
    item = np.arange(T * k)
    counts = np.maximum(layout.replica_counts[flat], 1)
    sel = item % counts
    g = layout.expert_hosts[flat, sel]
    slots = layout.slot_of[flat, np.maximum(g, 0)]
    load = np.zeros(layout.num_instances, np.int64)
    for gg in range(layout.num_instances):
        load[gg] = len(np.unique(flat[g == gg]))
    return slots.reshape(T, k), load, None


# ---------------------------------------------------------------------------
# System scaling policies (cluster simulator)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PolicyDecision:
    n_a: int
    n_e: int
    total_gpus: int
    feasible: bool


class MonolithicPolicy:
    """SGLang-style: whole-model replicas in power-of-two GPU tiers.

    A monolithic deployment must *fit the whole model* on its tier (the
    paper's motivating example: DeepSeek-V3 needs ≥16 H100s just to load),
    so tiers below the model's memory floor are infeasible."""

    def __init__(self, tier_sizes=(8, 16, 32, 64, 128)):
        self.tiers = tier_sizes

    def min_tier(self, scaler) -> int:
        cfg = scaler.model.cfg
        model_bytes = cfg.total_params() * cfg.bytes_per_param()
        floor = model_bytes / (0.6 * scaler.model.hw.mem_bytes)  # 40% for KV/act
        for t in self.tiers:
            if t >= floor:
                return t
        return self.tiers[-1]

    def decide(self, scaler, demand: float, slo: float) -> PolicyDecision:
        lo = self.min_tier(scaler)
        for total in self.tiers:
            if total < lo:
                continue
            # monolithic: attention and MoE share the same GPUs; model as a
            # balanced split of the tier for TPOT evaluation purposes
            n_e = max(scaler.n_e_min, total // 2)
            n_a = total - n_e
            if n_a < 1:
                continue
            r = scaler.evaluate(demand, slo, n_a, n_e)
            if r is not None and r.tpot <= slo:
                return PolicyDecision(n_a, n_e, n_a + n_e, True)
        t = self.tiers[-1]
        n_e = max(scaler.n_e_min, t // 2)
        return PolicyDecision(max(1, t - n_e), n_e, t, False)


class CoupledPolicy:
    """MegaScale-Infer-style: restrict plans to those balancing attention-side
    and MoE-side times (for pipelined execution).  Among SLO-feasible plans it
    picks the *most balanced* (then fewest GPUs) — which typically costs more
    GPUs than Janus's unconstrained min-GPU search; when no balanced feasible
    plan exists the balanced-but-violating plan with the lowest TPOT is used
    (the Fig. 8 SLO-violation regime)."""

    def __init__(self, tol: float = 0.3):
        self.tol = tol

    def _imbalance(self, r) -> float:
        return abs(r.t_attn - r.t_moe) / max(r.t_attn, r.t_moe, 1e-12)

    def decide(self, scaler, demand: float, slo: float) -> PolicyDecision:
        balanced_feasible = []
        feasible = []
        violating = []
        for n_a in range(1, scaler.n_max + 1):
            for n_e in range(scaler.n_e_min, scaler.n_max + 1):
                r = scaler.evaluate(demand, slo, n_a, n_e)
                if r is None:
                    continue
                imb = self._imbalance(r)
                if r.tpot <= slo:
                    feasible.append((imb, n_a + n_e, r))
                    if imb <= self.tol:
                        balanced_feasible.append((n_a + n_e, imb, r))
                elif imb <= self.tol:
                    violating.append((r.tpot, n_a + n_e, r))
        if balanced_feasible:
            _, _, r = min(balanced_feasible, key=lambda t: (t[0], t[1]))
            return PolicyDecision(r.n_a, r.n_e, r.n_a + r.n_e, True)
        if feasible:
            _, _, r = min(feasible, key=lambda t: (t[0], t[1]))  # most balanced
            return PolicyDecision(r.n_a, r.n_e, r.n_a + r.n_e, True)
        if violating:
            _, _, r = min(violating, key=lambda t: (t[0], t[1]))
            return PolicyDecision(r.n_a, r.n_e, r.n_a + r.n_e, False)
        return PolicyDecision(scaler.n_max, scaler.n_max, 2 * scaler.n_max, False)


class FixedUnitPolicy:
    """xDeepServe-style: scale in fixed units of ``unit`` GPUs, split evenly."""

    def __init__(self, unit: int = 4):
        self.unit = unit

    def decide(self, scaler, demand: float, slo: float) -> PolicyDecision:
        total = self.unit
        while total <= 2 * scaler.n_max:
            n_e = max(scaler.n_e_min, total // 2)
            n_a = total - n_e
            if n_a < 1:
                total += self.unit
                continue
            r = scaler.evaluate(demand, slo, n_a, n_e)
            if r is not None and r.tpot <= slo:
                return PolicyDecision(n_a, n_e, n_a + n_e, True)
            total += self.unit
        return PolicyDecision(scaler.n_max, scaler.n_max, 2 * scaler.n_max, False)
