"""Janus core: the paper's contribution as composable JAX modules.

  aebs       — Activated-Expert-Balanced Scheduling (Alg. 1)
  placement  — activation-aware replica allocation/placement (Alg. 3)
  scaling    — SLO-aware fine-grained scaler (Eq. 1–3, Alg. 2)
  amax       — balls-into-bins bound (Eq. 4–5) + Monte-Carlo estimator
  comm       — adaptive two-phase communication cost model
  baselines  — EPLB/random/token-hash schedulers + baseline scaling policies
  disagg     — attention/MoE pool abstraction
"""

from repro.core import aebs, amax, baselines, comm, disagg, placement, scaling  # noqa: F401
from repro.core.aebs import ReplicaLayout, aebs_assign, aebs_numpy  # noqa: F401
