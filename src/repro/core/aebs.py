"""Activated-Expert-Balanced Scheduling (AEBS) — Janus §3.4, Algorithm 1.

Given the per-token top-k *logical* expert ids and the replica layout
(which MoE instance hosts which expert replicas), AEBS picks one physical
replica per *activated* logical expert so that the maximum number of distinct
activated experts on any MoE instance (``a_max``) is minimised greedily:

  1. collect the set of activated logical experts (union over the batch);
  2. assign single-replica experts to their unique hosts;
  3. assign multi-replica experts to the currently least-loaded host
     (load = activated-expert count), deterministic tie-break by instance id;
  4. rewrite every token's routing from logical EIDs to physical replica slots.

The algorithm is deterministic in its inputs, which is what lets Janus run it
redundantly on every MoE instance with no cross-instance synchronisation
(§3.4 "synchronization-free scheduling").  We preserve that property: the
jnp implementation is a pure function of (eids, layout) and is intended to be
executed identically on every model-axis shard inside the jitted serve step.

Three implementations share one semantics:
  * :func:`aebs_assign`        — pure jnp (jit/vmap-able, runs inside serve_step)
  * :func:`aebs_numpy`         — host-side (fast path for the cluster simulator)
  * ``repro.kernels.aebs``     — the Pallas TPU kernel (paper's GPU-kernel analogue)
All are covered by equivalence tests.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

INVALID = jnp.int32(-1)


# ---------------------------------------------------------------------------
# Replica layout
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReplicaLayout:
    """Physical placement of expert replicas on MoE instances.

    Slots are numbered globally: slot of (instance g, local slot c) is
    ``g * C + c``.  ``slot_to_expert[g, c]`` is the logical expert hosted
    there (-1 for an empty slot).
    """

    num_experts: int  # E
    num_instances: int  # n_e
    capacity: int  # C (expert slots per instance)
    slot_to_expert: np.ndarray  # [n_e, C] int32, -1 = empty
    # derived tables (computed in __post_init__ equivalents below)
    expert_hosts: np.ndarray  # [E, R_max] int32 instance ids, -1 padded
    replica_counts: np.ndarray  # [E] int32
    slot_of: np.ndarray  # [E, n_e] int32 global slot id of e's replica on g, -1

    @staticmethod
    def build(slot_to_expert: np.ndarray, num_experts: int) -> "ReplicaLayout":
        slot_to_expert = np.asarray(slot_to_expert, np.int32)
        n_e, C = slot_to_expert.shape
        counts = np.zeros(num_experts, np.int32)
        slot_of = -np.ones((num_experts, n_e), np.int32)
        for g in range(n_e):
            for c in range(C):
                e = slot_to_expert[g, c]
                if e >= 0:
                    if slot_of[e, g] < 0:  # first replica of e on g wins
                        slot_of[e, g] = g * C + c
                        counts[e] += 1
        r_max = max(1, int(counts.max(initial=1)))
        hosts = -np.ones((num_experts, r_max), np.int32)
        for e in range(num_experts):
            gs = np.nonzero(slot_of[e] >= 0)[0]
            hosts[e, : len(gs)] = gs
        return ReplicaLayout(
            num_experts=num_experts,
            num_instances=n_e,
            capacity=C,
            slot_to_expert=slot_to_expert,
            expert_hosts=hosts,
            replica_counts=counts,
            slot_of=slot_of,
        )

    @staticmethod
    def round_robin(num_experts: int, num_instances: int, capacity: int) -> "ReplicaLayout":
        """Default layout: experts 0..E-1 dealt round-robin, leftover slots
        replicate the first experts (simple redundancy)."""
        total = num_instances * capacity
        seq = [e % num_experts for e in range(total)]
        stx = np.array(seq, np.int32).reshape(num_instances, capacity, order="F")
        # order='F': slot (g, c) = c * n_e + g  → experts striped across instances
        return ReplicaLayout.build(stx, num_experts)

    # -- device-side view ----------------------------------------------------
    def device_tables(self) -> Dict[str, jax.Array]:
        return {
            "expert_hosts": jnp.asarray(self.expert_hosts),
            "replica_counts": jnp.asarray(self.replica_counts),
            "slot_of": jnp.asarray(self.slot_of),
        }

    @property
    def total_slots(self) -> int:
        return self.num_instances * self.capacity


# ---------------------------------------------------------------------------
# jnp implementation (runs inside jitted serve steps)
# ---------------------------------------------------------------------------


def activated_mask(eids: jax.Array, num_experts: int) -> jax.Array:
    """Step 1 of the workflow: union of selected EIDs. eids [..., k] -> [E] bool."""
    flat = eids.reshape(-1)
    return jnp.zeros(num_experts, bool).at[flat].set(True)


def aebs_assign(
    eids: jax.Array,  # [T, k] int32 logical expert ids
    tables: Dict[str, jax.Array],  # from ReplicaLayout.device_tables()
    num_instances: int,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Algorithm 1.  Returns (slot_ids [T,k], load [n_e], act_rep [E]).

    ``slot_ids[t, j]`` is the *global physical slot* serving token t's j-th
    expert choice; ``load[g]`` the resulting activated-expert count on
    instance g (so ``a_max = load.max()``).
    """
    hosts = tables["expert_hosts"]  # [E, R]
    counts = tables["replica_counts"]  # [E]
    slot_of = tables["slot_of"]  # [E, n_e]
    E = hosts.shape[0]

    act = activated_mask(eids, E)  # [E]

    def assign_pass(carry, want_multi: bool):
        load, act_rep = carry

        def body(e, c):
            load, act_rep = c
            is_multi = counts[e] > 1
            eligible = act[e] & (is_multi == want_multi) & (counts[e] >= 1)
            host_row = hosts[e]  # [R]
            # masked argmin of load over this expert's hosts
            host_load = jnp.where(host_row >= 0, load[jnp.maximum(host_row, 0)], jnp.iinfo(jnp.int32).max)
            # deterministic tie-break: lowest replica index (argmin picks first)
            sel = jnp.argmin(host_load)
            g = host_row[sel]
            slot = slot_of[e, jnp.maximum(g, 0)]
            new_load = load.at[jnp.maximum(g, 0)].add(jnp.where(eligible, 1, 0))
            new_rep = act_rep.at[e].set(jnp.where(eligible, slot, act_rep[e]))
            return (jnp.where(eligible, new_load, load), new_rep)

        return jax.lax.fori_loop(0, E, body, (load, act_rep))

    load0 = jnp.zeros(num_instances, jnp.int32)
    rep0 = jnp.full((E,), INVALID)
    # pass 1: single-replica experts (their host is forced)
    load1, rep1 = assign_pass((load0, rep0), want_multi=False)
    # pass 2: multi-replica experts via least-loaded host
    load2, rep2 = assign_pass((load1, rep1), want_multi=True)

    slot_ids = rep2[eids]  # [T, k]
    return slot_ids, load2, rep2


# AEBS activates exactly one physical replica per activated logical expert —
# the property that lets the grouped dispatch collapse replica slots back to
# logical experts (see repro.models.moe.scheduler_is_single_replica).
aebs_assign.single_active_replica = True


def amax_of(load: jax.Array) -> jax.Array:
    return jnp.max(load)


# ---------------------------------------------------------------------------
# Host-side (numpy) implementation — used by the cluster simulator
# ---------------------------------------------------------------------------


def aebs_numpy(
    eids: np.ndarray, layout: ReplicaLayout
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Reference host implementation of Algorithm 1 (same semantics)."""
    E, n_e = layout.num_experts, layout.num_instances
    act = np.zeros(E, bool)
    act[np.asarray(eids).reshape(-1)] = True
    load = np.zeros(n_e, np.int64)
    act_rep = -np.ones(E, np.int64)
    activated = np.nonzero(act)[0]
    singles = [e for e in activated if layout.replica_counts[e] == 1]
    multis = [e for e in activated if layout.replica_counts[e] > 1]
    for e in singles:
        g = int(layout.expert_hosts[e, 0])
        act_rep[e] = layout.slot_of[e, g]
        load[g] += 1
    for e in multis:  # ascending expert id = deterministic order
        hs = layout.expert_hosts[e]
        hs = hs[hs >= 0]
        g = int(hs[np.argmin(load[hs])])
        act_rep[e] = layout.slot_of[e, g]
        load[g] += 1
    slot_ids = act_rep[np.asarray(eids)]
    return slot_ids, load, act_rep
