"""Activation-aware replica allocation & placement — Janus §3.5 + Appendix B.

Two stages:
  1. :func:`allocate_replicas` — replica *counts*: S = n_e·C slots seat one
     replica of each of the E experts, then the S−E redundant slots go
     iteratively to the expert with the largest per-replica load
     l(e) = c(e)/R(e).
  2. :func:`place_replicas` — Algorithm 3: greedy min–max co-activation
     placement with bounded swaps (the min–max assignment of Eq. 7 is NP-hard
     via unrelated-machines scheduling, so a heuristic).

Returns a :class:`repro.core.aebs.ReplicaLayout` consumed by the schedulers.
"""

from __future__ import annotations

import heapq
from typing import Optional, Tuple

import numpy as np

from repro.core.aebs import ReplicaLayout


def allocate_replicas(
    activation_counts: np.ndarray,  # c(e) over a sliding window
    num_instances: int,
    capacity: int,
) -> np.ndarray:
    """Replica count R(e) per expert (Appendix B "Replica count")."""
    E = len(activation_counts)
    total_slots = num_instances * capacity
    if total_slots < E:
        raise ValueError(f"{total_slots} slots cannot seat {E} experts")
    R = np.ones(E, np.int64)
    c = np.asarray(activation_counts, np.float64) + 1e-9
    # max-heap on per-replica load l(e) = c(e) / R(e); an expert holds at most
    # one replica per instance, so R(e) ≤ n_e
    heap = [(-c[e] / 1.0, e) for e in range(E)]
    heapq.heapify(heap)
    extra = total_slots - E
    while extra > 0 and heap:
        negl, e = heapq.heappop(heap)
        if R[e] >= num_instances:
            continue
        R[e] += 1
        extra -= 1
        if R[e] < num_instances:
            heapq.heappush(heap, (-c[e] / R[e], e))
    return R


def place_replicas(
    replica_counts: np.ndarray,  # R(e)
    coactivation: np.ndarray,  # a(e, e') [E, E]
    num_instances: int,
    capacity: int,
    loads: Optional[np.ndarray] = None,  # per-replica load l_i for ordering
) -> ReplicaLayout:
    """Algorithm 3: place replicas in descending load order; each goes to the
    feasible instance with the least added co-activation pressure; when no
    instance has both a free slot and no copy of the expert, do a bounded
    swap."""
    E = len(replica_counts)
    n_e, C = num_instances, capacity
    if replica_counts.sum() > n_e * C:
        raise ValueError("more replicas than slots")

    # replica list: (load, expert), descending load
    if loads is None:
        loads = np.ones(E, np.float64)
    replicas = []
    for e in range(E):
        per = loads[e] / max(1, replica_counts[e])
        replicas += [(per, e)] * int(replica_counts[e])
    replicas.sort(key=lambda t: -t[0])

    placed = [[] for _ in range(n_e)]  # experts per instance
    slots_free = [C] * n_e
    has = np.zeros((E, n_e), bool)

    def coact_penalty(e: int, g: int) -> float:
        return float(sum(coactivation[e, j] for j in placed[g]))

    for _, e in replicas:
        feas = [g for g in range(n_e) if slots_free[g] > 0 and not has[e, g]]
        if feas:
            g_star = min(feas, key=lambda g: (coact_penalty(e, g), g))
            placed[g_star].append(e)
            slots_free[g_star] -= 1
            has[e, g_star] = True
            continue
        # no feasible slot: bounded swap (lines 11–18). Find g without e and a
        # victim j on g, plus an instance h with a free slot that lacks j.
        best = None  # (delta, g, j, h)
        for g in range(n_e):
            if has[e, g]:
                continue
            for j in placed[g]:
                for h in range(n_e):
                    if slots_free[h] <= 0 or has[j, h] or h == g:
                        continue
                    delta = (
                        coact_penalty(e, g)
                        - coactivation[e, j]  # j leaves g
                        - sum(coactivation[j, jj] for jj in placed[g] if jj != j)
                        + coact_penalty(j, h)
                    )
                    if best is None or delta < best[0]:
                        best = (delta, g, j, h)
        if best is None:
            raise RuntimeError("infeasible placement (capacity exhausted)")
        _, g, j, h = best
        placed[g].remove(j)
        has[j, g] = False
        placed[g].append(e)
        has[e, g] = True
        placed[h].append(j)
        slots_free[h] -= 1
        has[j, h] = True

    stx = -np.ones((n_e, C), np.int32)
    for g in range(n_e):
        for c_i, e in enumerate(placed[g]):
            stx[g, c_i] = e
    return ReplicaLayout.build(stx, E)


def build_layout(
    trace: np.ndarray,  # recent routing trace [N, k]
    num_experts: int,
    num_instances: int,
    capacity: int,
) -> ReplicaLayout:
    """Counts + co-activation from a trace → allocate → place."""
    from repro.core.amax import coactivation_matrix

    counts = np.bincount(trace.reshape(-1), minlength=num_experts).astype(np.float64)
    R = allocate_replicas(counts, num_instances, capacity)
    A = coactivation_matrix(trace, num_experts)
    return place_replicas(R, A, num_instances, capacity, loads=counts)


def layout_for_survivors(
    num_experts: int,
    n_surviving: int,
    capacity: Optional[int] = None,
    trace: Optional[np.ndarray] = None,
) -> ReplicaLayout:
    """Re-plan expert placement after a permanent MoE-device loss (§3.5
    applied to failure instead of scaling): seat every expert on the
    ``n_surviving`` instances, growing per-instance capacity as needed so no
    expert is orphaned.  With a routing ``trace`` the activation-aware
    allocate/place pipeline runs (same as a scaling reconfiguration); without
    one a round-robin layout keeps recovery O(1) — either way the layout
    seats all experts, so expert *semantics* (hence token streams) are
    unchanged and only load balance degrades."""
    if n_surviving < 1:
        raise ValueError("MoE pool lost its last device — degrade to mono instead")
    C = -(-num_experts // n_surviving)  # ceil: every expert gets a seat
    if capacity is not None:
        C = max(C, capacity)
    if n_surviving * C == num_experts:
        C += 1  # replication headroom, matching the serving default
    if trace is not None:
        return build_layout(trace, num_experts, n_surviving, C)
    return ReplicaLayout.round_robin(num_experts, n_surviving, C)


def instance_coactivation_load(layout: ReplicaLayout, coactivation: np.ndarray) -> np.ndarray:
    """I(g) of Eq. 6, for evaluation/benchmarks."""
    out = np.zeros(layout.num_instances)
    for g in range(layout.num_instances):
        hosted = layout.slot_to_expert[g]
        hosted = np.unique(hosted[hosted >= 0])
        s = 0.0
        for i in range(len(hosted)):
            for j in range(i + 1, len(hosted)):
                s += coactivation[hosted[i], hosted[j]]
        out[g] = s
    return out
