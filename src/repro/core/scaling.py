"""Fine-grained, SLO-aware resource scaling — Janus §3.5 (Eq. 1–3, Alg. 2).

Performance model (Eq. 1):
    TPOT = Σ_ℓ [ T_attn + T_moe + T_comm ]
    T_attn = max(c_a, α·b + c_kv·b·S_ctx)          (roofline plateau + growth)
    T_moe  = β·a_max(n_e, B) + c_e                  (activated-expert linear)
    T_comm = adaptive two-phase cost model (repro.core.comm)

Coefficients are derived analytically from the model config and hardware spec
(the container substitute for the paper's one-time offline profiling);
``calibrate()`` accepts measured overrides.

Steady-state batch (Eq. 2, Little's law):  B* = λ · TPOT(B*) solved by a
bounded monotone binary search.  The scaler (Algorithm 2) enumerates
(n_a, n_e), prunes infeasible candidates, and returns the SLO-feasible
configuration with the smallest GPU count — together with the full evaluated
search space (Fig. 16).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import comm as comm_mod
from repro.core.aebs import ReplicaLayout
from repro.core.amax import MonteCarloAmax, amax_bound
from repro.core.comm import HardwareSpec, TPU_V5E
from repro.core.placement import build_layout


# ---------------------------------------------------------------------------
# Analytic layer coefficients
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LayerCoeffs:
    """Per-layer coefficients of Eq. 1 (seconds)."""

    c_a: float  # attention memory-bound plateau
    alpha: float  # attention compute per token
    c_kv: float  # KV-cache read per token per context unit
    beta: float  # MoE time per distinct activated expert
    c_e: float  # MoE constant (launch + shared expert)
    t_ffn: float  # dense-FFN time (non-MoE layers), weight-read bound

    @staticmethod
    def from_config(cfg, hw: HardwareSpec = TPU_V5E) -> "LayerCoeffs":
        bp = cfg.bytes_per_param()
        d, hd = cfg.d_model, cfg.resolved_head_dim
        nh, nkv = max(1, cfg.num_heads), max(1, cfg.num_kv_heads)
        attn_params = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        c_a = attn_params * bp / hw.hbm_bw + hw.kernel_launch
        alpha = 2.0 * attn_params / hw.peak_flops
        c_kv = 2.0 * nkv * hd * bp / hw.hbm_bw
        if cfg.has_moe:
            glu = 3
            expert_bytes = glu * d * cfg.d_ff_expert * bp
            beta = expert_bytes / hw.hbm_bw
            shared_bytes = cfg.num_shared_experts * expert_bytes
            c_e = hw.kernel_launch + shared_bytes / hw.hbm_bw
            t_ffn = 0.0
        else:
            beta = 0.0
            c_e = 0.0
            glu = 3 if cfg.ffn_activation in ("swiglu", "geglu") else 2
            t_ffn = (glu * d * cfg.d_ff * bp) / hw.hbm_bw + hw.kernel_launch
        return LayerCoeffs(c_a, alpha, c_kv, beta, c_e, t_ffn)


# ---------------------------------------------------------------------------
# Performance model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EvalResult:
    n_a: int
    n_e: int
    batch: float
    tpot: float
    t_attn: float
    t_moe: float
    t_comm: float
    a_max: float
    tpg: float  # tokens/s per GPU
    feasible: bool


class PerfModel:
    def __init__(
        self,
        cfg,
        hw: HardwareSpec = TPU_V5E,
        amax_estimator: Optional[MonteCarloAmax] = None,
        slots_per_instance: Optional[int] = None,
        layout_fn: Optional[Callable[[int], ReplicaLayout]] = None,
        s_ctx: float = 1024.0,
    ):
        self.cfg = cfg
        self.hw = hw
        self.coeffs = LayerCoeffs.from_config(cfg, hw)
        self.s_ctx = s_ctx
        self.amax_est = amax_estimator
        if slots_per_instance is None and cfg.has_moe:
            expert_bytes = 3 * cfg.d_model * cfg.d_ff_expert * cfg.bytes_per_param()
            budget = 0.7 * hw.mem_bytes / max(1, cfg.num_layers)
            slots_per_instance = max(1, int(budget // expert_bytes))
        self.C = slots_per_instance or 1
        self._layout_cache: Dict[int, ReplicaLayout] = {}
        self._layout_fn = layout_fn
        self._overrides: Dict[str, float] = {}

    # -- calibration hook ----------------------------------------------------
    def calibrate(self, **measured: float) -> None:
        """Override analytic coefficients with measured values."""
        for k, v in measured.items():
            if not hasattr(self.coeffs, k):
                raise KeyError(k)
            setattr(self.coeffs, k, v)

    # -- layout --------------------------------------------------------------
    def layout_for(self, n_e: int) -> ReplicaLayout:
        if n_e not in self._layout_cache:
            if self._layout_fn is not None:
                self._layout_cache[n_e] = self._layout_fn(n_e)
            else:
                self._layout_cache[n_e] = ReplicaLayout.round_robin(
                    self.cfg.num_experts, n_e, self.C
                )
        return self._layout_cache[n_e]

    # -- Eq. 1 terms ----------------------------------------------------------
    def amax(self, n_e: int, batch: float) -> float:
        if not self.cfg.has_moe:
            return 1.0
        b = max(1, int(round(batch)))
        if self.amax_est is not None:
            return self.amax_est.estimate(self.layout_for(n_e), b)
        return amax_bound(
            n_e, b, self.cfg.num_experts, self.cfg.top_k, self.C
        )

    def t_attn(self, local_batch: float) -> float:
        c = self.coeffs
        return max(c.c_a, c.alpha * local_batch + c.c_kv * local_batch * self.s_ctx)

    def t_moe(self, n_e: int, batch: float) -> Tuple[float, float]:
        c = self.coeffs
        if not self.cfg.has_moe:
            return c.t_ffn, 1.0
        a = self.amax(n_e, batch)
        return c.beta * a + c.c_e, a

    def t_comm(self, n_a: int, n_e: int, batch: float, scheme: str = "2pc") -> float:
        if not self.cfg.has_moe:
            return 0.0
        return comm_mod.layer_comm_time(
            n_a,
            n_e,
            max(1, int(round(batch))),
            self.cfg.d_model,
            self.hw,
            self.cfg.bytes_per_param(),
            scheme=scheme,
            top_k=self.cfg.top_k,
            num_experts=self.cfg.num_experts,
        )

    def tpot(self, batch: float, n_a: int, n_e: int, scheme: str = "2pc") -> EvalResult:
        L = self.cfg.num_layers
        b_local = batch / n_a
        ta = self.t_attn(b_local)
        tm, a = self.t_moe(n_e, batch)
        tc = self.t_comm(n_a, n_e, batch, scheme)
        tpot = L * (ta + tm + tc)
        tpg = batch / tpot / (n_a + n_e) if tpot > 0 else 0.0
        return EvalResult(n_a, n_e, batch, tpot, L * ta, L * tm, L * tc, a, tpg, True)

    # -- memory feasibility ----------------------------------------------------
    def attn_memory(self, local_batch: float, s_ctx: Optional[float] = None) -> float:
        cfg = self.cfg
        s = s_ctx if s_ctx is not None else self.s_ctx
        pc = cfg.param_counts()
        attn_bytes = (pc["attn"] + pc["embed"] + pc["norm"] + pc["ffn"] + pc["ssm"]) * cfg.bytes_per_param()
        kv = cfg.kv_bytes_per_token() * local_batch * s
        act = local_batch * cfg.d_model * cfg.bytes_per_param() * 64  # buffers
        return attn_bytes + kv + act

    def max_local_batch(self) -> float:
        cfg = self.cfg
        pc = cfg.param_counts()
        attn_bytes = (pc["attn"] + pc["embed"] + pc["norm"] + pc["ffn"] + pc["ssm"]) * cfg.bytes_per_param()
        free = self.hw.mem_bytes * 0.9 - attn_bytes
        if free <= 0:
            return 0.0
        per_tok = cfg.kv_bytes_per_token() * self.s_ctx + cfg.d_model * cfg.bytes_per_param() * 64
        return free / per_tok


# ---------------------------------------------------------------------------
# Eq. 2 — steady-state batch via bounded binary search
# ---------------------------------------------------------------------------


def solve_batch(
    model: PerfModel, demand: float, n_a: int, n_e: int, b_max: float, scheme: str = "2pc"
) -> Optional[float]:
    """Solve B = λ·TPOT(B) on [1, b_max].  Returns None if infeasible."""

    def f(B: float) -> float:
        return B - demand * model.tpot(B, n_a, n_e, scheme).tpot

    if b_max < 1:
        return None
    if f(1.0) >= 0:
        return 1.0  # workload too light to form a larger steady batch
    if f(b_max) < 0:
        return None  # even the max memory-feasible batch can't sustain demand
    lo, hi = 1.0, b_max
    for _ in range(40):
        mid = 0.5 * (lo + hi)
        if f(mid) < 0:
            lo = mid
        else:
            hi = mid
    return hi


# ---------------------------------------------------------------------------
# Algorithm 2 — the scaler
# ---------------------------------------------------------------------------


class SLOScaler:
    def __init__(self, model: PerfModel, n_max: int = 16, scheme: str = "2pc"):
        self.model = model
        self.n_max = n_max
        self.scheme = scheme
        cfg = model.cfg
        self.n_e_min = (
            max(1, math.ceil(cfg.num_experts / model.C)) if cfg.has_moe else 1
        )
        self.search_log: List[EvalResult] = []

    def evaluate(
        self, demand: float, slo: float, n_a: int, n_e: int
    ) -> Optional[EvalResult]:
        b_max = self.model.max_local_batch() * n_a
        B = solve_batch(self.model, demand, n_a, n_e, b_max, self.scheme)
        if B is None:
            return None
        r = self.model.tpot(B, n_a, n_e, self.scheme)
        r.feasible = (
            r.tpot <= slo
            and self.model.attn_memory(B / n_a) <= 0.9 * self.model.hw.mem_bytes
        )
        return r

    def scale(self, demand: float, slo: float) -> Optional[EvalResult]:
        """Algorithm 2: min n_a + n_e over SLO-feasible candidates."""
        self.search_log = []
        best: Optional[EvalResult] = None
        for n_a in range(1, self.n_max + 1):
            for n_e in range(self.n_e_min, self.n_max + 1):
                r = self.evaluate(demand, slo, n_a, n_e)
                if r is None:
                    continue
                self.search_log.append(r)
                if not r.feasible:
                    continue
                if best is None or (r.n_a + r.n_e) < (best.n_a + best.n_e) or (
                    (r.n_a + r.n_e) == (best.n_a + best.n_e) and r.tpg > best.tpg
                ):
                    best = r
        return best
