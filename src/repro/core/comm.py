"""Adaptive two-phase communication — Janus §3.3, adapted to TPU.

The paper's mechanism: instead of O(m×n) small cross-node transfers between
m attention instances and n MoE instances, first aggregate activations over
the *fast intra-node* fabric (NVLink), then issue few large transfers over
the *slow inter-node* fabric (IB/RDMA).  Two regimes:

  Case-1  aggregated payloads go directly to each destination node;
  Case-2  one-to-one node pairing + local multicast at the destination.

TPU adaptation (DESIGN.md §2): the fast fabric is the intra-pod ICI torus and
the slow fabric is the cross-pod DCN link; in SPMD the same trade appears as
hierarchical collective decomposition (intra-pod ring all-gather before the
cross-pod exchange), which we verify in the lowered HLO.  This module is the
*analytic cost model* used by (a) the SLO scaler's T_comm term, (b) the
Fig. 12 ablation benchmark, and (c) regime selection in the serving engine.

Costs use the classic α–β model: per-message latency α plus bytes/bandwidth.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    name: str
    peak_flops: float  # FLOP/s (bf16)
    hbm_bw: float  # bytes/s
    fast_bw: float  # intra-node / intra-pod bytes/s (per device)
    slow_bw: float  # inter-node / cross-pod bytes/s (per device)
    alpha_fast: float  # per-message latency on the fast fabric (s)
    alpha_slow: float  # per-message latency on the slow fabric (s)
    mem_bytes: float  # device memory
    devices_per_node: int  # instances sharing the fast fabric
    kernel_launch: float = 5e-6  # dispatch constant (c_a / c_e floor)


# TPU v5e (target hardware of this repro; ICI ~50 GB/s/link, ~3 links usable)
TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    fast_bw=3 * 50e9,
    slow_bw=25e9,  # cross-pod DCN per device (conservative)
    alpha_fast=1e-6,
    alpha_slow=10e-6,
    mem_bytes=16e9,
    devices_per_node=4,  # v5e host = 4 chips on shared ICI neighbourhood
)

# H100 DGX (the paper's testbed — used to sanity-check paper-scale numbers)
H100 = HardwareSpec(
    name="h100",
    peak_flops=989e12,
    hbm_bw=3.35e12,
    fast_bw=900e9,  # NVLink
    slow_bw=50e9,  # 400 Gbps IB
    alpha_fast=3e-6,
    alpha_slow=8e-6,
    mem_bytes=80e9,
    devices_per_node=8,
)


@dataclasses.dataclass(frozen=True)
class CommConfig:
    n_attn: int  # m attention instances
    n_moe: int  # n MoE instances
    bytes_per_token: int  # activation payload per token (d_model × dtype)
    batch: int  # tokens in flight per layer step
    hw: HardwareSpec = TPU_V5E

    @property
    def attn_nodes(self) -> int:
        return max(1, math.ceil(self.n_attn / self.hw.devices_per_node))

    @property
    def moe_nodes(self) -> int:
        return max(1, math.ceil(self.n_moe / self.hw.devices_per_node))

    @property
    def total_bytes(self) -> float:
        """Full (ungated) activations, attention→MoE (EGate semantics)."""
        return float(self.batch) * self.bytes_per_token


def one_phase_cost(c: CommConfig) -> float:
    """Strawman: every attention instance sends to every MoE instance.

    m×n messages of (B/m)·bytes each; messages serialise per NIC (per source
    instance: n sends) and every transfer crosses the slow fabric.
    """
    # EGate sends full activations to every MoE instance, so each source puts
    # its activation block on the wire once per destination.
    per_src_msgs = c.n_moe
    bytes_on_wire_per_src = (c.total_bytes / c.n_attn) * c.n_moe
    return per_src_msgs * c.hw.alpha_slow + bytes_on_wire_per_src / c.hw.slow_bw


def two_phase_case1(c: CommConfig) -> float:
    """Phase 1: intra-node aggregation; Phase 2: each attention node sends the
    aggregated payload directly to each MoE node."""
    intra = c.hw.alpha_fast * math.ceil(math.log2(max(2, c.hw.devices_per_node))) + (
        c.total_bytes / c.attn_nodes
    ) / c.hw.fast_bw
    per_node_payload = c.total_bytes / c.attn_nodes
    inter = c.moe_nodes * c.hw.alpha_slow + (per_node_payload * c.moe_nodes) / c.hw.slow_bw
    return intra + inter


def two_phase_case2(c: CommConfig) -> float:
    """Phase 1: intra-node aggregation; Phase 2: one-to-one node pairing, then
    intra-node multicast at the destination."""
    intra = c.hw.alpha_fast * math.ceil(math.log2(max(2, c.hw.devices_per_node))) + (
        c.total_bytes / c.attn_nodes
    ) / c.hw.fast_bw
    pairs = max(c.attn_nodes, c.moe_nodes)
    # each pair carries the *global* payload split across pairs, then fans out
    inter = c.hw.alpha_slow + (c.total_bytes / pairs) / c.hw.slow_bw
    multicast = c.hw.alpha_fast + (c.total_bytes / c.moe_nodes) / c.hw.fast_bw
    return intra + inter + multicast


def adaptive_two_phase(c: CommConfig) -> Tuple[float, str]:
    """Janus regime selection: pick the cheaper of case-1 / case-2."""
    t1, t2 = two_phase_case1(c), two_phase_case2(c)
    return (t1, "case1") if t1 <= t2 else (t2, "case2")


def agate_cost(c: CommConfig, top_k: int, num_experts: int) -> float:
    """Attention-side gating baseline (MegaScale): only routed activations are
    sent, but with per-expert packing + metadata, each source talks to every
    MoE instance hosting an activated expert → many small messages."""
    # expected distinct destination instances per source ≈ n_moe (top-k spreads)
    frac = min(1.0, top_k / max(1, num_experts) * num_experts / c.n_moe)
    dests = max(1.0, c.n_moe * min(1.0, frac))
    routed_bytes = c.total_bytes * top_k / max(1, num_experts) * (num_experts / c.n_moe)
    meta_bytes = c.batch * 8  # routing metadata per token
    per_src_msgs = dests
    t = per_src_msgs * c.hw.alpha_slow + (routed_bytes + meta_bytes) / c.hw.slow_bw
    return t


def layer_comm_time(
    n_attn: int,
    n_moe: int,
    batch: int,
    d_model: int,
    hw: HardwareSpec = TPU_V5E,
    dtype_bytes: int = 2,
    scheme: str = "2pc",
    top_k: int = 8,
    num_experts: int = 64,
) -> float:
    """Round-trip (dispatch + combine) communication time for one MoE layer."""
    c = CommConfig(n_attn, n_moe, d_model * dtype_bytes, batch, hw)
    if scheme == "2pc":
        t, _ = adaptive_two_phase(c)
    elif scheme == "1pc":
        t = one_phase_cost(c)
    elif scheme == "agate":
        t = agate_cost(c, top_k, num_experts)
    else:
        raise ValueError(scheme)
    return 2.0 * t  # dispatch + combine
