"""a_max estimation — Janus §3.5 + Appendix A.

Two estimators for the maximum number of distinct activated experts on any
MoE instance, ``a_max(n_e, B)``:

* :func:`amax_bound` — the closed-form balls-into-bins upper bound (Eq. 4–5):
  adversarial w.r.t. the scheduler, one-sided (never under-predicts).
* :class:`MonteCarloAmax` — the estimator Janus actually uses at decision
  time: sample B tokens from a recent routing trace, run the *actual*
  scheduler against the *actual* replica layout, record the resulting a_max.

Also provides synthetic routing-trace generators (uniform and Zipf-skewed
top-k activations) standing in for the paper's ShareGPT-derived traces.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.aebs import ReplicaLayout, aebs_numpy


# ---------------------------------------------------------------------------
# Closed-form bound (Appendix A)
# ---------------------------------------------------------------------------


def expected_instance_load(
    probs_on_g: np.ndarray, batch: int
) -> float:
    """E[a_g] ≤ Σ_{e∈P(g)} [1 - (1 - p_e)^B]   (Eq. 4)."""
    return float(np.sum(1.0 - np.power(1.0 - probs_on_g, batch)))


def amax_bound(
    n_e: int,
    batch: int,
    num_experts: int,
    top_k: int,
    capacity: int,
    probs: Optional[np.ndarray] = None,
    layout: Optional[ReplicaLayout] = None,
) -> float:
    """Eq. 5:  a_max ≤ ceil( min(C, ā_max + sqrt(2 ā_max ln n_e)) + 1 ).

    With a layout + per-expert probabilities, ā_max maximises Eq. 4 over
    instances; otherwise the uniform p_e = K/E symmetric case is used.
    """
    if probs is None:
        probs = np.full(num_experts, top_k / num_experts)
    probs = np.minimum(probs, 1.0)
    if layout is not None:
        a_bar = 0.0
        for g in range(layout.num_instances):
            hosted = layout.slot_to_expert[g]
            hosted = np.unique(hosted[hosted >= 0])
            a_bar = max(a_bar, expected_instance_load(probs[hosted], batch))
    else:
        per_inst = math.ceil(num_experts / n_e)
        # symmetric: every instance hosts ~E/n_e distinct experts
        a_bar = per_inst * (1.0 - (1.0 - top_k / num_experts) ** batch)
    bound = min(capacity, a_bar + math.sqrt(2.0 * a_bar * max(math.log(n_e), 0.0)))
    return math.ceil(bound + 1.0)


# ---------------------------------------------------------------------------
# Synthetic routing traces
# ---------------------------------------------------------------------------


def make_routing_trace(
    num_tokens: int,
    num_experts: int,
    top_k: int,
    skew: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Per-token top-k expert ids, [num_tokens, top_k] int32.

    ``skew = 0`` → uniform routing; ``skew > 0`` → Zipf-like popularity with
    exponent ``skew`` (hot experts emerge, as in real traces).
    """
    rng = np.random.default_rng(seed)
    if skew <= 0:
        w = np.ones(num_experts)
    else:
        w = 1.0 / np.power(np.arange(1, num_experts + 1), skew)
        w = rng.permutation(w)  # hot experts at random ids
    p = w / w.sum()
    out = np.empty((num_tokens, top_k), np.int32)
    for t in range(num_tokens):
        out[t] = rng.choice(num_experts, size=top_k, replace=False, p=p)
    return out


def trace_expert_probs(trace: np.ndarray, num_experts: int) -> np.ndarray:
    """Per-token activation probability p_e estimated from a trace."""
    counts = np.bincount(trace.reshape(-1), minlength=num_experts).astype(np.float64)
    return counts / max(1, trace.shape[0])


def coactivation_matrix(trace: np.ndarray, num_experts: int) -> np.ndarray:
    """a(e, e') — co-activation frequency within a token (Appendix B)."""
    A = np.zeros((num_experts, num_experts), np.float64)
    for row in trace:
        for i in range(len(row)):
            for j in range(i + 1, len(row)):
                A[row[i], row[j]] += 1
                A[row[j], row[i]] += 1
    return A / max(1, trace.shape[0])


# ---------------------------------------------------------------------------
# Monte Carlo estimator (lookup table rebuilt periodically)
# ---------------------------------------------------------------------------

SchedulerNumpy = Callable[[np.ndarray, ReplicaLayout], Tuple[np.ndarray, np.ndarray, object]]


@dataclasses.dataclass
class MonteCarloAmax:
    """\\hat a_max(n_e, B): replay B-token samples from the trace through the
    scheduler + layout (Janus §3.5 "Monte Carlo estimator")."""

    trace: np.ndarray  # [N, k] recent routing decisions
    num_experts: int
    trials: int = 16
    seed: int = 0
    scheduler: SchedulerNumpy = staticmethod(lambda e, l: aebs_numpy(e, l))

    def __post_init__(self):
        self._cache: Dict[Tuple[int, int], float] = {}

    def estimate(self, layout: ReplicaLayout, batch: int) -> float:
        key = (layout.num_instances, layout.capacity, batch, hash(layout.slot_to_expert.tobytes()))
        if key in self._cache:
            return self._cache[key]
        rng = np.random.default_rng(self.seed + batch)
        n = self.trace.shape[0]
        vals = []
        for _ in range(self.trials):
            idx = rng.integers(0, n, size=min(batch, n))
            sample = self.trace[idx]
            _, load, _ = self.scheduler(sample, layout)
            vals.append(int(np.max(load)))
        est = float(np.mean(vals))
        self._cache[key] = est
        return est
