"""Disaggregated cluster abstraction — Janus §3.1/§3.2 (R1).

Maps the paper's two sub-clusters onto JAX device sets:

* **Pool mode** (literal, used by the runnable serving engine/example): the
  available devices are split into ``n_a`` attention devices and ``n_e`` MoE
  devices; attention instances each hold a full attention-stack replica and a
  KV-cache shard of the in-flight batch; MoE instances hold expert replica
  slots.  Layer-wise exchange is an explicit device-to-device transfer
  (the two-phase scheme decides its pattern).

* **SPMD mode** (production mesh, used by the multi-pod dry-run): the
  attention pool is the data-parallel axis group and the MoE pool is the
  model-axis expert-parallel group; the two-phase transfer appears as a
  hierarchically-decomposed all-gather/psum pair (DESIGN.md §2).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax

from repro.core.aebs import ReplicaLayout


@dataclasses.dataclass
class DisaggConfig:
    """A (n_a, n_e) deployment with its expert layout and comm scheme."""

    n_attn: int
    n_moe: int
    layout: ReplicaLayout
    comm_scheme: str = "2pc"  # 2pc | 1pc
    gate_side: str = "moe"  # moe (EGate) | attn (AGate)

    @property
    def total_instances(self) -> int:
        return self.n_attn + self.n_moe

    def describe(self) -> str:
        return f"{self.n_attn}A{self.n_moe}E"


@dataclasses.dataclass
class DevicePools:
    attn_devices: List[jax.Device]
    moe_devices: List[jax.Device]

    @staticmethod
    def split(
        n_attn: int, n_moe: int, devices: Optional[Sequence[jax.Device]] = None
    ) -> "DevicePools":
        devs = list(devices if devices is not None else jax.devices())
        if len(devs) < n_attn + n_moe:
            raise ValueError(
                f"need {n_attn + n_moe} devices, have {len(devs)} "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
            )
        return DevicePools(devs[:n_attn], devs[n_attn : n_attn + n_moe])


def reconfigure(
    cfg_from: DisaggConfig, n_attn: int, n_moe: int, layout: ReplicaLayout
) -> DisaggConfig:
    """Incremental reconfiguration (§3.5): a new deployment object; in SPMD
    JAX the engine re-lowers for the new pool sizes (DESIGN.md §2 —
    'recompile-and-swap' actuation)."""
    return dataclasses.replace(cfg_from, n_attn=n_attn, n_moe=n_moe, layout=layout)
