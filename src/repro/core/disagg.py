"""Disaggregated cluster abstraction — Janus §3.1/§3.2 (R1), now runnable.

Maps the paper's two sub-clusters onto JAX device sets:

* **Pool mode** (literal, executed by
  :class:`repro.serving.disagg.DisaggExecutor` behind
  ``ServingEngine(executor="disagg")``): the available devices are split into
  ``n_a`` attention devices and ``n_e`` MoE devices.  Attention instances
  each hold a full attention-stack replica and a contiguous *batch shard* of
  the in-flight KV caches; MoE instances hold their expert replica slots'
  weights only.  Every layer performs a real hand-off: the post-attention
  activations are moved attention→MoE with explicit ``device_put`` steps
  whose pattern — case-1 direct node-to-node vs case-2 pairing + multicast —
  is chosen per step by :func:`repro.core.comm.adaptive_two_phase` and
  realised by :func:`plan_exchange` below.  Pools carry a ``node_size`` so
  the two-phase schedule has a fabric hierarchy (fast intra-node / slow
  inter-node) to exploit; on CPU hosts the hierarchy is simulated but the
  transfer *schedule* (message count, per-fabric bytes) is the real one and
  is surfaced in engine telemetry.

* **SPMD mode** (production mesh, used by the multi-pod dry-run): the
  attention pool is the data-parallel axis group and the MoE pool is the
  model-axis expert-parallel group; the two-phase transfer appears as a
  hierarchically-decomposed all-gather/psum pair (DESIGN.md §2).

:func:`reconfigure` produces the incremental-deployment object (§3.5); the
pool-mode executor actuates it by re-lowering only the affected pool
(attention and MoE counts move independently mid-run, KV caches are
re-sharded in place).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import jax

from repro.core.aebs import ReplicaLayout


@dataclasses.dataclass
class DisaggConfig:
    """A (n_p, n_a, n_e) deployment with its expert layout and comm scheme.

    ``n_prefill`` is the third sub-cluster: devices dedicated to chunked
    prompt prefill, feeding the attention pool's KV caches via streamed
    per-chunk hand-off (0 = prefill runs inline on the default device, the
    pre-disaggregation behaviour)."""

    n_attn: int
    n_moe: int
    layout: ReplicaLayout
    comm_scheme: str = "2pc"  # 2pc | 1pc
    gate_side: str = "moe"  # moe (EGate) | attn (AGate)
    n_prefill: int = 0

    @property
    def total_instances(self) -> int:
        return self.n_prefill + self.n_attn + self.n_moe

    def describe(self) -> str:
        p = f"{self.n_prefill}P" if self.n_prefill else ""
        return f"{p}{self.n_attn}A{self.n_moe}E"


@dataclasses.dataclass
class DevicePools:
    """The device sub-clusters plus their fabric hierarchy.

    ``node_size`` is the number of consecutive devices sharing the fast
    fabric (NVLink node / ICI neighbourhood); the two-phase exchange
    aggregates within a node before crossing node boundaries.

    ``prefill_devices`` is the third sub-cluster: full-model replicas that
    run chunked prompt prefill and stream each finished chunk's KV slab into
    the attention pool's batch-sharded caches.  It may be empty (prefill
    then runs inline on the default device — the pre-disaggregation mode).
    """

    attn_devices: List[jax.Device]
    moe_devices: List[jax.Device]
    node_size: int = 1
    prefill_devices: List[jax.Device] = dataclasses.field(default_factory=list)

    @staticmethod
    def split(
        n_attn: int,
        n_moe: int,
        devices: Optional[Sequence[jax.Device]] = None,
        node_size: int = 1,
        allow_reuse: bool = False,
        n_prefill: int = 0,
    ) -> "DevicePools":
        """Split ``devices`` into the three pools.

        Anchoring invariant: attention devices are taken from the *front* of
        the list, MoE devices from the *back*, and prefill devices from the
        tail of the middle gap (immediately ahead of the MoE pool).  Resizing
        the attention pool therefore never relocates prefill or MoE devices,
        and resizing the prefill pool never relocates either decode pool —
        an incremental reconfiguration (§3.5) really does leave the
        unaffected pools' weights in place.  (Resizing the MoE pool re-anchors
        the prefill pool; prefill replicas hold no cross-request state, so
        that relocation is one weight placement, not a cache migration.)

        ``allow_reuse=True`` maps pools onto too-few devices round-robin —
        the degenerate single-host mode used by tests that must stay on one
        device (the transfer schedule still runs; the puts are local).
        """
        devs = list(devices if devices is not None else jax.devices())
        total = n_attn + n_moe + n_prefill
        if len(devs) < total:
            if not allow_reuse:
                raise ValueError(
                    f"need {total} devices, have {len(devs)} "
                    "(set XLA_FLAGS=--xla_force_host_platform_device_count=N)"
                )
            devs = [devs[i % len(devs)] for i in range(total)]
        n = len(devs)
        return DevicePools(
            devs[:n_attn],
            devs[n - n_moe :],
            node_size,
            devs[n - n_moe - n_prefill : n - n_moe],
        )

    # -- fabric hierarchy ----------------------------------------------------
    def _groups(self, devs: List[jax.Device]) -> List[List[jax.Device]]:
        ns = max(1, self.node_size)
        return [devs[i : i + ns] for i in range(0, len(devs), ns)]

    @property
    def attn_nodes(self) -> List[List[jax.Device]]:
        return self._groups(self.attn_devices)

    @property
    def moe_nodes(self) -> List[List[jax.Device]]:
        return self._groups(self.moe_devices)


@dataclasses.dataclass(frozen=True)
class TransferStep:
    """One explicit device-to-device move in a realised exchange pattern.

    ``src``/``dst`` are ``(pool, index)`` addresses — ``("attn", i)`` or
    ``("moe", g)`` — rather than device objects, so the schedule stays
    well-defined when pools alias physical devices (single-host testing).
    ``chunk`` is the index of the payload chunk being moved (a chunk is one
    attention node's aggregated activation block in case-1, one pair split in
    case-2); ``fabric`` prices it for telemetry.
    """

    src: Tuple[str, int]
    dst: Tuple[str, int]
    chunk: int
    fabric: str  # "fast" | "slow"
    phase: int = 2  # 1 = intra-node shard aggregation, 2 = cross-pool move


@dataclasses.dataclass(frozen=True)
class Chunk:
    """One payload chunk of a realised exchange.

    ``members`` are the attention-pool device indices whose shards form the
    chunk's parent node payload (aggregated on ``members[0]``, the node
    leader); the chunk itself is row-split ``sub``/``n_subs`` of that
    payload (``n_subs == 1`` means the whole node payload — case-1 and the
    balanced case-2).  Case-2 subdivides so every pair link carries
    ≈ total/pairs bytes, matching :func:`repro.core.comm.two_phase_case2`.
    """

    members: Tuple[int, ...]
    sub: int = 0
    n_subs: int = 1


def plan_exchange(pools: DevicePools, regime: str) -> Tuple[List[Chunk], List[TransferStep]]:
    """Realise the adaptive two-phase pattern as explicit per-node steps.

    Returns ``(chunks, steps)``: the payload :class:`Chunk` list (in batch
    row order) and the ordered ``device_put`` schedule that lands every
    chunk on every MoE device:

    * phase 1 (both cases): shard → node-leader aggregation over the fast
      fabric;
    * case-1: each node's chunk goes leader→leader to every MoE node
      (slow), then leader→local devices (fast);
    * case-2: the payload is split across ``pairs = max(attn_nodes,
      moe_nodes)`` chunks; chunk ``p`` goes to the paired MoE node
      ``p % moe_nodes`` (slow — one ≈total/pairs message per pair), then
      MoE nodes redistribute chunks amongst themselves and multicast
      locally (fast).
    """
    ns = max(1, pools.node_size)
    n_attn, n_moe = len(pools.attn_devices), len(pools.moe_devices)
    a_nodes = [tuple(range(i, min(i + ns, n_attn))) for i in range(0, n_attn, ns)]
    m_nodes = [list(range(i, min(i + ns, n_moe))) for i in range(0, n_moe, ns)]

    # case-2 subdivides node payloads so the pair count matches the model
    pairs = max(len(a_nodes), len(m_nodes))
    subs = -(-pairs // len(a_nodes)) if regime == "case2" else 1

    chunks: List[Chunk] = []
    steps: List[TransferStep] = []
    for node in a_nodes:
        first_cid = len(chunks)
        for s in range(subs):
            chunks.append(Chunk(node, s, subs))
        for i in node[1:]:
            steps.append(
                TransferStep(("attn", i), ("attn", node[0]), first_cid, "fast", phase=1)
            )

    if regime == "case1":
        for cid, ch in enumerate(chunks):
            leader = ch.members[0]
            for mnode in m_nodes:
                steps.append(TransferStep(("attn", leader), ("moe", mnode[0]), cid, "slow"))
                for g in mnode[1:]:
                    steps.append(TransferStep(("moe", mnode[0]), ("moe", g), cid, "fast"))
    elif regime == "case2":
        # one-to-one pairing: every chunk crosses the slow fabric exactly once
        dst_leader = {}
        for cid, ch in enumerate(chunks):
            mnode = m_nodes[cid % len(m_nodes)]
            steps.append(
                TransferStep(("attn", ch.members[0]), ("moe", mnode[0]), cid, "slow")
            )
            dst_leader[cid] = mnode[0]
        # destination-side redistribution + local multicast (fast fabric)
        for mnode in m_nodes:
            for cid in range(len(chunks)):
                holder = dst_leader[cid]
                if holder != mnode[0]:
                    steps.append(TransferStep(("moe", holder), ("moe", mnode[0]), cid, "fast"))
                for g in mnode[1:]:
                    steps.append(TransferStep(("moe", mnode[0]), ("moe", g), cid, "fast"))
    else:
        raise ValueError(regime)
    return chunks, steps


def reconfigure(
    cfg_from: DisaggConfig,
    n_attn: int,
    n_moe: int,
    layout: ReplicaLayout,
    n_prefill: Optional[int] = None,
) -> DisaggConfig:
    """Incremental reconfiguration (§3.5): a new deployment object.  The
    pool-mode executor actuates it with ``DisaggExecutor.reconfigure`` —
    re-lowering only the pool whose count changed — while the SPMD engine
    re-lowers for the new mesh ('recompile-and-swap', DESIGN.md §2)."""
    return dataclasses.replace(
        cfg_from,
        n_attn=n_attn,
        n_moe=n_moe,
        layout=layout,
        n_prefill=cfg_from.n_prefill if n_prefill is None else n_prefill,
    )
