"""Training loop: jit'd train_step (remat'd scan over layer periods) + driver."""

from __future__ import annotations

import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import model as model_mod
from repro.training.checkpoint import save_checkpoint
from repro.training.data import DataConfig, TokenPipeline
from repro.training.optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


def make_train_step(cfg, opt_cfg: AdamWConfig, remat: bool = True) -> Callable:
    def train_step(params, opt_state: OptState, tokens, labels):
        def loss(p):
            return model_mod.loss_fn(p, tokens, labels, cfg, remat=remat)

        (l, aux), grads = jax.value_and_grad(loss, has_aux=True)(params)
        new_params, new_state, info = adamw_update(opt_cfg, params, grads, opt_state)
        info = dict(info, loss=l, lb_loss=aux.get("lb_loss", jnp.float32(0.0)))
        return new_params, new_state, info

    return train_step


def train(
    cfg,
    steps: int = 100,
    batch_size: int = 8,
    seq_len: int = 128,
    seed: int = 0,
    opt_cfg: Optional[AdamWConfig] = None,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    log_every: int = 10,
    log_fn=print,
) -> Dict:
    opt_cfg = opt_cfg or AdamWConfig(total_steps=steps)
    params = model_mod.init_params(cfg, seed)
    opt_state = init_opt_state(params)
    pipe = TokenPipeline(DataConfig(cfg.vocab_size, seq_len, batch_size, seed))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg))

    history = []
    t0 = time.perf_counter()
    for step in range(steps):
        toks, labels = pipe.batch(step)
        params, opt_state, info = step_fn(params, opt_state, jnp.asarray(toks), jnp.asarray(labels))
        if step % log_every == 0 or step == steps - 1:
            loss = float(info["loss"])
            history.append((step, loss))
            log_fn(
                f"step {step:5d}  loss {loss:.4f}  lr {float(info['lr']):.2e}  "
                f"gnorm {float(info['grad_norm']):.2f}"
            )
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            save_checkpoint(ckpt_dir, step + 1, params, opt_state)
    wall = time.perf_counter() - t0
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, params, opt_state)
    return {
        "history": history,
        "final_loss": history[-1][1] if history else float("nan"),
        "first_loss": history[0][1] if history else float("nan"),
        "wall_s": wall,
        "params": params,
    }
