"""AdamW with bf16 params + float32 moments, cosine LR schedule."""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


class OptState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(jnp.int32(0), zeros, jax.tree.map(jnp.copy, zeros))


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(
    cfg: AdamWConfig, params, grads, state: OptState
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_schedule(cfg, state.step)
    b1c = 1.0 - cfg.b1**step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2**step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        pf = p.astype(jnp.float32)
        pf = pf - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}
