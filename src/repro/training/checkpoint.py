"""Minimal dependency-free checkpointing: flat-key npz of the param/opt pytree."""

from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        arr = np.asarray(tree)
        if arr.dtype == jnp.bfloat16:
            out[prefix[:-1] + "::bf16"] = arr.astype(np.float32)
        else:
            out[prefix[:-1]] = arr
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Dict[str, Any] = {}
    for key, arr in flat.items():
        if key.endswith("::bf16"):
            key = key[: -len("::bf16")]
            arr = jnp.asarray(arr, jnp.bfloat16)
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(arr)
    return _intify(root)


def _intify(node):
    """Convert {'0': .., '1': ..} dicts back to tuples."""
    if isinstance(node, dict):
        keys = list(node.keys())
        if keys and all(k.isdigit() for k in keys):
            return tuple(_intify(node[str(i)]) for i in range(len(keys)))
        return {k: _intify(v) for k, v in node.items()}
    return node


def save_checkpoint(path: str, step: int, params, opt_state=None) -> str:
    os.makedirs(path, exist_ok=True)
    fname = os.path.join(path, f"ckpt_{step:08d}.npz")
    flat = _flatten({"params": params})
    if opt_state is not None:
        flat.update(_flatten({"opt": {"step": opt_state.step, "mu": opt_state.mu, "nu": opt_state.nu}}))
    np.savez(fname, **flat)
    return fname


def load_checkpoint(fname: str) -> Tuple[Any, Any]:
    """Returns (params, opt_dict_or_None)."""
    with np.load(fname) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten(flat)
    return tree["params"], tree.get("opt")


def latest_checkpoint(path: str):
    if not os.path.isdir(path):
        return None
    cands = sorted(f for f in os.listdir(path) if f.startswith("ckpt_"))
    return os.path.join(path, cands[-1]) if cands else None
