"""Synthetic token pipeline: deterministic, seekable, infinite.

Generates structured pseudo-language (Zipf unigrams + Markov bigram mixing)
so models have real signal to fit during the example training runs, with
deterministic per-step batches (checkpoint-resumable by step index).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2


class TokenPipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self.unigram = (1.0 / ranks**cfg.zipf_a)
        self.unigram /= self.unigram.sum()
        # sparse bigram: each token prefers a few successors
        self.succ = rng.integers(0, v, size=(v, 4))

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) for this step — deterministic in (seed, step)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.batch_size, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=b, p=self.unigram)
        follow = rng.random((b, s)) < 0.7
        uni = rng.choice(cfg.vocab_size, size=(b, s), p=self.unigram)
        pick = rng.integers(0, self.succ.shape[1], size=(b, s))
        for t in range(s):
            nxt = self.succ[toks[:, t], pick[:, t]]
            toks[:, t + 1] = np.where(follow[:, t], nxt, uni[:, t])
        return toks[:, :-1], toks[:, 1:]

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
