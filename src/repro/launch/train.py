"""Training launcher.

CPU-scale run (real execution):
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
        --reduced --steps 200 --batch 8 --seq 128

Production-mesh launch (TPU; on CPU use --dry-run to lower+compile only):
    PYTHONPATH=src python -m repro.launch.train --arch yi-34b --shape train_4k --dry-run
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--dry-run", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args()

    if args.dry_run:
        import os

        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_one

        run_one(args.arch, args.shape, args.multi_pod, "results/dryrun")
        return

    from repro.configs import get_config
    from repro.training.train_loop import train

    name = args.arch + ("-reduced" if args.reduced else "")
    cfg = get_config(name)
    res = train(
        cfg,
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        seed=args.seed,
        ckpt_dir=args.ckpt_dir,
    )
    print(
        f"done: loss {res['first_loss']:.4f} → {res['final_loss']:.4f} "
        f"in {res['wall_s']:.1f}s"
    )


if __name__ == "__main__":
    main()
