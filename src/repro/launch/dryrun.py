import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_DRYRUN_EXTRA_FLAGS", "")
).strip()

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, prove memory feasibility, and extract roofline terms.

MUST be invoked as its own process (device count is locked at first jax
init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-moe-a2.7b \
        --shape decode_32k [--multi-pod] [--out results/dryrun]

    PYTHONPATH=src python -m repro.launch.dryrun --all   # fan out everything
"""

import argparse  # noqa: E402
import json  # noqa: E402
import subprocess  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402

from repro.configs import REGISTRY, SHAPES, get_config, shape_supported  # noqa: E402
from repro.launch.mesh import make_production_mesh, use_mesh  # noqa: E402
from repro.launch.steps import BUILDERS  # noqa: E402
from repro.roofline import analysis  # noqa: E402


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str) -> dict:
    cfg = get_config(arch)
    if os.environ.get("DRYRUN_KV_QUANT"):
        import dataclasses

        cfg = dataclasses.replace(cfg, kv_quant=True)
        arch = arch + "+int8kv"
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    t0 = time.perf_counter()
    with use_mesh(mesh):
        step, abs_args = BUILDERS[shape.kind](cfg, mesh, shape)
        lowered = step.lower(*abs_args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    from repro.models.transformer import period_pattern

    _, n_periods = period_pattern(cfg)
    if shape.kind == "decode":
        n_periods = 1  # serve_step unrolls the layer loop (§Perf P1)
    terms = analysis.analyze(
        arch,
        shape_name,
        mesh_name,
        chips,
        cost,
        hlo,
        mem,
        analysis.model_flops_estimate(cfg, shape),
        loop_scale=float(n_periods),
    )
    rec = terms.to_dict()
    rec.update(
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory_analysis=str(mem),
        hlo_collective_count=terms.collective_breakdown.get("count", 0),
    )
    os.makedirs(out_dir, exist_ok=True)
    fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)
    print(
        f"[dryrun] {arch} × {shape_name} × {mesh_name}: "
        f"compile={t_compile:.0f}s peak_mem/dev={terms.peak_memory_per_device/2**30:.2f}GiB "
        f"t_comp={terms.t_compute*1e3:.2f}ms t_mem={terms.t_memory*1e3:.2f}ms "
        f"t_coll={terms.t_collective*1e3:.2f}ms dominant={terms.dominant}"
    )
    print(mem)
    print({k: v for k, v in cost.items() if k in ("flops", "bytes accessed")})
    return rec


PAPER_ARCHS = ("dsv2-lite", "dsv2", "scaled-ds-1", "scaled-ds-2")


def all_combos(include_paper: bool = False):
    archs = [a for a in REGISTRY if a not in PAPER_ARCHS]
    if include_paper:
        archs += list(PAPER_ARCHS)
    for arch in archs:
        for shape_name in SHAPES:
            if arch in PAPER_ARCHS and shape_name == "train_4k":
                continue  # the paper's models are serving-only in its eval
            ok, _ = shape_supported(get_config(arch), SHAPES[shape_name])
            if ok:
                yield arch, shape_name


def fan_out(out_dir: str, multi_pod_also: bool, jobs: int, include_paper: bool = False) -> int:
    """Run every combo as a subprocess (device count is per-process)."""
    tasks = []
    for arch, shape_name in all_combos(include_paper):
        for mp in ([False, True] if multi_pod_also else [False]):
            mesh_name = "2x16x16" if mp else "16x16"
            fname = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
            if os.path.exists(fname):
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape_name, "--out", out_dir,
            ] + (["--multi-pod"] if mp else [])
            tasks.append(cmd)
    print(f"[dryrun] {len(tasks)} combos to run, {jobs} parallel")
    running, failed = [], []
    while tasks or running:
        while tasks and len(running) < jobs:
            cmd = tasks.pop(0)
            running.append((cmd, subprocess.Popen(cmd)))
        time.sleep(2)
        still = []
        for cmd, p in running:
            if p.poll() is None:
                still.append((cmd, p))
            elif p.returncode != 0:
                failed.append(cmd)
                print("[dryrun] FAILED:", " ".join(cmd[3:]))
        running = still
    print(f"[dryrun] done, {len(failed)} failures")
    return len(failed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--paper-models", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    if args.all:
        sys.exit(fan_out(args.out, multi_pod_also=True, jobs=args.jobs,
                         include_paper=args.paper_models))
    run_one(args.arch, args.shape, args.multi_pod, args.out)


if __name__ == "__main__":
    main()
