"""Production mesh construction (multi-pod dry-run contract).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The caller is responsible
for setting ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before*
any jax import when running on the CPU container (see launch/dryrun.py).
"""

from __future__ import annotations

import jax


def use_mesh(mesh):
    """Version-compat context manager for entering a mesh.

    ``jax.set_mesh`` (newer releases) → ``jax.sharding.use_mesh`` (transition
    releases) → the ``Mesh`` object itself (a context manager on every
    version).  One shim shared by launch/dryrun, the serve examples, and the
    EP subprocess tests so no caller hard-codes a jax API level.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over host devices for examples/tests (CPU)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
