"""Serving launcher: run the continuous-batching engine (CPU-scale, reduced
configs) with the Janus scheduled-MoE path and the autoscaling controller.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-moe-a2.7b \
        --rate 20 --duration 2 --scheduler aebs
"""

from __future__ import annotations

import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b")
    ap.add_argument("--rate", type=float, default=20.0, help="requests/s")
    ap.add_argument("--duration", type=float, default=2.0, help="seconds of arrivals")
    ap.add_argument("--scheduler", default="aebs", choices=["aebs", "random", "token_hash", "none"])
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--n-instances", type=int, default=4)
    ap.add_argument("--slots", type=int, default=0, help="expert slots per instance")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--executor", default="mono", choices=["mono", "disagg"],
        help="disagg = two-pool execution (attention/MoE on separate devices; "
        "set XLA_FLAGS=--xla_force_host_platform_device_count=N for real pools)",
    )
    ap.add_argument("--n-attn", type=int, default=2, help="attention pool size (disagg)")
    ap.add_argument(
        "--n-prefill", type=int, default=0,
        help="prefill pool size (third sub-cluster; >0 switches admission to "
        "the pipelined chunked-prefill path unless --admission overrides)",
    )
    ap.add_argument(
        "--admission", default=None, choices=["blocking", "pipelined"],
        help="blocking = whole-prompt prefill inline (legacy); pipelined = "
        "chunked prefill on the prefill pool, streamed KV hand-off",
    )
    ap.add_argument("--prefill-chunk", type=int, default=64, help="prefill chunk size (tokens)")
    ap.add_argument(
        "--kv-page-size", type=int, default=None, metavar="ROWS",
        help="enable the paged KV cache with fixed-size pages of ROWS tokens "
        "(must divide --cache-len); default keeps contiguous per-slot slabs",
    )
    ap.add_argument(
        "--kv-num-pages", type=int, default=None,
        help="page-pool size (incl. the reserved null page); default backs "
        "every slot fully — shrink it to overcommit KV memory",
    )
    ap.add_argument(
        "--prefix-cache", action="store_true",
        help="page-granular radix prefix cache: prompts sharing a chunk-"
        "aligned token prefix reuse its KV pages by block-table splicing "
        "(zero recompute, zero copy); requires --kv-page-size",
    )
    ap.add_argument(
        "--prefix-cache-pages", type=int, default=None, metavar="N",
        help="page budget the prefix index may pin; LRU leaf eviction beyond "
        "it (default: unbounded — pages free when the last holder drops)",
    )
    ap.add_argument(
        "--prefill-batch", type=int, default=1, metavar="B",
        help="fuse up to B pending prompts into one padded-and-masked prefill "
        "chunk call per device (bit-identical to serial; default 1)",
    )
    ap.add_argument(
        "--workload", default="chat", choices=["chat", "shared-prefix"],
        help="request-length preset: chat (ShareGPT-like) or shared-prefix "
        "(every prompt opens with the same system prompt — the prefix-cache "
        "workload)",
    )
    ap.add_argument("--ping-pong", action="store_true", help="m=2 micro-batch overlap (disagg)")
    ap.add_argument(
        "--fault-plan", default=None, metavar="PATH",
        help="JSON fault-injection plan (see repro.serving.faults.FaultPlan) — "
        "device losses / exchange timeouts / prefill-chunk failures are "
        "injected at the scheduled decode steps and recovered live",
    )
    ap.add_argument(
        "--request-deadline", type=float, default=None,
        help="admission deadline in seconds after arrival; requests that wait "
        "longer while the engine is saturated are rejected",
    )
    ap.add_argument(
        "--trace", default=None, metavar="PATH",
        help="replay a multi-tenant workload file (TraceSpec JSON: duration, "
        "seed, tenants with request class / arrival process / priority / "
        "SLOs); replaces --rate/--duration/--workload",
    )
    ap.add_argument(
        "--sched", default="fifo", choices=["fifo", "priority"],
        help="request admission scheduler: fifo = strict arrival order; "
        "priority = higher Request.priority first, preempting lower-priority "
        "active slots via KV spill/restore (requires --kv-page-size)",
    )
    ap.add_argument(
        "--slo-ttft", type=float, default=None, metavar="S",
        help="default TTFT SLO (s, arrival → first token) stamped on every "
        "request that doesn't already carry one from the trace file",
    )
    ap.add_argument(
        "--slo-tpot", type=float, default=None, metavar="S",
        help="default TPOT SLO (s, p99 inter-token gap), same stamping rule",
    )
    ap.add_argument(
        "--draft", default=None, metavar="ARCH",
        help="enable speculative decode with ARCH (reduced config) as the "
        "draft model; pass the target --arch itself for self-drafting "
        "(acceptance 1.0 — useful for overhead measurement).  Output streams "
        "stay bit-identical to plain greedy regardless of the draft",
    )
    ap.add_argument(
        "--spec-k", type=int, default=0, metavar="K",
        help="draft tokens proposed per verify step (default 2 when --draft "
        "is set); each step emits 1..K+1 tokens",
    )
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.core.amax import make_routing_trace
    from repro.core.placement import build_layout
    from repro.models import model as model_mod
    from repro.serving.engine import ServingEngine
    from repro.serving.request import WorkloadSpec, sample_requests, shared_prefix_spec
    from repro.serving.trace import TraceSpec, poisson_arrivals

    cfg = get_config(args.arch + "-reduced")
    params = model_mod.init_params(cfg, args.seed)
    draft_config = None
    if args.draft is not None:
        draft_config = cfg if args.draft == args.arch else get_config(args.draft + "-reduced")
    layout = None
    if cfg.has_moe and args.scheduler != "none":
        C = args.slots or (cfg.num_experts // args.n_instances + 1)
        trace = make_routing_trace(2048, cfg.num_experts, cfg.top_k, skew=0.8, seed=args.seed)
        layout = build_layout(trace, cfg.num_experts, args.n_instances, C)
    if args.trace is not None:
        with open(args.trace) as fh:
            tspec = TraceSpec.from_json(fh.read())
        reqs = tspec.build(vocab_size=cfg.vocab_size, with_prompts=True)
    else:
        if args.workload == "shared-prefix":
            spec = shared_prefix_spec(vocab_size=cfg.vocab_size)
        else:
            spec = WorkloadSpec(
                mean_input=8, mean_output=24, vocab_size=cfg.vocab_size, max_input=48, max_output=64
            )
        reqs = sample_requests(spec, poisson_arrivals(args.rate, args.duration, args.seed), with_prompts=True)
    for r in reqs:
        if args.slo_ttft is not None and r.ttft_slo is None:
            r.ttft_slo = args.slo_ttft
        if args.slo_tpot is not None and r.tpot_slo is None:
            r.tpot_slo = args.slo_tpot
    if args.request_deadline is not None:
        for r in reqs:
            if r.deadline is None:
                r.deadline = r.arrival + args.request_deadline
    fault_plan = None
    if args.fault_plan is not None:
        from repro.serving.faults import FaultPlan

        with open(args.fault_plan) as fh:
            fault_plan = FaultPlan.from_json(fh.read())
    eng = ServingEngine(
        cfg,
        params,
        max_batch=args.max_batch,
        cache_len=args.cache_len,
        layout=layout,
        scheduler=args.scheduler,
        executor=args.executor,
        n_attn=args.n_attn,
        n_prefill=args.n_prefill,
        admission=args.admission,
        prefill_chunk=args.prefill_chunk,
        ping_pong=args.ping_pong,
        fault_plan=fault_plan,
        kv_page_size=args.kv_page_size,
        kv_num_pages=args.kv_num_pages,
        prefix_cache=args.prefix_cache,
        prefix_cache_pages=args.prefix_cache_pages,
        prefill_batch=args.prefill_batch,
        sched=args.sched,
        draft_config=draft_config,
        spec_k=args.spec_k,
    )
    print(
        f"serving {len(reqs)} requests on {cfg.name} "
        f"(scheduler={args.scheduler}, executor={args.executor}, "
        f"admission={eng.admission}, sched={args.sched}, "
        f"n_prefill={args.n_prefill}"
        + (f", trace={args.trace}" if args.trace else "")
        + (f", fault_plan={args.fault_plan}" if fault_plan else "")
        + ")"
    )
    m = eng.run(reqs)
    for k, v in m.items():
        print(f"  {k:20s} {v:.4f}" if isinstance(v, float) else f"  {k:20s} {v}")
    if fault_plan is not None and eng.degraded_reason:
        print(f"  degraded to mono executor: {eng.degraded_reason}")


if __name__ == "__main__":
    main()
