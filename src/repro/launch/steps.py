"""Distributed step builders: train_step / prefill_step / serve_step bound to
a mesh with full parameter+input shardings.

These are what the multi-pod dry-run lowers and what a real deployment would
dispatch.  MoE layers use the expert-parallel shard_map path
(``repro.models.moe_ep``): ``logical`` mode for train/prefill, ``scheduled``
(AEBS over replica slots) for decode — the Janus serving path as a
first-class feature of the step function."""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig, input_specs
from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.models import model as model_mod
from repro.models import transformer
from repro.sharding.rules import batch_axes, input_pspecs, param_pspecs
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda: model_mod.init_params(cfg, 0))


def serving_layout(cfg: ModelConfig, n_instances: int) -> ReplicaLayout:
    """Default production layout: n_e = model-axis size, capacity chosen so
    every expert is seated with ≥ n_e·C − E redundant replica slots."""
    C = math.ceil((cfg.num_experts + 1) / n_instances) + 0
    C = max(C, math.ceil(cfg.num_experts / n_instances))
    if n_instances * C == cfg.num_experts:
        C += 1  # guarantee some replication headroom
    return ReplicaLayout.round_robin(cfg.num_experts, n_instances, C)


def materialize_slot_params(params, cfg: ModelConfig, slot_to_expert):
    """Pin replica-slot expert weights (Janus: placement pins replicas in
    device memory at reconfiguration time).  Expert leaves [.., E, d, f]
    become [.., S_total, d, f]; everything else is untouched."""
    import jax.numpy as jnp

    idx = jnp.maximum(jnp.asarray(slot_to_expert), 0)

    def walk(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        if (
            "moe" in names
            and "shared" not in names  # shared-expert FFN is not slotted
            and names[-1] in ("w_gate", "w_up", "w_down")
        ):
            # stacked blocks have a leading n_periods axis
            axis = 1 if "blocks" in names else 0
            return jnp.take(leaf, idx, axis=axis)
        return leaf

    return jax.tree_util.tree_map_with_path(walk, params)


def pad_attention_heads(params, cfg: ModelConfig, n_model: int):
    """Pad query heads up to a multiple of the model-axis size so attention
    shards by head instead of falling back to d_model-contraction sharding
    (which costs an extra full-activation psum per layer — §Perf iteration
    Y1, yi-34b: 56 → 64 heads).  Padded wo rows are zero, so outputs are
    exact; num_kv_heads is untouched (GQA group size grows)."""
    import jax.numpy as jnp

    nh = cfg.num_heads
    if nh == 0 or nh % n_model == 0 or cfg.num_kv_heads == 0:
        return params
    if nh % cfg.num_kv_heads:
        return params
    nkv = cfg.num_kv_heads
    # heads are grouped kv-major: [kv0:(q0..qg-1), kv1:(...)] — pad *within*
    # each group so _group_q's reshape keeps q↔kv associations intact
    lcm = n_model * nkv // math.gcd(n_model, nkv)
    target = ((nh + lcm - 1) // lcm) * lcm
    g_old, g_new = nh // nkv, target // nkv
    pad_g = g_new - g_old

    def walk(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        off = 1 if "blocks" in names or "encoder" in names else 0
        if names[-1] == "wq":
            # [.., d, nh, hd] -> [.., d, nkv, g, hd] -> pad g -> back
            sh = leaf.shape
            w = leaf.reshape(*sh[: off + 1], nkv, g_old, sh[-1])
            w = jnp.pad(w, [(0, 0)] * (off + 2) + [(0, pad_g), (0, 0)])
            return w.reshape(*sh[: off + 1], target, sh[-1])
        if names[-1] == "wo":
            # [.., nh, hd, d] -> [.., nkv, g, hd, d] -> pad g (zeros!) -> back
            sh = leaf.shape
            w = leaf.reshape(*sh[:off], nkv, g_old, *sh[off + 1 :])
            w = jnp.pad(w, [(0, 0)] * (off + 1) + [(0, pad_g), (0, 0), (0, 0)])
            return w.reshape(*sh[:off], target, *sh[off + 1 :])
        return leaf

    return jax.tree_util.tree_map_with_path(walk, params)


def build_disagg_executor(
    cfg: ModelConfig,
    params,
    n_attn: int,
    n_moe: int,
    *,
    max_batch: int,
    cache_len: int,
    layout: Optional[ReplicaLayout] = None,
    scheduler=aebs_assign,
    capacity: Optional[int] = None,
    ping_pong: bool = False,
    node_size: int = 1,
    n_prefill: int = 0,
    devices=None,
):
    """Launch-layer entry for the pool deployment: split the device set into
    (n_attn, n_moe) decode pools plus an optional ``n_prefill`` prefill
    sub-cluster, derive a default replica layout when none is given, and
    lower the per-layer stage functions onto the pools.  The prefill devices
    ride on ``executor.pools.prefill_devices`` — ``ServingEngine`` (or a
    direct :class:`repro.serving.prefill.PrefillWorker`) places full-model
    replicas there for chunked prompt prefill with streamed KV hand-off.

    The returned :class:`repro.serving.disagg.DisaggExecutor` is what a
    controller decision later re-lowers incrementally (only the affected
    pool) via ``executor.reconfigure`` — see ``repro.serving.controller
    .AutoScaler.actuate``."""
    from repro.core.disagg import DevicePools
    from repro.serving.disagg import DisaggExecutor

    devs = list(devices) if devices is not None else jax.devices()
    pools = DevicePools.split(
        n_attn, n_moe, devs, node_size=node_size, n_prefill=n_prefill,
        allow_reuse=len(devs) < n_attn + n_moe + n_prefill,
    )
    if layout is None:
        layout = serving_layout(cfg, n_moe)
    return DisaggExecutor(
        cfg, params, pools, layout,
        max_batch=max_batch, cache_len=cache_len,
        scheduler=scheduler, capacity=capacity, ping_pong=ping_pong,
        devices=devs,
    )


def build_serving_engine(
    cfg: ModelConfig,
    params,
    n_attn: int,
    n_moe: int,
    *,
    max_batch: int,
    cache_len: int,
    n_prefill: int = 0,
    layout: Optional[ReplicaLayout] = None,
    scheduler: str = "aebs",
    capacity: Optional[int] = None,
    prefill_chunk: int = 64,
    fault_plan=None,
    retry_policy=None,
    watchdog=None,
    kv_page_size: Optional[int] = None,
    kv_num_pages: Optional[int] = None,
    **engine_kw,
):
    """Launch-layer entry for a full fault-tolerant pool deployment: the
    three-pool :class:`repro.serving.engine.ServingEngine` with a default
    replica layout derived from the MoE pool size and an optional armed
    :class:`repro.serving.faults.FaultPlan` — the one-call path
    ``launch/serve.py --fault-plan`` and the fault benchmark build on."""
    from repro.serving.engine import ServingEngine

    if layout is None and cfg.has_moe:
        layout = serving_layout(cfg, n_moe)
    return ServingEngine(
        cfg, params,
        max_batch=max_batch, cache_len=cache_len,
        layout=layout, scheduler=scheduler, capacity_tokens=capacity,
        executor="disagg", n_attn=n_attn, n_prefill=n_prefill,
        prefill_chunk=prefill_chunk,
        fault_plan=fault_plan, retry_policy=retry_policy, watchdog=watchdog,
        kv_page_size=kv_page_size, kv_num_pages=kv_num_pages,
        **engine_kw,
    )


def build_prefill_worker(
    cfg: ModelConfig,
    params,
    n_prefill: int,
    *,
    cache_len: int,
    chunk: int = 64,
    n_attn: int = 0,
    n_moe: int = 0,
    devices=None,
    prefill_time_fn=None,
):
    """Launch-layer entry for a prefill sub-cluster: a
    :class:`repro.serving.prefill.PrefillWorker` over the prefill slice of
    the standard three-way split.  Pass the deployment's ``n_attn``/``n_moe``
    so the worker lands on the *same* devices a composed
    :func:`build_disagg_executor` reserves for prefill (immediately ahead of
    the MoE pool) — with the defaults (0, 0) the worker takes the tail of the
    device list, the standalone single-pool layout."""
    from repro.core.disagg import DevicePools
    from repro.serving.prefill import PrefillWorker

    devs = list(devices) if devices is not None else jax.devices()
    pools = DevicePools.split(
        n_attn, n_moe, devs, n_prefill=n_prefill,
        allow_reuse=len(devs) < n_attn + n_moe + n_prefill,
    )
    return PrefillWorker(
        cfg, params, pools.prefill_devices,
        cache_len=cache_len, chunk=chunk, prefill_time_fn=prefill_time_fn,
    )


def make_moe_ctx(
    cfg: ModelConfig, mesh, mode: str, scheduler=aebs_assign, fsdp: bool = False
) -> Optional[Dict]:
    if not cfg.has_moe:
        return None
    n_model = mesh.shape["model"]
    ctx: Dict[str, Any] = dict(
        dispatch="ep",
        ep_ctx=dict(
            mesh=mesh, dp_axes=batch_axes(mesh), model_axis="model", mode=mode, fsdp=fsdp
        ),
    )
    if mode == "scheduled":
        # serving hot path: sort-based grouped dispatch — no per-step weight
        # copies, inactive replica slots stream no weights (β·a_max cost)
        ctx["ep_ctx"]["dispatch"] = "grouped"
        layout = serving_layout(cfg, n_model)
        ctx.update(
            scheduler=scheduler,
            layout_tables=layout.device_tables(),
            slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
            num_instances=n_model,
        )
    return ctx


def _ns(mesh, tree_pspecs):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p),
        tree_pspecs,
        is_leaf=lambda x: isinstance(x, P),
    )


def _extra_inputs(cfg: ModelConfig, specs: Dict[str, jax.ShapeDtypeStruct]) -> Tuple[Dict, Dict]:
    """Split the input-spec dict into (model extras, remaining)."""
    extras = {k: specs[k] for k in ("encoder_frames", "patch_embeds") if k in specs}
    rest = {k: v for k, v in specs.items() if k not in extras}
    return extras, rest


# ---------------------------------------------------------------------------
# Train step
# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, mesh, shape: InputShape, opt_cfg: Optional[AdamWConfig] = None):
    """Returns (jitted step, example abstract args)."""
    opt_cfg = opt_cfg or AdamWConfig()
    specs = input_specs(cfg, shape)
    extras_abs, rest = _extra_inputs(cfg, specs)
    moe_ctx = make_moe_ctx(cfg, mesh, "logical")

    params_abs = abstract_params(cfg)
    n_model = mesh.shape["model"]
    if cfg.num_heads and cfg.num_heads % n_model:
        # §Perf Y1: head padding → head-sharded attention, one less psum/layer
        params_abs = jax.eval_shape(
            lambda p: pad_attention_heads(p, cfg, n_model), params_abs
        )
    opt_abs = jax.eval_shape(init_opt_state, params_abs)
    # ZeRO-1: parameters replicated across the data axes for compute (TP over
    # the model axis only), optimizer moments fully sharded (data × model).
    # The update step then lowers to reduce-scatter(grads) → sharded update →
    # all-gather(params) — without the gather-hoisting blowup full FSDP
    # suffers inside scan-over-layers (EXPERIMENTS.md §Perf, iteration 0).
    p_pspecs = param_pspecs(cfg, params_abs, mesh, fsdp=False)
    m_pspecs = param_pspecs(cfg, params_abs, mesh, fsdp=True)
    opt_pspecs = type(opt_abs)(P(), m_pspecs, m_pspecs)
    in_pspecs = input_pspecs(cfg, shape, specs, mesh)

    # §Perf Y3 applies to attention-stack archs only: recurrent (ssm/hybrid)
    # layers consume the sequence serially, so a seq-sharded residual just
    # adds all-gather/reduce-scatter churn (measured: zamba2 train collective
    # bytes +63% — refinement Z2/Y3b)
    seq_ok = (
        shape.seq_len % mesh.shape["model"] == 0
        and not cfg.has_moe
        and cfg.family not in ("ssm", "hybrid")
    )
    act_ns = NamedSharding(
        mesh, P(in_pspecs["tokens"][0], "model" if seq_ok else None, None)
    )

    def train_step(params, opt_state, batch):
        extra = {k: batch[k] for k in extras_abs}
        if moe_ctx:
            extra["moe_ctx"] = moe_ctx
        # §Perf Y3: sequence-parallel residual stream between layer periods
        # (psum → reduce-scatter + all-gather pair, halving on-wire bytes)
        extra["act_constraint"] = lambda x: jax.lax.with_sharding_constraint(x, act_ns)

        def loss(p):
            return model_mod.loss_fn(
                p, batch["tokens"], batch["labels"], cfg,
                extra=extra or None, remat=True, xent_chunk=512,
            )

        (l, _aux), grads = jax.value_and_grad(loss, has_aux=True)(params)
        # ZeRO-1 dataflow (§Perf Y2): constrain grads to the moments' sharding
        # so XLA reduce-scatters the bf16 grads instead of all-gathering the
        # f32 moments (3× tensors, 2× bytes each) to the replicated layout.
        grads = jax.lax.with_sharding_constraint(grads, _ns(mesh, m_pspecs))
        new_params, new_opt, info = adamw_update(opt_cfg, params, grads, opt_state)
        return new_params, new_opt, {"loss": l, "grad_norm": info["grad_norm"]}

    batch_abs = dict(rest, **extras_abs)
    batch_sh = {k: NamedSharding(mesh, in_pspecs[k]) for k in batch_abs}
    step = jax.jit(
        train_step,
        in_shardings=(_ns(mesh, p_pspecs), _ns(mesh, opt_pspecs), batch_sh),
        out_shardings=(_ns(mesh, p_pspecs), _ns(mesh, opt_pspecs), None),
        donate_argnums=(0, 1),
    )
    return step, (params_abs, opt_abs, batch_abs)


# ---------------------------------------------------------------------------
# Prefill step
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, mesh, shape: InputShape):
    specs = input_specs(cfg, shape)
    extras_abs, rest = _extra_inputs(cfg, specs)
    moe_ctx = make_moe_ctx(cfg, mesh, "logical")
    params_abs = abstract_params(cfg)
    n_model = mesh.shape["model"]
    if cfg.num_heads and cfg.num_heads % n_model:
        params_abs = jax.eval_shape(
            lambda p: pad_attention_heads(p, cfg, n_model), params_abs
        )
    p_pspecs = param_pspecs(cfg, params_abs, mesh)
    in_pspecs = input_pspecs(cfg, shape, specs, mesh)
    # caches produced by prefill follow the decode cache shardings
    decode_shape = InputShape(shape.name, shape.seq_len, shape.global_batch, "decode")
    cache_specs = input_specs(cfg, decode_shape)
    cache_pspecs = input_pspecs(cfg, decode_shape, cache_specs, mesh)

    def prefill_step(params, batch):
        extra = {k: batch[k] for k in extras_abs}
        if moe_ctx:
            extra["moe_ctx"] = moe_ctx
        logits, caches = model_mod.prefill(
            params, batch["tokens"], cfg, cache_len=shape.seq_len, extra=extra or None
        )
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, caches

    batch_abs = dict(rest, **extras_abs)
    batch_sh = {k: NamedSharding(mesh, in_pspecs[k]) for k in batch_abs}
    cache_sh = {
        k: NamedSharding(mesh, cache_pspecs[k])
        for k in cache_specs
        if k not in ("tokens", "cache_index")
    }
    step = jax.jit(
        prefill_step,
        in_shardings=(_ns(mesh, p_pspecs), batch_sh),
        out_shardings=(NamedSharding(mesh, P(cache_pspecs["tokens"][0])), cache_sh),
    )
    return step, (params_abs, batch_abs)


# ---------------------------------------------------------------------------
# Serve (decode) step
# ---------------------------------------------------------------------------


def build_serve_step(
    cfg: ModelConfig, mesh, shape: InputShape, scheduler=aebs_assign, unroll: bool = True
):
    """One new token with a KV cache of shape.seq_len — the Janus decode path."""
    specs = input_specs(cfg, shape)
    moe_ctx = make_moe_ctx(cfg, mesh, "scheduled", scheduler)
    params_abs = abstract_params(cfg)
    if moe_ctx is not None:
        stx = moe_ctx["slot_to_expert"]
        params_abs = jax.eval_shape(
            lambda p: materialize_slot_params(p, cfg, stx), params_abs
        )
    p_pspecs = param_pspecs(cfg, params_abs, mesh)
    in_pspecs = input_pspecs(cfg, shape, specs, mesh)

    cache_keys = [k for k in specs if k not in ("tokens", "cache_index")]

    def serve_step(params, tokens, cache_index, caches):
        extra = {"moe_ctx": moe_ctx} if moe_ctx else None
        logits, new_caches = model_mod.decode_step(
            params, tokens, caches, cache_index, cfg, extra=extra, unroll=unroll
        )
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    caches_abs = {k: specs[k] for k in cache_keys}
    cache_sh = {k: NamedSharding(mesh, in_pspecs[k]) for k in cache_keys}
    step = jax.jit(
        serve_step,
        in_shardings=(
            _ns(mesh, p_pspecs),
            NamedSharding(mesh, in_pspecs["tokens"]),
            NamedSharding(mesh, P()),
            cache_sh,
        ),
        out_shardings=(
            NamedSharding(mesh, P(in_pspecs["tokens"][0])),
            cache_sh,
        ),
        donate_argnums=(3,),
    )
    abs_args = (
        params_abs,
        specs["tokens"],
        specs["cache_index"],
        caches_abs,
    )
    return step, abs_args


BUILDERS = {
    "train": build_train_step,
    "prefill": build_prefill_step,
    "decode": build_serve_step,
}
