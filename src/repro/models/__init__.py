"""Model zoo: functional JAX implementations of every supported family."""

from repro.models import attention, common, ffn, model, moe, ssm, transformer  # noqa: F401
