"""Model facade: init / loss / step functions consumed by training, serving,
launch and the smoke tests."""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.common import Params


def init_params(cfg, seed: int = 0) -> Params:
    return transformer.init_params(cfg, jax.random.PRNGKey(seed))


def logits_fn(params, tokens, cfg, extra=None, remat: bool = False) -> Tuple[jax.Array, Dict]:
    x, _, aux = transformer.forward(params, tokens, cfg, extra=extra, remat=remat)
    return transformer.lm_head(params, x, cfg), aux


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token xent. logits [b, s, v] f32, labels [b, s]."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def chunked_xent(params, x, labels, cfg, chunk: int = 512) -> jax.Array:
    """Next-token xent without materialising the full [B,S,V] logits: the
    sequence is processed in chunks (essential for 200k+ vocabularies at
    megatoken batch sizes — see EXPERIMENTS.md §Perf)."""
    B, S, d = x.shape
    chunk = min(chunk, S)
    if S % chunk:
        chunk = S  # fall back (smoke-test sizes)
    n = S // chunk
    xc = x.reshape(B, n, chunk, d).swapaxes(0, 1)  # [n, B, chunk, d]
    lc = labels.reshape(B, n, chunk).swapaxes(0, 1)

    @jax.checkpoint
    def per_chunk(args):
        xi, li = args
        logits = transformer.lm_head(params, xi, cfg)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return jnp.sum(logz - gold)

    totals = jax.lax.map(per_chunk, (xc, lc))
    return jnp.sum(totals) / (B * S)


def loss_fn(
    params,
    tokens,
    labels,
    cfg,
    extra=None,
    remat: bool = False,
    lb_coef: float = 0.01,
    xent_chunk: int = 0,
):
    if xent_chunk:
        x, _, aux = transformer.forward(params, tokens, cfg, extra=extra, remat=remat)
        loss = chunked_xent(params, x, labels, cfg, xent_chunk)
    else:
        logits, aux = logits_fn(params, tokens, cfg, extra=extra, remat=remat)
        loss = cross_entropy(logits, labels)
    if cfg.has_moe:
        loss = loss + lb_coef * aux["lb_loss"]
    return loss, aux


def prefill(params, tokens, cfg, cache_len: int, extra=None):
    return transformer.prefill(params, tokens, cfg, cache_len, extra=extra)


def prefill_chunk(params, tokens, caches, start, cfg, extra=None):
    return transformer.prefill_chunk(params, tokens, caches, start, cfg, extra=extra)


def prefill_chunk_batched(params, tokens, caches, starts, lengths, cfg, extra=None):
    return transformer.prefill_chunk_batched(
        params, tokens, caches, starts, lengths, cfg, extra=extra
    )


def supports_chunked_prefill(cfg) -> bool:
    return transformer.supports_chunked_prefill(cfg)


def supports_batched_prefill(cfg) -> bool:
    return transformer.supports_batched_prefill(cfg)


def decode_step(params, tokens, caches, cache_index, cfg, extra=None, unroll=False):
    return transformer.decode_step(
        params, tokens, caches, cache_index, cfg, extra=extra, unroll=unroll
    )


def decode_step_verify(params, tokens, caches, cache_index, cfg, extra=None, widths=None):
    return transformer.decode_step_verify(
        params, tokens, caches, cache_index, cfg, extra=extra, widths=widths
    )


def supports_speculative_decode(cfg) -> bool:
    return transformer.supports_speculative_decode(cfg)


def greedy_token(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def init_decode_caches(cfg, batch: int, cache_len: int) -> Dict[str, jax.Array]:
    """Zero caches matching ``configs.base._cache_specs`` (for decode-only runs).

    For encoder-decoder configs the caller must run ``transformer.run_encoder``
    and overwrite ``caches["enc_out"]``.
    """
    from repro.configs.base import InputShape, input_specs

    shape = InputShape("adhoc", cache_len, batch, "decode")
    specs = input_specs(cfg, shape)
    return {
        k: jnp.zeros(v.shape, v.dtype)
        for k, v in specs.items()
        if k not in ("tokens", "cache_index")
    }
