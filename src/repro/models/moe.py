"""Mixture-of-Experts layer: routing, dispatch, shared experts, AEBS hook.

Three dispatch implementations with identical semantics (tested for
equivalence):

* :func:`capacity_dispatch_ffn` — einsum/one-hot based.  O(T·k·S·cap) mask
  memory; the readable oracle, used at small scale and as the other paths'
  equivalence reference.  Chosen with ``dispatch="einsum"`` (the default for
  ad-hoc calls without a serving layout).
* :func:`scatter_dispatch_ffn` — scatter/gather based.  O(S·cap·d) buffer
  memory but still O(T·k·S) one-hot/cumsum position work, and on the
  scheduled path it needs per-slot weights (a ``[S_total, d, f]`` replica
  materialisation via :func:`gather_slot_weights`).  Kept as the per-shard
  body of the legacy expert-parallel path and as the benchmark baseline.
  Chosen with ``dispatch="scatter"``.
* :func:`grouped_dispatch_ffn` — sort-based grouped dispatch, the production
  serving path (``dispatch="grouped"``; :class:`repro.serving.engine
  .ServingEngine` selects it whenever a replica layout is present).  Tokens
  are packed into capacity blocks by a stable argsort over bucket ids plus
  segment offsets (O(T·k·log) work, no one-hot masks, no ``jnp.repeat``), and
  expert weights are *never* copied per slot: single-active-replica
  schedulers (AEBS, random — at most one physical replica per activated
  expert) collapse replica slots back to logical experts and run one batched
  GEMM over the ``[E, d, f]`` arrays, while per-item schedulers keep slot
  buckets and read weights slot-indirectly — via the scalar-prefetch Pallas
  kernel on TPU (``repro.kernels.expert_ffn``) or a stream loop over
  *activated* slots elsewhere.  Per-step cost therefore tracks the number of
  distinct activated experts (β·a_max, Eq. 1c) instead of the slot count.

Scheduling hook: when a :class:`repro.core.aebs.ReplicaLayout` is provided,
token routing is rewritten from logical expert ids to *physical replica
slots* by a pluggable scheduler (AEBS / random / token-hash — Janus vs the
paper's baselines) before dispatch.  This is the paper's §3.4 workflow:
route → collect activated → select replicas → rewrite → dispatch.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, split_keys
from repro.models.ffn import ffn, init_ffn

SchedulerFn = Callable[..., Tuple[jax.Array, jax.Array, jax.Array]]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_moe(cfg, key, dtype=jnp.bfloat16) -> Params:
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff_expert
    k1, k2, k3, k4, k5 = split_keys(key, 5)
    params: Params = {
        "router": dense_init(k1, (d, E), fan_in=d, dtype=jnp.float32),
        "w_gate": dense_init(k2, (E, d, f), fan_in=d, dtype=dtype),
        "w_up": dense_init(k3, (E, d, f), fan_in=d, dtype=dtype),
        "w_down": dense_init(k4, (E, f, d), fan_in=f, dtype=dtype),
    }
    if cfg.num_shared_experts:
        params["shared"] = init_ffn(d, cfg.num_shared_experts * f, "swiglu", k5, dtype)
    return params


# ---------------------------------------------------------------------------
# Routing (gating) — softmax then top-k, renormalised (Qwen/DeepSeek style)
# ---------------------------------------------------------------------------


def route(router_w: jax.Array, x2d: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gates [T,k] f32, eids [T,k] i32, probs [T,E] f32)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, eids.astype(jnp.int32), probs


def load_balance_loss(probs: jax.Array, eids: jax.Array, num_experts: int) -> jax.Array:
    """Switch-transformer auxiliary loss: E * Σ_e f_e · P_e."""
    onehot = jax.nn.one_hot(eids, num_experts)  # [T, k, E]
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # [E]
    mean_probs = jnp.mean(probs, axis=0)  # [E]
    return num_experts * jnp.sum(frac_tokens * mean_probs)


# ---------------------------------------------------------------------------
# Expert FFN over stacked bucket weights
# ---------------------------------------------------------------------------


def expert_ffn(w: Params, xe: jax.Array) -> jax.Array:
    """xe [S, C, d] with stacked weights [S, d, f] → [S, C, d] (SwiGLU)."""
    g = jnp.einsum("scd,sdf->scf", xe, w["w_gate"])
    u = jnp.einsum("scd,sdf->scf", xe, w["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("scf,sfd->scd", h, w["w_down"])


def gather_slot_weights(params: Params, slot_to_expert: jax.Array) -> Params:
    """Materialise per-slot expert weights (replication) from logical weights.

    slot_to_expert: flat [S_total] int32 (-1 → expert 0; such slots receive no
    tokens by construction).

    This is the O(S_total·d·f) copy the grouped path exists to avoid: it is
    only used by the einsum/scatter paths and by one-time deployment pinning
    (``launch.steps.materialize_slot_params``)."""
    idx = jnp.maximum(slot_to_expert, 0)
    return {k: params[k][idx] for k in ("w_gate", "w_up", "w_down")}


def stream_slot_ffn(
    xin: jax.Array,  # [S, cap, d] capacity-packed tokens
    weights: Params,  # logical [E, d, f] (or stacked [S, d, f] w/ identity map)
    slot_to_expert: jax.Array,  # [S] int32, -1 → inactive
    active: jax.Array,  # [S] bool
    block: int = 8,
) -> jax.Array:
    """Expert FFN over *activated* slots only, streaming weight blocks.

    The host-side analogue of the Pallas kernel's ``@pl.when`` skip: slots are
    compacted so the loop trip count is ``ceil(n_active / block)`` — run time
    tracks the activated-expert count (β·a_max), and at most ``block`` experts'
    weights are resident at once (no ``[S, d, f]`` materialisation).
    """
    S, cap, d = xin.shape
    g = min(block, S)
    nblk = (S + g - 1) // g
    perm = jnp.argsort(~active)  # active slot ids first (stable)
    perm = jnp.pad(perm, (0, nblk * g - S))
    n_act = jnp.sum(active.astype(jnp.int32))
    n_blk = (n_act + g - 1) // g

    def body(i, out):
        sl = jax.lax.dynamic_slice_in_dim(perm, i * g, g)  # [g] slot ids
        es = jnp.maximum(slot_to_expert[sl], 0)
        wg = weights["w_gate"][es]  # [g, d, f] transient working set
        wu = weights["w_up"][es]
        wd = weights["w_down"][es]
        xb = xin[sl]  # [g, cap, d]
        h = jax.nn.silu(jnp.einsum("gcd,gdf->gcf", xb, wg)) * jnp.einsum(
            "gcd,gdf->gcf", xb, wu
        )
        y = jnp.einsum("gcf,gfd->gcd", h, wd)
        m = jnp.arange(g) + i * g < n_act  # tail block may be part-active
        return out.at[sl].add(jnp.where(m[:, None, None], y, 0).astype(out.dtype))

    return jax.lax.fori_loop(0, n_blk, body, jnp.zeros_like(xin))


# ---------------------------------------------------------------------------
# Dispatch paths
# ---------------------------------------------------------------------------


def _positions_in_bucket(flat_ids: jax.Array, num_buckets: int, item_mask: Optional[jax.Array]) -> jax.Array:
    """Arrival order of each item within its bucket. flat_ids [I] → pos [I].

    One-hot/cumsum based — O(I·num_buckets); used by the oracle paths only."""
    oh = jax.nn.one_hot(flat_ids, num_buckets, dtype=jnp.int32)
    if item_mask is not None:
        oh = oh * item_mask[:, None].astype(jnp.int32)
    pos = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1
    return pos


def sort_dispatch_plan(
    flat_ids: jax.Array,  # [I] bucket id per item (may contain -1 / invalid)
    num_buckets: int,
    capacity: int,
    item_mask: Optional[jax.Array] = None,  # [I] bool
) -> Dict[str, jax.Array]:
    """Sort-based token permutation: the O(I·log I) replacement for the
    one-hot/cumsum position computation.

    A stable argsort over bucket ids groups items by bucket in arrival order
    (so capacity overflow drops exactly the same items as the one-hot paths);
    segment offsets then come from a binary search instead of a cumsum.

    Returns a dict with:
      ``pos``    [I]        arrival position of each item within its bucket
      ``keep``   [I] bool   item survives masking + capacity
      ``counts`` [B] int32  items per bucket (pre-capacity)
      ``src``    [B, cap]   item index feeding each capacity row
      ``row_valid`` [B, cap] bool — capacity row is backed by a real item
    """
    I = flat_ids.shape[0]
    valid = (flat_ids >= 0) & (flat_ids < num_buckets)
    if item_mask is not None:
        valid = valid & item_mask
    ids = jnp.where(valid, flat_ids, num_buckets)  # invalid → sentinel bucket
    order = jnp.argsort(ids, stable=True).astype(jnp.int32)  # [I]
    sorted_ids = ids[order]
    offsets = jnp.searchsorted(sorted_ids, jnp.arange(num_buckets + 1)).astype(jnp.int32)
    counts = offsets[1:] - offsets[:-1]  # [B]
    pos_sorted = jnp.arange(I, dtype=jnp.int32) - offsets[jnp.clip(sorted_ids, 0, num_buckets)]
    pos = jnp.zeros((I,), jnp.int32).at[order].set(pos_sorted)
    keep = valid & (pos < capacity)
    rows = offsets[:-1, None] + jnp.arange(capacity, dtype=jnp.int32)[None, :]  # [B, cap]
    row_valid = jnp.arange(capacity)[None, :] < counts[:, None]
    src = order[jnp.clip(rows, 0, I - 1)]
    return {"pos": pos, "keep": keep, "counts": counts, "src": src, "row_valid": row_valid}


def capacity_dispatch_ffn(
    x2d: jax.Array,  # [T, d]
    bucket_ids: jax.Array,  # [T, k]
    gates: jax.Array,  # [T, k]
    num_buckets: int,
    capacity: int,
    weights: Params,  # stacked [num_buckets, ...]
    item_mask: Optional[jax.Array] = None,  # [T*k] bool
) -> jax.Array:
    """Einsum/one-hot dispatch (oracle path)."""
    T, k = bucket_ids.shape
    dt = x2d.dtype
    flat = bucket_ids.reshape(-1)
    x_rep = jnp.repeat(x2d, k, axis=0)  # [I, d], item i = (t, j) with i = t*k+j
    pos = _positions_in_bucket(flat, num_buckets, item_mask)
    keep = (pos >= 0) & (pos < capacity)
    if item_mask is not None:
        keep = keep & item_mask
    pos_c = jnp.where(keep, pos, capacity)  # one_hot(capacity, capacity) == 0 → dropped
    disp = jnp.einsum(
        "ie,ic->iec",
        jax.nn.one_hot(flat, num_buckets, dtype=dt),
        jax.nn.one_hot(pos_c, capacity, dtype=dt),
    )
    xin = jnp.einsum("iec,id->ecd", disp, x_rep)
    out = expert_ffn(weights, xin)
    y_items = jnp.einsum("iec,ecd->id", disp, out)
    gflat = (gates.reshape(-1) * keep).astype(dt)
    return (y_items * gflat[:, None]).reshape(T, k, -1).sum(axis=1)


def scatter_dispatch_ffn(
    x2d: jax.Array,
    bucket_ids: jax.Array,
    gates: jax.Array,
    num_buckets: int,
    capacity: int,
    weights: Params,
    item_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Scatter/gather dispatch (legacy production path, same semantics)."""
    T, k = bucket_ids.shape
    d = x2d.shape[-1]
    dt = x2d.dtype
    flat = bucket_ids.reshape(-1)
    x_rep = jnp.repeat(x2d, k, axis=0)
    pos = _positions_in_bucket(flat, num_buckets, item_mask)
    keep = (pos >= 0) & (pos < capacity)
    if item_mask is not None:
        keep = keep & item_mask
    pos_c = jnp.where(keep, pos, capacity)  # row `capacity` = dump row
    bkt_c = jnp.where(keep, flat, 0)
    buf = jnp.zeros((num_buckets, capacity + 1, d), dt)
    buf = buf.at[bkt_c, pos_c].add(jnp.where(keep[:, None], x_rep, 0))
    out = expert_ffn(weights, buf[:, :capacity])
    y_items = out[bkt_c, jnp.minimum(pos_c, capacity - 1)]
    gflat = (gates.reshape(-1) * keep).astype(dt)
    return (y_items * gflat[:, None]).reshape(T, k, -1).sum(axis=1)


def grouped_dispatch_items(
    x2d: jax.Array,  # [T, d]
    bucket_ids: jax.Array,  # [T, k]
    num_buckets: int,
    capacity: int,
    weights: Params,  # stacked [B, ...] (map None) or logical [E, ...] (map given)
    slot_to_expert: Optional[jax.Array] = None,  # [B] int32 bucket → expert, -1 empty
    item_mask: Optional[jax.Array] = None,  # [T*k] bool
    backend: str = "auto",  # auto | einsum | stream | kernel
) -> Tuple[jax.Array, jax.Array]:
    """Grouped dispatch up to the per-item expert outputs.

    Returns ``(y_items [T*k, d], keep [T*k] bool)`` — the expert output of
    every (token, choice) item *before* gate-weighting and the top-k sum.
    :func:`grouped_dispatch_ffn` finishes the combine locally; the
    disaggregated executor instead ships these items back to the attention
    pool and combines there, so both executors share the exact op order.
    Rows with ``keep == False`` are arbitrary and must be gated to zero.
    """
    k = bucket_ids.shape[1]
    dt = x2d.dtype
    flat = bucket_ids.reshape(-1)
    plan = sort_dispatch_plan(flat, num_buckets, capacity, item_mask)
    xin = jnp.where(plan["row_valid"][..., None], x2d[plan["src"] // k], 0).astype(dt)

    if backend == "auto":
        if slot_to_expert is None:
            backend = "einsum"
        else:
            backend = "kernel" if jax.default_backend() == "tpu" else "stream"

    active = plan["counts"] > 0
    if slot_to_expert is not None:
        active = active & (slot_to_expert >= 0)

    if backend == "einsum":
        if slot_to_expert is not None:
            raise ValueError("einsum backend needs bucket-stacked weights (no slot map)")
        out = jnp.where(active[:, None, None], expert_ffn(weights, xin), 0).astype(dt)
    elif backend == "stream":
        s2e = (
            slot_to_expert
            if slot_to_expert is not None
            else jnp.arange(num_buckets, dtype=jnp.int32)
        )
        out = stream_slot_ffn(xin, weights, s2e, active)
    elif backend == "kernel":
        from repro.kernels.expert_ffn.ops import expert_ffn_grouped

        s2e = (
            slot_to_expert
            if slot_to_expert is not None
            else jnp.arange(num_buckets, dtype=jnp.int32)
        )
        out = expert_ffn_grouped(
            xin, weights["w_gate"], weights["w_up"], weights["w_down"], s2e, active
        )
    else:
        raise ValueError(f"unknown grouped backend: {backend}")

    keep = plan["keep"]
    pos = plan["pos"]
    y_items = out[jnp.where(keep, flat, 0), jnp.minimum(pos, capacity - 1)]
    return y_items, keep


def grouped_dispatch_ffn(
    x2d: jax.Array,  # [T, d]
    bucket_ids: jax.Array,  # [T, k]
    gates: jax.Array,  # [T, k]
    num_buckets: int,
    capacity: int,
    weights: Params,  # stacked [B, ...] (map None) or logical [E, ...] (map given)
    slot_to_expert: Optional[jax.Array] = None,  # [B] int32 bucket → expert, -1 empty
    item_mask: Optional[jax.Array] = None,  # [T*k] bool
    backend: str = "auto",  # auto | einsum | stream | kernel
) -> jax.Array:
    """Sort-based grouped dispatch — the production hot path.

    Token permutation is a stable argsort (no one-hot masks, no
    ``jnp.repeat``); the capacity buffer is built by gather from segment
    offsets.  The expert FFN runs:

    * ``einsum``  — one batched GEMM over the bucket-stacked weights (used
      when buckets *are* logical experts, i.e. ``slot_to_expert is None``);
    * ``kernel``  — the Pallas grouped kernel: ``slot_to_expert`` is a
      scalar-prefetch operand and weights stream straight from the logical
      ``[E, d, f]`` arrays (TPU; interpret elsewhere — tests only);
    * ``stream``  — :func:`stream_slot_ffn`, a loop over *activated* slots
      with block weight streaming (CPU/GPU production fallback);
    * ``auto``    — einsum if buckets are experts, else kernel on TPU and
      stream elsewhere.

    Inactive buckets (no tokens, or ``slot_to_expert == -1``) contribute
    exact zeros and — on kernel/stream backends — stream no weights.
    """
    T, k = bucket_ids.shape
    dt = x2d.dtype
    y_items, keep = grouped_dispatch_items(
        x2d, bucket_ids, num_buckets, capacity, weights,
        slot_to_expert=slot_to_expert, item_mask=item_mask, backend=backend,
    )
    gflat = (gates.reshape(-1) * keep).astype(dt)
    return (y_items * gflat[:, None]).reshape(T, k, -1).sum(axis=1)


def default_capacity(num_tokens: int, top_k: int, num_buckets: int, factor: float) -> int:
    cap = math.ceil(num_tokens * top_k * factor / max(1, num_buckets))
    return max(4, int(cap))


def scheduler_is_single_replica(scheduler) -> bool:
    """True when the scheduler activates at most one physical replica per
    logical expert per batch (AEBS and per-expert random do; per-item
    token-hash does not).  Declared via a ``single_active_replica`` attribute
    on the scheduler function; unknown schedulers conservatively return
    False."""
    return bool(getattr(scheduler, "single_active_replica", False))


DISPATCH_FNS = {
    "einsum": capacity_dispatch_ffn,
    "scatter": scatter_dispatch_ffn,
    "grouped": grouped_dispatch_ffn,
}


# ---------------------------------------------------------------------------
# Full MoE layer
# ---------------------------------------------------------------------------


def moe_layer(
    params: Params,
    x: jax.Array,  # [b, s, d]
    cfg,
    *,
    dispatch: str = "einsum",  # einsum | scatter | grouped | ep
    layout_tables: Optional[Dict[str, jax.Array]] = None,
    slot_to_expert: Optional[jax.Array] = None,  # flat [S_total]
    num_instances: int = 0,
    scheduler: Optional[SchedulerFn] = None,
    capacity: Optional[int] = None,
    with_aux: bool = False,
    ep_ctx: Optional[Dict] = None,  # mesh/dp_axes/model_axis/mode for dispatch="ep"
):
    """Route + (optional scheduling) + dispatch + shared experts.

    Without a layout: buckets are the logical experts (training / monolithic
    baseline).  With layout + scheduler: buckets are physical replica slots
    chosen by the scheduler (Janus serving path).  ``dispatch="grouped"`` is
    the production serving default (see module docstring); on that path the
    per-slot weight copy (:func:`gather_slot_weights`) is never performed —
    single-active-replica schedulers collapse slots back to logical experts,
    anything else reads weights slot-indirectly.
    """
    if dispatch == "ep":
        from repro.models import moe_ep

        return moe_ep.moe_layer_ep(
            params,
            x,
            cfg,
            scheduler=scheduler,
            layout_tables=layout_tables,
            slot_to_expert=slot_to_expert,
            num_instances=num_instances,
            with_aux=with_aux,
            **(ep_ctx or {}),
        )

    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    gates, eids, probs = route(params["router"], x2d, cfg.top_k)
    logical_weights = {k: params[k] for k in ("w_gate", "w_up", "w_down")}

    aux: Dict[str, jax.Array] = {}
    bucket_map = None  # bucket → expert map for slot-indirect grouped dispatch
    if layout_tables is not None and scheduler is not None:
        slot_ids, load, _ = scheduler(eids, layout_tables, num_instances)
        num_buckets = int(slot_to_expert.shape[0])
        # capacity is a per-*slot* budget regardless of bucketing, so the
        # collapsed grouped path drops exactly the same tokens as the others
        cap = capacity or default_capacity(b * s, cfg.top_k, num_buckets, cfg.capacity_factor)
        aux["load"] = load
        aux["a_max"] = jnp.max(load)
        if dispatch == "grouped" and scheduler_is_single_replica(scheduler):
            # ≤1 activated replica per expert → replica slots collapse back to
            # logical experts: identical token sets per bucket, one batched
            # GEMM over [E, d, f], zero weight copies or indirection.
            # (invalid slot ids stay -1 → dropped by the dispatch plan)
            bucket_ids = jnp.where(
                slot_ids >= 0, slot_to_expert[jnp.maximum(slot_ids, 0)], -1
            )
            num_buckets = cfg.num_experts
            weights = logical_weights
        elif dispatch == "grouped":
            bucket_ids = slot_ids
            bucket_map = slot_to_expert
            weights = logical_weights  # read slot-indirectly, never copied
        else:
            bucket_ids = slot_ids
            weights = gather_slot_weights(params, slot_to_expert)
    else:
        bucket_ids = eids
        num_buckets = cfg.num_experts
        cap = capacity or default_capacity(b * s, cfg.top_k, num_buckets, cfg.capacity_factor)
        weights = logical_weights
    if dispatch == "grouped":
        y2d = grouped_dispatch_ffn(
            x2d, bucket_ids, gates.astype(x.dtype), num_buckets, cap, weights,
            slot_to_expert=bucket_map,
        )
    else:
        dispatch_fn = DISPATCH_FNS[dispatch]
        y2d = dispatch_fn(x2d, bucket_ids, gates.astype(x.dtype), num_buckets, cap, weights)

    if "shared" in params:
        y2d = y2d + ffn(params["shared"], x2d, "swiglu")

    y = y2d.reshape(b, s, d)
    if with_aux:
        aux["lb_loss"] = load_balance_loss(probs, eids, cfg.num_experts)
        return y, aux
    return y
