"""Mixture-of-Experts layer: routing, dispatch, shared experts, AEBS hook.

Two dispatch implementations with identical semantics (tested for
equivalence):

* :func:`capacity_dispatch_ffn` — einsum/one-hot based.  O(T·S·cap) mask
  memory; the readable oracle, used at small scale and as the kernels' ref.
* :func:`scatter_dispatch_ffn` — scatter/gather based.  O(S·cap·d) buffer
  memory; the production path, also the per-shard body of the
  expert-parallel (shard_map) MoE in ``repro.launch.steps``.

Scheduling hook: when a :class:`repro.core.aebs.ReplicaLayout` is provided,
token routing is rewritten from logical expert ids to *physical replica
slots* by a pluggable scheduler (AEBS / random / token-hash — Janus vs the
paper's baselines) before dispatch.  This is the paper's §3.4 workflow:
route → collect activated → select replicas → rewrite → dispatch.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, split_keys
from repro.models.ffn import ffn, init_ffn

SchedulerFn = Callable[..., Tuple[jax.Array, jax.Array, jax.Array]]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_moe(cfg, key, dtype=jnp.bfloat16) -> Params:
    E, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff_expert
    k1, k2, k3, k4, k5 = split_keys(key, 5)
    params: Params = {
        "router": dense_init(k1, (d, E), fan_in=d, dtype=jnp.float32),
        "w_gate": dense_init(k2, (E, d, f), fan_in=d, dtype=dtype),
        "w_up": dense_init(k3, (E, d, f), fan_in=d, dtype=dtype),
        "w_down": dense_init(k4, (E, f, d), fan_in=f, dtype=dtype),
    }
    if cfg.num_shared_experts:
        params["shared"] = init_ffn(d, cfg.num_shared_experts * f, "swiglu", k5, dtype)
    return params


# ---------------------------------------------------------------------------
# Routing (gating) — softmax then top-k, renormalised (Qwen/DeepSeek style)
# ---------------------------------------------------------------------------


def route(router_w: jax.Array, x2d: jax.Array, top_k: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (gates [T,k] f32, eids [T,k] i32, probs [T,E] f32)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eids = jax.lax.top_k(probs, top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return gates, eids.astype(jnp.int32), probs


def load_balance_loss(probs: jax.Array, eids: jax.Array, num_experts: int) -> jax.Array:
    """Switch-transformer auxiliary loss: E * Σ_e f_e · P_e."""
    onehot = jax.nn.one_hot(eids, num_experts)  # [T, k, E]
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)  # [E]
    mean_probs = jnp.mean(probs, axis=0)  # [E]
    return num_experts * jnp.sum(frac_tokens * mean_probs)


# ---------------------------------------------------------------------------
# Expert FFN over stacked bucket weights
# ---------------------------------------------------------------------------


def expert_ffn(w: Params, xe: jax.Array) -> jax.Array:
    """xe [S, C, d] with stacked weights [S, d, f] → [S, C, d] (SwiGLU)."""
    g = jnp.einsum("scd,sdf->scf", xe, w["w_gate"])
    u = jnp.einsum("scd,sdf->scf", xe, w["w_up"])
    h = jax.nn.silu(g) * u
    return jnp.einsum("scf,sfd->scd", h, w["w_down"])


def gather_slot_weights(params: Params, slot_to_expert: jax.Array) -> Params:
    """Materialise per-slot expert weights (replication) from logical weights.

    slot_to_expert: flat [S_total] int32 (-1 → expert 0; such slots receive no
    tokens by construction)."""
    idx = jnp.maximum(slot_to_expert, 0)
    return {k: params[k][idx] for k in ("w_gate", "w_up", "w_down")}


# ---------------------------------------------------------------------------
# Dispatch paths
# ---------------------------------------------------------------------------


def _positions_in_bucket(flat_ids: jax.Array, num_buckets: int, item_mask: Optional[jax.Array]) -> jax.Array:
    """Arrival order of each item within its bucket. flat_ids [I] → pos [I]."""
    oh = jax.nn.one_hot(flat_ids, num_buckets, dtype=jnp.int32)
    if item_mask is not None:
        oh = oh * item_mask[:, None].astype(jnp.int32)
    pos = jnp.sum(jnp.cumsum(oh, axis=0) * oh, axis=-1) - 1
    return pos


def capacity_dispatch_ffn(
    x2d: jax.Array,  # [T, d]
    bucket_ids: jax.Array,  # [T, k]
    gates: jax.Array,  # [T, k]
    num_buckets: int,
    capacity: int,
    weights: Params,  # stacked [num_buckets, ...]
    item_mask: Optional[jax.Array] = None,  # [T*k] bool
) -> jax.Array:
    """Einsum/one-hot dispatch (oracle path)."""
    T, k = bucket_ids.shape
    dt = x2d.dtype
    flat = bucket_ids.reshape(-1)
    x_rep = jnp.repeat(x2d, k, axis=0)  # [I, d], item i = (t, j) with i = t*k+j
    pos = _positions_in_bucket(flat, num_buckets, item_mask)
    keep = (pos >= 0) & (pos < capacity)
    if item_mask is not None:
        keep = keep & item_mask
    pos_c = jnp.where(keep, pos, capacity)  # one_hot(capacity, capacity) == 0 → dropped
    disp = jnp.einsum(
        "ie,ic->iec",
        jax.nn.one_hot(flat, num_buckets, dtype=dt),
        jax.nn.one_hot(pos_c, capacity, dtype=dt),
    )
    xin = jnp.einsum("iec,id->ecd", disp, x_rep)
    out = expert_ffn(weights, xin)
    y_items = jnp.einsum("iec,ecd->id", disp, out)
    gflat = (gates.reshape(-1) * keep).astype(dt)
    return (y_items * gflat[:, None]).reshape(T, k, -1).sum(axis=1)


def scatter_dispatch_ffn(
    x2d: jax.Array,
    bucket_ids: jax.Array,
    gates: jax.Array,
    num_buckets: int,
    capacity: int,
    weights: Params,
    item_mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Scatter/gather dispatch (production path, same semantics)."""
    T, k = bucket_ids.shape
    d = x2d.shape[-1]
    dt = x2d.dtype
    flat = bucket_ids.reshape(-1)
    x_rep = jnp.repeat(x2d, k, axis=0)
    pos = _positions_in_bucket(flat, num_buckets, item_mask)
    keep = (pos >= 0) & (pos < capacity)
    if item_mask is not None:
        keep = keep & item_mask
    pos_c = jnp.where(keep, pos, capacity)  # row `capacity` = dump row
    bkt_c = jnp.where(keep, flat, 0)
    buf = jnp.zeros((num_buckets, capacity + 1, d), dt)
    buf = buf.at[bkt_c, pos_c].add(jnp.where(keep[:, None], x_rep, 0))
    out = expert_ffn(weights, buf[:, :capacity])
    y_items = out[bkt_c, jnp.minimum(pos_c, capacity - 1)]
    gflat = (gates.reshape(-1) * keep).astype(dt)
    return (y_items * gflat[:, None]).reshape(T, k, -1).sum(axis=1)


def default_capacity(num_tokens: int, top_k: int, num_buckets: int, factor: float) -> int:
    cap = math.ceil(num_tokens * top_k * factor / max(1, num_buckets))
    return max(4, int(cap))


# ---------------------------------------------------------------------------
# Full MoE layer
# ---------------------------------------------------------------------------


def moe_layer(
    params: Params,
    x: jax.Array,  # [b, s, d]
    cfg,
    *,
    dispatch: str = "einsum",  # einsum | scatter
    layout_tables: Optional[Dict[str, jax.Array]] = None,
    slot_to_expert: Optional[jax.Array] = None,  # flat [S_total]
    num_instances: int = 0,
    scheduler: Optional[SchedulerFn] = None,
    capacity: Optional[int] = None,
    with_aux: bool = False,
    ep_ctx: Optional[Dict] = None,  # mesh/dp_axes/model_axis/mode for dispatch="ep"
):
    """Route + (optional scheduling) + dispatch + shared experts.

    Without a layout: buckets are the logical experts (training / monolithic
    baseline).  With layout + scheduler: buckets are physical replica slots
    chosen by the scheduler (Janus serving path).
    """
    if dispatch == "ep":
        from repro.models import moe_ep

        return moe_ep.moe_layer_ep(
            params,
            x,
            cfg,
            scheduler=scheduler,
            layout_tables=layout_tables,
            slot_to_expert=slot_to_expert,
            num_instances=num_instances,
            with_aux=with_aux,
            **(ep_ctx or {}),
        )

    b, s, d = x.shape
    x2d = x.reshape(b * s, d)
    gates, eids, probs = route(params["router"], x2d, cfg.top_k)

    aux: Dict[str, jax.Array] = {}
    if layout_tables is not None and scheduler is not None:
        slot_ids, load, _ = scheduler(eids, layout_tables, num_instances)
        bucket_ids = slot_ids
        num_buckets = int(slot_to_expert.shape[0])
        weights = gather_slot_weights(params, slot_to_expert)
        aux["load"] = load
        aux["a_max"] = jnp.max(load)
    else:
        bucket_ids = eids
        num_buckets = cfg.num_experts
        weights = {k: params[k] for k in ("w_gate", "w_up", "w_down")}

    cap = capacity or default_capacity(b * s, cfg.top_k, num_buckets, cfg.capacity_factor)
    dispatch_fn = capacity_dispatch_ffn if dispatch == "einsum" else scatter_dispatch_ffn
    y2d = dispatch_fn(x2d, bucket_ids, gates.astype(x.dtype), num_buckets, cap, weights)

    if "shared" in params:
        y2d = y2d + ffn(params["shared"], x2d, "swiglu")

    y = y2d.reshape(b, s, d)
    if with_aux:
        aux["lb_loss"] = load_balance_loss(probs, eids, cfg.num_experts)
        return y, aux
    return y
