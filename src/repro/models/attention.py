"""Grouped-query attention with full, sliding-window, cross and decode paths.

Layouts (einsum-first, SPMD-friendly):
  q proj:  [d_model, n_heads,   head_dim]
  k/v:     [d_model, n_kv_heads, head_dim]
  o proj:  [n_heads, head_dim, d_model]
  caches:  [batch, cache_len, n_kv_heads, head_dim]

GQA is expressed by reshaping q heads into (kv_head, q_per_kv) groups so the
head axis stays shardable by kv-head.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

import os

from repro.models.common import Params, apply_rope, dense_init, softcap, split_keys

NEG_INF = -2.0e38


def paged_decode_backend() -> str:
    """Which read path serves paged decode attention: the paged Pallas flash
    kernel (``"kernel"``) or the jnp gather + dense softmax (``"gather"``).

    ``REPRO_PAGED_DECODE`` overrides (``kernel``/``gather``); the ``auto``
    default picks the kernel on TPU — where streaming pages HBM→VMEM with
    online softmax beats materialising the gathered ``[b, S, ...]`` view —
    and the gather path elsewhere (interpreted Pallas is debug-speed).
    Token streams match either way (flash and dense softmax agree to float
    tolerance; greedy argmax sees identical winners), and the int8-quantised
    pool always takes the gather path (the paged kernel is bf16/f32-only)."""
    mode = os.environ.get("REPRO_PAGED_DECODE", "auto")
    if mode in ("kernel", "gather"):
        return mode
    return "kernel" if jax.default_backend() == "tpu" else "gather"


def init_attention(cfg, key, dtype=jnp.bfloat16) -> Params:
    d, nh, nkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = split_keys(key, 4)
    return {
        "wq": dense_init(k1, (d, nh, hd), fan_in=d, dtype=dtype),
        "wk": dense_init(k2, (d, nkv, hd), fan_in=d, dtype=dtype),
        "wv": dense_init(k3, (d, nkv, hd), fan_in=d, dtype=dtype),
        "wo": dense_init(k4, (nh, hd, d), fan_in=nh * hd, dtype=dtype),
    }


def _group_q(q: jax.Array, n_kv: int) -> jax.Array:
    """[b, s, nh, hd] -> [b, s, n_kv, q_per_kv, hd]."""
    b, s, nh, hd = q.shape
    return q.reshape(b, s, n_kv, nh // n_kv, hd)


def _attend(
    q: jax.Array,  # [b, sq, n_kv, g, hd]
    k: jax.Array,  # [b, sk, n_kv, hd]
    v: jax.Array,  # [b, sk, n_kv, hd]
    mask: jax.Array,  # broadcastable to [b, n_kv, g, sq, sk] (bool, True=keep)
    logit_cap: Optional[float],
) -> jax.Array:
    hd = q.shape[-1]
    scale = hd**-0.5
    scores = jnp.einsum("bsngh,btnh->bngst", q, k, preferred_element_type=jnp.float32)
    scores = scores * scale
    if logit_cap is not None:
        scores = logit_cap * jnp.tanh(scores / logit_cap)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v)
    b, sq, n_kv, g, hd = out.shape
    return out.reshape(b, sq, n_kv * g, hd)


CHUNK_THRESHOLD = 2048  # switch to q-chunked attention above this seq length
Q_CHUNK = 256


def _attend_qchunked(
    qg: jax.Array,  # [b, s, n_kv, g, hd]
    k: jax.Array,  # [b, s, n_kv, hd]
    v: jax.Array,
    causal: bool,
    window: Optional[int],
    logit_cap: Optional[float],
    q_chunk: int = Q_CHUNK,
) -> jax.Array:
    """Memory-bounded attention: queries processed in checkpointed chunks so
    only an O(s·q_chunk) score block is ever live (the O(s²) f32 score tensor
    of the naive path dominates training memory at 4k–32k sequence lengths —
    see EXPERIMENTS.md §Perf)."""
    b, s, n_kv, g, hd = qg.shape
    qc = min(q_chunk, s)
    if s % qc:
        mask = None  # fallback handled by caller
        raise ValueError(f"seq {s} not divisible by q_chunk {qc}")
    nchunks = s // qc
    qg_c = qg.reshape(b, nchunks, qc, n_kv, g, hd).transpose(1, 0, 2, 3, 4, 5)
    sk = jnp.arange(s)[None, :]

    @jax.checkpoint
    def one_chunk(args):
        qi, idx = args  # qi [b, qc, n_kv, g, hd]
        sq = idx * qc + jnp.arange(qc)[:, None]
        m = jnp.ones((qc, s), bool) if not causal else (sk <= sq)
        if window is not None:
            m = m & (sk > sq - window)
        return _attend(qi, k, v, m[None, None, None], logit_cap)  # [b, qc, nh, hd]

    outs = jax.lax.map(one_chunk, (qg_c, jnp.arange(nchunks)))
    # [nchunks, b, qc, nh, hd] -> [b, s, nh, hd]
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, n_kv * g, hd)


def attention_full(
    params: Params,
    x: jax.Array,  # [b, s, d]
    cfg,
    positions: Optional[jax.Array] = None,  # [s] or [b, s]
    window: Optional[int] = None,
    causal: bool = True,
    return_kv: bool = False,
):
    """Self-attention over a full sequence (train / prefill)."""
    b, s, _ = x.shape
    nkv = cfg.num_kv_heads
    if positions is None:
        positions = jnp.arange(s)
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    if cfg.use_rope:
        q = apply_rope(q, jnp.broadcast_to(positions, (b, s)), cfg.rope_theta)
        k = apply_rope(k, jnp.broadcast_to(positions, (b, s)), cfg.rope_theta)
    qg = _group_q(q, nkv)
    if s > CHUNK_THRESHOLD and s % Q_CHUNK == 0:
        out = _attend_qchunked(qg, k, v, causal, window, cfg.attn_logit_softcap)
    else:
        sq = jnp.arange(s)[:, None]
        sk = jnp.arange(s)[None, :]
        mask = jnp.ones((s, s), bool) if not causal else (sk <= sq)
        if window is not None:
            mask = mask & (sk > sq - window)
        out = _attend(qg, k, v, mask[None, None, None], cfg.attn_logit_softcap)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    if return_kv:
        return y, (k, v)  # post-rope keys: cache-ready
    return y


def attention_cross(
    params: Params,
    x: jax.Array,  # [b, sq, d]
    kv_src: jax.Array,  # [b, sk, d]
    cfg,
) -> jax.Array:
    """Encoder-decoder cross attention (no positions on k/v, no mask)."""
    nkv = cfg.num_kv_heads
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("btd,dnh->btnh", kv_src, params["wk"])
    v = jnp.einsum("btd,dnh->btnh", kv_src, params["wv"])
    qg = _group_q(q, nkv)
    mask = jnp.ones((1, 1, 1, x.shape[1], kv_src.shape[1]), bool)
    out = _attend(qg, k, v, mask, cfg.attn_logit_softcap)
    return jnp.einsum("bsnh,nhd->bsd", out, params["wo"])


def attention_prefill_chunk(
    params: Params,
    x: jax.Array,  # [b, c, d] — one prompt chunk
    cache_k: jax.Array,  # [b, S, nkv, hd] bf16 (or int8 when cfg.kv_quant)
    cache_v: jax.Array,
    start: jax.Array,  # scalar int32 (or [b] — one chunk position per row)
    cfg,
    window: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,  # [b, S, nkv] (int8 caches only)
    v_scale: Optional[jax.Array] = None,
    lengths: Optional[jax.Array] = None,  # [b] valid tokens per row (vector start)
):
    """Chunked prefill: attend a c-token prompt chunk against the cache.

    The chunk's keys/values are written into the cache at absolute positions
    ``[start, start+c)`` and the chunk's queries attend causally over
    ``cache[0:start+c]`` — i.e. all previously prefilled chunks plus the
    chunk itself.  Iterating this over a prompt is mathematically identical
    to :func:`attention_full` on the whole prompt (and bit-identical in
    practice: per-token projections/rope are position-indexed, and masked
    cache entries contribute exact zeros to the softmax/PV reductions — the
    same padding argument :func:`attention_decode` already relies on).

    Quantised (int8) caches use *chunk-boundary-deterministic* quantisation:
    each chunk's keys/values are quantised once (per-token absmax over
    head_dim — a per-row property, independent of how the prompt was
    chunked), written to the cache, and every read — including the chunk
    attending its own freshly written keys — goes through the int8
    round-trip.  Raw keys are never re-read across a chunk boundary, so on
    the non-window path the result is invariant to the chunk grid.  The
    output differs from whole-prompt :func:`attention_full` (which attends
    raw keys) by ordinary quantisation error; what serving relies on is the
    determinism, which :func:`attention_decode` then matches by reading the
    same int8 cache.

    Batched multi-prompt prefill passes a *vector* ``start`` (``[b]``) plus
    ``lengths`` (``[b]``): each row carries its own chunk at its own absolute
    positions, rows are zero-padded to a common width, padded query rows are
    fully masked (their softmax degenerates to a uniform, finite
    distribution over masked scores — garbage out, never NaN) and padded
    cache writes are dropped, so every valid row computes exactly what the
    scalar path would.  Vector start requires the non-window path.

    Returns ``(out, new_cache_k, new_cache_v)`` — plus
    ``(new_k_scale, new_v_scale)`` when the cache is quantised.
    """
    quant = cache_k.dtype == jnp.int8
    b, c, _ = x.shape
    S = cache_k.shape[1]
    nkv = cfg.num_kv_heads
    vec = jnp.ndim(start) == 1  # batched multi-prompt path
    if vec and window is not None:
        raise ValueError("vector-start chunks require full-context layers")
    if vec and lengths is None:
        raise ValueError("vector-start chunks require per-row lengths")
    # [c] absolute positions (scalar start) or [b, c] (vector start)
    pos = start[:, None] + jnp.arange(c)[None, :] if vec else start + jnp.arange(c)
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    if cfg.use_rope:
        q = apply_rope(q, jnp.broadcast_to(pos, (b, c)), cfg.rope_theta)
        k = apply_rope(k, jnp.broadcast_to(pos, (b, c)), cfg.rope_theta)
    if quant:
        k_q, ks_q = quantize_kv(k)
        v_q, vs_q = quantize_kv(v)
        # The chunk attends its own keys through the same round-trip later
        # reads will see — never the raw values.
        k = dequantize_kv(k_q, ks_q, x.dtype)
        v = dequantize_kv(v_q, vs_q, x.dtype)
    qg = _group_q(q, nkv)
    idx = jnp.arange(S)
    if window is not None:
        # Rolling layout: slot s holds the key of absolute position
        # s + S·⌊(last−s)/S⌋ where last = start−1 is the newest *pre-chunk*
        # position (negative ⇒ slot never written).  The chunk's own keys are
        # attended from a separate fresh segment rather than written first —
        # writing up-front would let a chunk key overwrite a predecessor
        # (q−S) that earlier queries of the same chunk still need, and would
        # desynchronise slot indices from the causal mask once the buffer
        # wraps (prompts longer than the window).
        if c > S:
            raise ValueError(f"chunk ({c}) must not exceed the window ({S})")
        abs_pos = idx + S * ((start - 1 - idx) // S)  # [S] per-slot key position
        cache_mask = (
            (abs_pos[None, :] >= 0)
            & (abs_pos[None, :] <= pos[:, None])
            & (abs_pos[None, :] > pos[:, None] - window)
        )
        self_mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - window)
        mask = jnp.concatenate([cache_mask, self_mask], axis=1)  # [c, S+c]
        if quant:
            k_prev = dequantize_kv(cache_k, k_scale, x.dtype)
            v_prev = dequantize_kv(cache_v, v_scale, x.dtype)
        else:
            k_prev, v_prev = cache_k, cache_v
        k_r = jnp.concatenate([k_prev, k], axis=1)
        v_r = jnp.concatenate([v_prev, v], axis=1)
        out = _attend(qg, k_r, v_r, mask[None, None, None], cfg.attn_logit_softcap)
        slots = pos % S
        if quant:
            cache_k = cache_k.at[:, slots].set(k_q)
            cache_v = cache_v.at[:, slots].set(v_q)
            k_scale = k_scale.at[:, slots].set(ks_q)
            v_scale = v_scale.at[:, slots].set(vs_q)
        else:
            cache_k = cache_k.at[:, slots].set(k.astype(cache_k.dtype))
            cache_v = cache_v.at[:, slots].set(v.astype(cache_v.dtype))
    elif vec:
        # per-row chunk writes: row b lands at [start[b], start[b]+len[b]);
        # padding columns redirect to an out-of-bounds row and are dropped
        valid = jnp.arange(c)[None, :] < lengths[:, None]  # [b, c]
        row = jnp.where(valid, pos, S)
        bidx = jnp.arange(b)[:, None]
        if quant:
            cache_k = cache_k.at[bidx, row].set(k_q, mode="drop")
            cache_v = cache_v.at[bidx, row].set(v_q, mode="drop")
            k_scale = k_scale.at[bidx, row].set(ks_q, mode="drop")
            v_scale = v_scale.at[bidx, row].set(vs_q, mode="drop")
            k_att = dequantize_kv(cache_k, k_scale, x.dtype)
            v_att = dequantize_kv(cache_v, v_scale, x.dtype)
        else:
            cache_k = cache_k.at[bidx, row].set(k.astype(cache_k.dtype), mode="drop")
            cache_v = cache_v.at[bidx, row].set(v.astype(cache_v.dtype), mode="drop")
            k_att, v_att = cache_k, cache_v
        # [b, c, S]: causal per row, padded query rows fully masked
        mask = (idx[None, None, :] <= pos[:, :, None]) & valid[:, :, None]
        out = _attend(qg, k_att, v_att, mask[:, None, None], cfg.attn_logit_softcap)
    else:
        if quant:
            cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_q, start, axis=1)
            cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_q, start, axis=1)
            k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks_q, start, axis=1)
            v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs_q, start, axis=1)
            k_att = dequantize_kv(cache_k, k_scale, x.dtype)
            v_att = dequantize_kv(cache_v, v_scale, x.dtype)
        else:
            cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), start, axis=1)
            cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), start, axis=1)
            k_att, v_att = cache_k, cache_v
        mask = idx[None, :] <= pos[:, None]  # [c, S]: causal over cache + chunk
        out = _attend(qg, k_att, v_att, mask[None, None, None], cfg.attn_logit_softcap)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    if quant:
        return y, cache_k, cache_v, k_scale, v_scale
    return y, cache_k, cache_v


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """int8 absmax quantisation over head_dim: [..., hd] → (int8, scale[...])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None]).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dt) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)).astype(dt)


def attention_decode(
    params: Params,
    x: jax.Array,  # [b, 1, d]
    cache_k: jax.Array,  # [b, S, nkv, hd], or pages [P, ps, nkv, hd] (paged)
    cache_v: jax.Array,
    cache_index: jax.Array,  # scalar int32 — number of tokens already cached
    cfg,
    window: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,  # [b, S, nkv] (int8 caches only)
    v_scale: Optional[jax.Array] = None,
    block_tables: Optional[jax.Array] = None,  # [b, n_blocks] int32 (paged)
):
    """One-token decode against a (possibly rolling) KV cache.

    ``cache_index`` may be a scalar (whole-batch position) or a [b] vector
    (per-request positions, continuous batching).  Keys are stored
    *post-rope* at absolute positions, so a rolling buffer needs no
    re-rotation.  Returns (out [b,1,d], new_cache_k, new_cache_v).

    With ``block_tables`` the caches are page pools ``[P, ps, nkv, hd]``
    (int8 scales ``[P, ps, nkv]``) indexed slot→page through the table: the
    new token writes into page ``bt[b, pos // ps]`` at offset ``pos % ps``,
    and reads gather the table's pages into the same ``[b, S, ...]`` view
    the contiguous path attends — identical values at every unmasked
    position, so paged decode is bit-identical to contiguous.  Unbacked
    table entries point at the null page; its garbage rows sit strictly
    beyond ``pos`` and contribute exact zeros through the mask.  Rolling
    windows are not paged (their buffers are already window-bounded).
    """
    b = x.shape[0]
    paged = block_tables is not None
    if paged:
        if window is not None:
            raise ValueError("paged KV caches do not support rolling windows")
        ps = cache_k.shape[1]
        S = block_tables.shape[1] * ps  # virtual per-slot length
    else:
        S = cache_k.shape[1]
    nkv = cfg.num_kv_heads
    per_req = jnp.ndim(cache_index) == 1
    pos = (
        cache_index[:, None] if per_req else jnp.broadcast_to(cache_index, (b, 1))
    )  # [b, 1]
    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"])
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"])
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"])
    if cfg.use_rope:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    quant = cache_k.dtype == jnp.int8
    if quant:
        k_w, ks_w = quantize_kv(k)
        v_w, vs_w = quantize_kv(v)
    else:
        k_w, v_w = k, v
    slot = pos % S if window is not None else pos  # [b, 1]
    if paged:
        bidx = jnp.arange(b)
        pg = block_tables[bidx, pos[:, 0] // ps]  # [b] page of each writer
        off = pos[:, 0] % ps
        cache_k = cache_k.at[pg, off].set(k_w[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[pg, off].set(v_w[:, 0].astype(cache_v.dtype))
        if quant:
            k_scale = k_scale.at[pg, off].set(ks_w[:, 0])
            v_scale = v_scale.at[pg, off].set(vs_w[:, 0])
    elif per_req:
        bidx = jnp.arange(b)
        cache_k = cache_k.at[bidx, slot[:, 0]].set(k_w[:, 0].astype(cache_k.dtype))
        cache_v = cache_v.at[bidx, slot[:, 0]].set(v_w[:, 0].astype(cache_v.dtype))
        if quant:
            k_scale = k_scale.at[bidx, slot[:, 0]].set(ks_w[:, 0])
            v_scale = v_scale.at[bidx, slot[:, 0]].set(vs_w[:, 0])
    else:
        s0 = slot[0, 0]
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_w.astype(cache_k.dtype), s0, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_w.astype(cache_v.dtype), s0, axis=1)
        if quant:
            k_scale = jax.lax.dynamic_update_slice_in_dim(k_scale, ks_w, s0, axis=1)
            v_scale = jax.lax.dynamic_update_slice_in_dim(v_scale, vs_w, s0, axis=1)
    idx = jnp.arange(S)
    mask = idx[None, :] <= pos  # [b, S] (rolling buffers are full once wrapped)
    qg = _group_q(q, nkv)
    if paged and not quant and paged_decode_backend() == "kernel":
        # page-indirect flash decode: scalar-prefetched block tables stream
        # each slot's pages HBM→VMEM, never materialising the gathered view
        from repro.kernels.decode_attention.ops import paged_decode_attention

        out = paged_decode_attention(
            q[:, 0], cache_k, cache_v, block_tables, pos[:, 0] + 1,
            logit_cap=float(cfg.attn_logit_softcap or 0.0),
        )[:, None]  # [b, 1, nh, hd]
    else:
        if paged:
            def gather(pool):
                return pool[block_tables].reshape(b, S, *pool.shape[2:])

            if quant:
                k_r = dequantize_kv(gather(cache_k), gather(k_scale), x.dtype)
                v_r = dequantize_kv(gather(cache_v), gather(v_scale), x.dtype)
            else:
                k_r, v_r = gather(cache_k), gather(cache_v)
        elif quant:
            k_r = dequantize_kv(cache_k, k_scale, x.dtype)
            v_r = dequantize_kv(cache_v, v_scale, x.dtype)
        else:
            k_r, v_r = cache_k, cache_v
        out = _attend(qg, k_r, v_r, mask[:, None, None, None, :], cfg.attn_logit_softcap)
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"])
    if quant:
        return y, cache_k, cache_v, k_scale, v_scale
    return y, cache_k, cache_v
