"""Dense feed-forward blocks (SwiGLU / GeGLU / plain GELU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, split_keys


def init_ffn(d_model: int, d_ff: int, activation: str, key, dtype=jnp.bfloat16) -> Params:
    k1, k2, k3 = split_keys(key, 3)
    params = {
        "w_up": dense_init(k1, (d_model, d_ff), dtype=dtype),
        "w_down": dense_init(k2, (d_ff, d_model), fan_in=d_ff, dtype=dtype),
    }
    if activation in ("swiglu", "geglu"):
        params["w_gate"] = dense_init(k3, (d_model, d_ff), dtype=dtype)
    return params


def ffn(params: Params, x: jax.Array, activation: str) -> jax.Array:
    up = jnp.einsum("...d,df->...f", x, params["w_up"])
    if activation == "swiglu":
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
        h = jax.nn.silu(gate) * up
    elif activation == "geglu":
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"])
        h = jax.nn.gelu(gate, approximate=True) * up
    else:  # gelu
        h = jax.nn.gelu(up, approximate=True)
    return jnp.einsum("...f,fd->...d", h, params["w_down"])
