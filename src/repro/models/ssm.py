"""Mamba-1 (falcon-mamba) and Mamba-2 (zamba2) state-space blocks.

Sequence mode (train / prefill) uses ``jax.lax.scan`` over time; decode mode
is a single recurrence step against carried (conv_state, ssm_state).  States
are float32 for numerical stability; activations follow the model dtype.

Layout notes (TPU-friendly):
  Mamba-1 state:  [batch, d_inner, state]
  Mamba-2 state:  [batch, heads, head_dim, state]
  conv state:     [batch, conv_k - 1, conv_dim]  (rolling window of inputs)
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init, split_keys


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_mamba(cfg, key, dtype=jnp.bfloat16) -> Params:
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    K = cfg.ssm_conv
    if cfg.ssm_version == 1:
        dt_rank = max(1, math.ceil(d / 16))
        k1, k2, k3, k4, k5 = split_keys(key, 5)
        return {
            "in_proj": dense_init(k1, (d, 2 * di), dtype=dtype),
            "conv_w": dense_init(k2, (K, di), fan_in=K, dtype=dtype),
            "conv_b": jnp.zeros((di,), dtype),
            "x_proj": dense_init(k3, (di, dt_rank + 2 * N), fan_in=di, dtype=dtype),
            "dt_proj": dense_init(k4, (dt_rank, di), fan_in=dt_rank, dtype=jnp.float32),
            "dt_bias": jnp.zeros((di,), jnp.float32),
            "A_log": jnp.log(
                jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))
            ),
            "D": jnp.ones((di,), jnp.float32),
            "out_proj": dense_init(k5, (di, d), fan_in=di, dtype=dtype),
        }
    # Mamba-2 (n_groups = 1)
    nh = cfg.ssm_num_heads
    cd = cfg.conv_dim
    k1, k2, k3 = split_keys(key, 3)
    return {
        "in_proj": dense_init(k1, (d, 2 * di + 2 * N + nh), dtype=dtype),
        "conv_w": dense_init(k2, (K, cd), fan_in=K, dtype=dtype),
        "conv_b": jnp.zeros((cd,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "out_proj": dense_init(k3, (di, d), fan_in=di, dtype=dtype),
    }


def init_ssm_state(cfg, batch: int) -> Tuple[jax.Array, jax.Array]:
    """(ssm_state f32, conv_state model-dtype) zeros for decode."""
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    conv = jnp.zeros((batch, cfg.ssm_conv - 1, cfg.conv_dim), dt)
    if cfg.ssm_version == 1:
        ssm = jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32)
    else:
        ssm = jnp.zeros(
            (batch, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        )
    return ssm, conv


# ---------------------------------------------------------------------------
# Depthwise causal conv
# ---------------------------------------------------------------------------


def _causal_conv_seq(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x [b, s, c], w [K, c] depthwise causal conv."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # sum of shifted slices — K is tiny (4), unrolled adds beat conv lowering
    s = x.shape[1]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + pad[:, i : i + s, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def _causal_conv_step(
    x_new: jax.Array, conv_state: jax.Array, w: jax.Array, b: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """x_new [b, c]; conv_state [b, K-1, c] (oldest first) → (y [b, c], new_state)."""
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # [b, K, c]
    y = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
    y = (y + b.astype(jnp.float32)).astype(x_new.dtype)
    return y, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------


def _m1_core_step(p, x_t, h, N, dt_rank):
    """x_t [b, di] post-conv, h [b, di, N] → (y [b, di], h')."""
    dbc = jnp.einsum("bd,dr->br", x_t.astype(jnp.float32), p["x_proj"].astype(jnp.float32))
    dt_in, B, C = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj"] + p["dt_bias"])  # [b, di]
    A = -jnp.exp(p["A_log"])  # [di, N]
    dA = jnp.exp(dt[:, :, None] * A[None])  # [b, di, N]
    dBx = (dt * x_t.astype(jnp.float32))[:, :, None] * B[:, None, :]
    h = dA * h + dBx
    y = jnp.einsum("bdn,bn->bd", h, C) + p["D"] * x_t.astype(jnp.float32)
    return y, h


def _conv_tail(x_pre: jax.Array, K: int) -> jax.Array:
    """Last K-1 pre-conv inputs, zero-padded at the front: [b, K-1, c]."""
    b, s, c = x_pre.shape
    pad = jnp.pad(x_pre, ((0, 0), (max(0, K - 1 - s), 0), (0, 0)))
    return pad[:, -(K - 1):, :]


SSM_CHUNK = 128  # time-chunk for the recurrent scan (memory/backward trade)


def _chunked_scan(step, h0, xs_t, seq_len: int):
    """scan(step) over time with per-chunk gradient checkpointing.

    A flat scan stores its f32 carry at EVERY timestep for the backward pass
    — for zamba2 train_4k that is 4096 × ~21 MB ≈ 85 GB per device (§Perf
    iteration Z1).  Chunking stores one carry per chunk and recomputes inside,
    bounding residuals to seq_len/SSM_CHUNK carries + one chunk's steps.
    """
    chunk = SSM_CHUNK
    if seq_len <= chunk or seq_len % chunk:
        return jax.lax.scan(step, h0, xs_t)

    @jax.checkpoint
    def chunk_body(h, xs_chunk):
        return jax.lax.scan(step, h, xs_chunk)

    n = seq_len // chunk
    xs_c = jax.tree.map(lambda a: a.reshape(n, chunk, *a.shape[1:]), xs_t)
    h_final, ys = jax.lax.scan(chunk_body, h0, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(n * chunk, *a.shape[2:]), ys)
    return h_final, ys


def mamba1_seq(p: Params, u: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """u [b, s, d] → (y [b, s, d], final ssm state, conv tail)."""
    d = cfg.d_model
    di, N = cfg.d_inner, cfg.ssm_state
    dt_rank = max(1, math.ceil(d / 16))
    xz = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    x, z = jnp.split(xz, 2, axis=-1)
    tail = _conv_tail(x, cfg.ssm_conv)
    x = jax.nn.silu(_causal_conv_seq(x, p["conv_w"], p["conv_b"]))

    def step(h, x_t):
        y, h = _m1_core_step(p, x_t, h, N, dt_rank)
        return h, y

    h0 = jnp.zeros((u.shape[0], di, N), jnp.float32)
    h_final, ys = _chunked_scan(step, h0, jnp.swapaxes(x, 0, 1), x.shape[1])
    y = jnp.swapaxes(ys, 0, 1).astype(u.dtype)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), h_final, tail


def mamba1_step(
    p: Params, u: jax.Array, conv_state: jax.Array, ssm_state: jax.Array, cfg
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """u [b, 1, d] decode step → (y [b, 1, d], conv_state', ssm_state')."""
    d, N = cfg.d_model, cfg.ssm_state
    dt_rank = max(1, math.ceil(d / 16))
    xz = jnp.einsum("bsd,de->bse", u, p["in_proj"])[:, 0]
    x, z = jnp.split(xz, 2, axis=-1)
    x_c, conv_state = _causal_conv_step(x, conv_state, p["conv_w"], p["conv_b"])
    x_c = jax.nn.silu(x_c)
    y, ssm_state = _m1_core_step(p, x_c, ssm_state, N, dt_rank)
    y = y.astype(u.dtype) * jax.nn.silu(z)
    return jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None], conv_state, ssm_state


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, n_groups = 1, scalar A per head)
# ---------------------------------------------------------------------------


def _m2_split(cfg, proj):
    di, N, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads
    z = proj[..., :di]
    xBC = proj[..., di : di + di + 2 * N]
    dt = proj[..., di + di + 2 * N :]
    assert dt.shape[-1] == nh
    return z, xBC, dt


def _m2_core_step(p, xBC_t, dt_t, h, cfg):
    """xBC_t [b, conv_dim] post-conv, dt_t [b, nh], h [b, nh, hd, N]."""
    di, N, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_num_heads, cfg.ssm_head_dim
    x = xBC_t[..., :di].astype(jnp.float32)
    B = xBC_t[..., di : di + N].astype(jnp.float32)
    C = xBC_t[..., di + N :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_t.astype(jnp.float32) + p["dt_bias"])  # [b, nh]
    A = -jnp.exp(p["A_log"])  # [nh]
    dA = jnp.exp(dt * A)  # [b, nh]
    xh = x.reshape(*x.shape[:-1], nh, hd)
    h = dA[..., None, None] * h + (dt[..., None] * xh)[..., None] * B[:, None, None, :]
    y = jnp.einsum("bhdn,bn->bhd", h, C) + p["D"][:, None] * xh
    return y.reshape(*y.shape[:-2], di), h


def mamba2_seq(p: Params, u: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    di = cfg.d_inner
    proj = jnp.einsum("bsd,de->bse", u, p["in_proj"])
    z, xBC, dt = _m2_split(cfg, proj)
    tail = _conv_tail(xBC, cfg.ssm_conv)
    xBC = jax.nn.silu(_causal_conv_seq(xBC, p["conv_w"], p["conv_b"]))

    def step(h, inp):
        xBC_t, dt_t = inp
        y, h = _m2_core_step(p, xBC_t, dt_t, h, cfg)
        return h, y

    b = u.shape[0]
    h0 = jnp.zeros((b, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
    h_final, ys = _chunked_scan(
        step, h0, (jnp.swapaxes(xBC, 0, 1), jnp.swapaxes(dt, 0, 1)), xBC.shape[1]
    )
    y = jnp.swapaxes(ys, 0, 1)
    y = _gated_rmsnorm(y, z.astype(jnp.float32), p["norm_scale"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y.astype(u.dtype), p["out_proj"]), h_final, tail


def mamba2_step(
    p: Params, u: jax.Array, conv_state: jax.Array, ssm_state: jax.Array, cfg
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    proj = jnp.einsum("bsd,de->bse", u, p["in_proj"])[:, 0]
    z, xBC, dt = _m2_split(cfg, proj)
    xBC_c, conv_state = _causal_conv_step(xBC, conv_state, p["conv_w"], p["conv_b"])
    xBC_c = jax.nn.silu(xBC_c)
    y, ssm_state = _m2_core_step(p, xBC_c, dt, ssm_state, cfg)
    y = _gated_rmsnorm(y[:, None], z[:, None].astype(jnp.float32), p["norm_scale"], cfg.norm_eps)[:, 0]
    return jnp.einsum("be,ed->bd", y.astype(u.dtype), p["out_proj"])[:, None], conv_state, ssm_state


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """Mamba-2 gated RMSNorm: norm(y * silu(z)) * (1 + scale)."""
    g = y.astype(jnp.float32) * jax.nn.silu(z)
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return g * jax.lax.rsqrt(var + eps) * (1.0 + scale)


# ---------------------------------------------------------------------------
# Family dispatch
# ---------------------------------------------------------------------------


def mamba_seq(p, u, cfg):
    return (mamba1_seq if cfg.ssm_version == 1 else mamba2_seq)(p, u, cfg)


def mamba_step(p, u, conv_state, ssm_state, cfg):
    return (mamba1_step if cfg.ssm_version == 1 else mamba2_step)(p, u, conv_state, ssm_state, cfg)
