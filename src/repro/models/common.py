"""Shared model components: norms, rotary/sinusoidal positions, init helpers.

Everything is purely functional: parameters are nested dict pytrees and every
op is jit/shard-friendly (einsum-first, no data-dependent shapes).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def param_dtype(cfg) -> jnp.dtype:
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.bfloat16) -> Params:
    return {"scale": jnp.zeros((d,), dtype=jnp.float32)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    normed = xf * jax.lax.rsqrt(var + eps)
    # (1 + scale) parameterisation (gemma-style); scale init 0 == identity
    return (normed * (1.0 + params["scale"])).astype(dt)


def init_layernorm(d: int) -> Params:
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y.astype(dt)


# ---------------------------------------------------------------------------
# Positional encodings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape [head_dim // 2] (float32)."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.

    x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq].
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # [hd/2]
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., seq, hd/2]
    sin = jnp.sin(ang)[..., None, :]  # [..., seq, 1, hd/2]
    cos = jnp.cos(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(num_pos: int, d_model: int, offset: int = 0) -> jax.Array:
    """Classic transformer sinusoidal table, [num_pos, d_model] (float32)."""
    pos = jnp.arange(offset, offset + num_pos, dtype=jnp.float32)[:, None]
    div = jnp.exp(
        jnp.arange(0, d_model, 2, dtype=jnp.float32) * (-math.log(10000.0) / d_model)
    )
    tbl = jnp.zeros((num_pos, d_model), jnp.float32)
    tbl = tbl.at[:, 0::2].set(jnp.sin(pos * div))
    tbl = tbl.at[:, 1::2].set(jnp.cos(pos * div))
    return tbl


def sinusoidal_at(positions: jax.Array, d_model: int) -> jax.Array:
    """Sinusoidal encoding for arbitrary integer positions, [..., d_model]."""
    pos = positions.astype(jnp.float32)[..., None]
    div = jnp.exp(
        jnp.arange(0, d_model, 2, dtype=jnp.float32) * (-math.log(10000.0) / d_model)
    )
    sin = jnp.sin(pos * div)
    cos = jnp.cos(pos * div)
    return jnp.stack([sin, cos], axis=-1).reshape(*positions.shape, d_model)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def dense_init(key, shape, fan_in: Optional[int] = None, dtype=jnp.bfloat16) -> jax.Array:
    fan = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
