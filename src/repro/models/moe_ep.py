"""Expert-parallel MoE layer via shard_map — the production dispatch path.

Mapping of Janus's disaggregated data plane onto the SPMD mesh (DESIGN.md §2):

* activations enter **replicated over the model axis** — the SPMD image of
  EGate ("send complete activations to the MoE side and gate there"): no
  routing metadata or per-expert packing crosses the wire, and on a
  hierarchical mesh XLA decomposes the implied broadcast into the intra-pod →
  cross-pod two-phase pattern;
* each model-axis shard is one **MoE instance**: it redundantly runs gating
  and the (deterministic) scheduler on the same inputs — Janus's
  synchronisation-free trick — then computes only the expert slots it hosts;
* the combine is a ``psum`` over the model axis (intra-node all-reduce before
  cross-node transfer in the reverse direction, §3.3).

Two modes:
  * ``logical``   — buckets are logical experts block-partitioned over the
    model axis (training / monolithic-baseline semantics);
  * ``scheduled`` — buckets are physical replica slots; per-token routing is
    rewritten by the scheduler (AEBS or a baseline) before dispatch — the
    Janus serving path.

Two per-shard dispatch bodies:
  * ``dispatch="scatter"`` — legacy scatter/one-hot capacity dispatch.  In
    scheduled mode without pinned replica weights this materialises a full
    ``[S_total, d, f]`` weight copy every call (``gather_slot_weights``).
  * ``dispatch="grouped"`` — sort-based grouped dispatch
    (:func:`repro.models.moe.grouped_dispatch_ffn`).  Replica weights are
    *never* copied per step: pinned deployments index their local
    slot-stacked slabs with the identity map, and unpinned deployments read
    the logical ``[E, d, f]`` weights slot-indirectly through the shard's
    slice of ``slot_to_expert`` (a shard_map operand partitioned over the
    model axis).  Inactive slots stream no weights, so per-instance cost
    tracks the activated-expert count (β·a_max).

    Note the memory trade of the *unpinned* grouped route: the logical
    weights are replicated across the model axis (``P(None, ...)``), so each
    shard holds all E experts instead of an ``S_total/n_model`` slice.  For
    deployments where expert weights only fit partitioned, pin replicas at
    reconfiguration time (``launch.steps.materialize_slot_params`` — the
    faithful Janus layout, and what ``launch.steps.make_moe_ctx`` sets up);
    pinned + grouped keeps both the partitioned memory footprint and the
    copy-free hot path.
"""

from __future__ import annotations

from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.ffn import ffn
from repro.models.moe import (
    gather_slot_weights,
    grouped_dispatch_ffn,
    load_balance_loss,
    route,
    scatter_dispatch_ffn,
)


def _pad_experts(w: jax.Array, e_pad: int) -> jax.Array:
    if w.shape[0] == e_pad:
        return w
    pad = e_pad - w.shape[0]
    return jnp.pad(w, ((0, pad),) + ((0, 0),) * (w.ndim - 1))


def moe_layer_ep(
    params: Dict[str, jax.Array],
    x: jax.Array,  # [b, s, d]
    cfg,
    *,
    mesh,
    dp_axes,
    model_axis: str,
    mode: str = "logical",  # logical | scheduled
    dispatch: str = "scatter",  # scatter | grouped (per-shard dispatch body)
    fsdp: bool = False,  # shard expert d_model over the data axes (training)
    scheduler: Optional[Callable] = None,
    layout_tables: Optional[Dict[str, jax.Array]] = None,
    slot_to_expert: Optional[jax.Array] = None,  # flat [S_total]
    num_instances: int = 0,
    capacity_factor: float = 2.0,
    with_aux: bool = False,
):
    b, s, d = x.shape
    n_model = mesh.shape[model_axis]
    dp_axes = tuple(a for a in dp_axes if a in mesh.shape)
    n_dp = 1
    for a in dp_axes:
        n_dp *= mesh.shape[a]
    batch_sharded = (b % n_dp) == 0 and n_dp > 1
    E, top_k = cfg.num_experts, cfg.top_k
    grouped = dispatch == "grouped"

    slot_indirect = False  # grouped + unpinned: logical weights + s2e slices
    if mode == "scheduled":
        assert slot_to_expert is not None and scheduler is not None
        total_slots = int(slot_to_expert.shape[0])
        assert total_slots % n_model == 0, (total_slots, n_model)
        if params["w_gate"].shape[0] == total_slots:
            # replica weights were pinned at deployment time
            # (launch.steps.materialize_slot_params) — the faithful Janus
            # layout: placement happens at reconfiguration, not per step.
            weights = {k: params[k] for k in ("w_gate", "w_up", "w_down")}
        elif grouped:
            # no per-step gather: each shard reads the logical weights
            # through its slice of slot_to_expert inside the dispatch body
            slot_indirect = True
            weights = {k: params[k] for k in ("w_gate", "w_up", "w_down")}
        else:
            weights = gather_slot_weights(params, slot_to_expert)
        buckets = total_slots
    else:
        e_pad = ((E + n_model - 1) // n_model) * n_model
        weights = {
            k: _pad_experts(params[k], e_pad) for k in ("w_gate", "w_up", "w_down")
        }
        buckets = e_pad

    buckets_local = buckets // n_model
    t_loc = (b // n_dp if batch_sharded else b) * s
    capacity = max(4, int(t_loc * top_k * capacity_factor / buckets))

    router_w = params["router"]
    n_sched = 3 if mode == "scheduled" else 0

    def body(xl, router_w, wg, wu, wd, *rest):
        # xl: [b_loc, s, d] — replicated over the model axis (EGate)
        g_idx = jax.lax.axis_index(model_axis)
        bl = xl.shape[0]
        x2d = xl.reshape(bl * s, d)
        if wg.shape[1] < d:
            # FSDP: weights arrive d_model-sharded over the data axes;
            # gather per layer (transpose = reduce-scatter of expert grads)
            wg = jax.lax.all_gather(wg, dp_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, dp_axes, axis=1, tiled=True)
        if wd.shape[2] < d:
            wd = jax.lax.all_gather(wd, dp_axes, axis=2, tiled=True)
        gates, eids, probs = route(router_w, x2d, top_k)

        if mode == "scheduled":
            tables = {
                "expert_hosts": rest[0],
                "replica_counts": rest[1],
                "slot_of": rest[2],
            }
            bucket_ids, load, _ = scheduler(eids, tables, num_instances)
        else:
            bucket_ids = eids
            load = None

        owner = bucket_ids // buckets_local
        local_slot = bucket_ids % buckets_local
        is_local = (owner == g_idx).reshape(-1)
        w_local = {"w_gate": wg, "w_up": wu, "w_down": wd}
        if grouped:
            if slot_indirect:
                s2e_local = rest[n_sched]  # [buckets_local] this shard's slice
            elif mode == "scheduled":
                # pinned slot-stacked weights: identity map (still gets the
                # inactive-slot skip from the stream/kernel backends)
                s2e_local = jnp.arange(buckets_local, dtype=jnp.int32)
            else:
                s2e_local = None  # buckets are (padded) logical experts
            y = grouped_dispatch_ffn(
                x2d,
                local_slot,
                gates.astype(x2d.dtype),
                buckets_local,
                capacity,
                w_local,
                slot_to_expert=s2e_local,
                item_mask=is_local,
            )
        else:
            y = scatter_dispatch_ffn(
                x2d,
                local_slot,
                gates.astype(x2d.dtype),
                buckets_local,
                capacity,
                w_local,
                item_mask=is_local,
            )
        y = jax.lax.psum(y, model_axis)
        aux_out = {}
        if with_aux:
            lb = load_balance_loss(probs, eids, E)
            if batch_sharded:
                lb = jax.lax.pmean(lb, dp_axes)
            aux_out["lb_loss"] = lb
            if load is not None:
                # straggler semantics: the layer finishes with the slowest
                # (data-shard, instance) pair → report max over data shards
                aux_out["load"] = (
                    jax.lax.pmax(load, dp_axes) if batch_sharded else load
                )
        return y.reshape(bl, s, d), aux_out

    xspec = P(dp_axes if batch_sharded else None, None, None)
    d_ok = fsdp and dp_axes and d % n_dp == 0
    if slot_indirect:
        # logical weights replicated across the model axis; indirection
        # replaces the per-shard weight partition
        wspec_gu = P(None, dp_axes if d_ok else None, None)
        wspec_d = P(None, None, dp_axes if d_ok else None)
    else:
        wspec_gu = P(model_axis, dp_axes if d_ok else None, None)
        wspec_d = P(model_axis, None, dp_axes if d_ok else None)
    in_specs = [xspec, P(None, None), wspec_gu, wspec_gu, wspec_d]
    operands = []
    if mode == "scheduled":
        operands += [
            layout_tables["expert_hosts"],
            layout_tables["replica_counts"],
            layout_tables["slot_of"],
        ]
        in_specs += [P(None, None), P(None), P(None, None)]
    if slot_indirect:
        operands.append(jnp.asarray(slot_to_expert, jnp.int32))
        in_specs.append(P(model_axis))  # each shard sees its own slice

    aux_specs = {}
    if with_aux:
        aux_specs["lb_loss"] = P()
        if mode == "scheduled":
            aux_specs["load"] = P(None)

    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=tuple(in_specs),
        out_specs=(xspec, aux_specs),
        check_rep=False,
    )(x, router_w, weights["w_gate"], weights["w_up"], weights["w_down"], *operands)

    if "shared" in params:
        # shared expert stays on the "attention side" (data-parallel partition)
        # and overlaps with the dispatch/combine collectives (§4).
        y = y + ffn(params["shared"], x, "swiglu")
    if with_aux:
        return y, aux
    return y
