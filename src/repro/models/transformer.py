"""Decoder-stack assembly for every architecture family.

The layer pattern of each config is *periodic* (see ``ModelConfig.layer_kinds``):
e.g. gemma2 alternates (local, global); zamba2 repeats (shared-attn+mamba,
mamba×5); most models have period 1.  We stack the parameters of each position
in the period along a leading ``n_periods`` axis and ``lax.scan`` over periods,
which keeps the lowered HLO small even for 60-layer models.

Entry points:
  * :func:`init_params`
  * :func:`forward`        — full-sequence logits (training)
  * :func:`prefill`        — full sequence → (last-token logits, decode caches)
  * :func:`decode_step`    — one token against the caches

Caches follow ``repro.configs.base._cache_specs`` layouts exactly, so
``input_specs`` stand-ins line up with the real pytrees.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import (
    Params,
    embed_init,
    init_rmsnorm,
    rmsnorm,
    sinusoidal_at,
    softcap,
    split_keys,
)

# ---------------------------------------------------------------------------
# Period structure
# ---------------------------------------------------------------------------


def period_pattern(cfg) -> Tuple[Tuple[str, ...], int]:
    """(kinds within one period, number of periods)."""
    kinds = cfg.layer_kinds()
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        p = cfg.hybrid_attn_every
    elif cfg.attn_pattern == "local_global":
        p = 2
    else:
        p = 1
    assert cfg.num_layers % p == 0, (cfg.name, cfg.num_layers, p)
    period = kinds[:p]
    assert kinds == period * (cfg.num_layers // p)
    return period, cfg.num_layers // p


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(kind: str, cfg, key, dtype) -> Params:
    d = cfg.d_model
    ks = split_keys(key, 4)
    if kind in ("dense", "dense_local"):
        return {
            "ln1": init_rmsnorm(d),
            "attn": attn_mod.init_attention(cfg, ks[0], dtype),
            "ln2": init_rmsnorm(d),
            "ffn": ffn_mod.init_ffn(d, cfg.d_ff, cfg.ffn_activation, ks[1], dtype),
        }
    if kind == "moe":
        return {
            "ln1": init_rmsnorm(d),
            "attn": attn_mod.init_attention(cfg, ks[0], dtype),
            "ln2": init_rmsnorm(d),
            "moe": moe_mod.init_moe(cfg, ks[1], dtype),
        }
    if kind in ("ssm", "ssm_hybrid"):
        return {"ln1": init_rmsnorm(d), "mamba": ssm_mod.init_mamba(cfg, ks[0], dtype)}
    if kind == "encdec":
        return {
            "ln1": init_rmsnorm(d),
            "attn": attn_mod.init_attention(cfg, ks[0], dtype),
            "ln_x": init_rmsnorm(d),
            "xattn": attn_mod.init_attention(cfg, ks[1], dtype),
            "ln2": init_rmsnorm(d),
            "ffn": ffn_mod.init_ffn(d, cfg.d_ff, cfg.ffn_activation, ks[2], dtype),
        }
    raise ValueError(kind)


def init_params(cfg, key) -> Params:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    period, n_periods = period_pattern(cfg)
    keys = split_keys(key, 8)
    params: Params = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dtype)}

    # decoder blocks: stacked over periods (vmap the per-layer init)
    dec_kinds = tuple("encdec" if cfg.encoder_layers else k for k in period)
    blocks = {}
    for pos, kind in enumerate(dec_kinds):
        pos_keys = jnp.stack(split_keys(jax.random.fold_in(keys[1], pos), n_periods))
        blocks[f"pos{pos}"] = jax.vmap(lambda k: _init_layer(kind, cfg, k, dtype))(pos_keys)
    params["blocks"] = blocks
    params["final_norm"] = init_rmsnorm(cfg.d_model)

    if cfg.family == "hybrid":
        # zamba2 shared (weight-tied) attention block: attn + dense FFN
        params["shared_attn"] = {
            "ln1": init_rmsnorm(cfg.d_model),
            "attn": attn_mod.init_attention(cfg, keys[2], dtype),
            "ln2": init_rmsnorm(cfg.d_model),
            "ffn": ffn_mod.init_ffn(cfg.d_model, cfg.d_ff, cfg.ffn_activation, keys[3], dtype),
        }
    if cfg.encoder_layers:
        enc_keys = jnp.stack(split_keys(keys[4], cfg.encoder_layers))
        params["encoder"] = jax.vmap(lambda k: _init_layer("dense", cfg, k, dtype))(enc_keys)
        params["enc_norm"] = init_rmsnorm(cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_tokens(params: Params, tokens: jax.Array, cfg, extra: Optional[Dict[str, Any]] = None) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.family != "audio":
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    if extra and cfg.frontend == "vision_patches" and "patch_embeds" in extra:
        p = extra["patch_embeds"]
        np_ = p.shape[1]
        x = jnp.concatenate([p.astype(x.dtype), x[:, np_:, :]], axis=1)
    return x


def lm_head(params: Params, x: jax.Array, cfg) -> jax.Array:
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("...d,vd->...v", x, params["embed"]).astype(jnp.float32)
    return softcap(logits, cfg.final_logit_softcap)


# ---------------------------------------------------------------------------
# Whisper encoder
# ---------------------------------------------------------------------------


def run_encoder(params: Params, frames: jax.Array, cfg) -> jax.Array:
    """frames [b, enc_seq, d] (stubbed conv/mel output) → encoder states."""
    x = frames + sinusoidal_at(jnp.arange(frames.shape[1]), cfg.d_model).astype(frames.dtype)

    def body(x, lp):
        h = attn_mod.attention_full(lp["attn"], rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg, causal=False)
        x = x + h
        x = x + ffn_mod.ffn(lp["ffn"], rmsnorm(lp["ln2"], x, cfg.norm_eps), cfg.ffn_activation)
        return x, None

    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rmsnorm(params["enc_norm"], x, cfg.norm_eps)


# ---------------------------------------------------------------------------
# Stage API — the attention/expert split the disaggregated executor places on
# separate device pools (Janus §3.1).  The monolithic paths below are plain
# compositions of these two stages, so pool-mode and mono execution share the
# exact op sequence (bit-identical logits between executors).
# ---------------------------------------------------------------------------


def attention_stage(lp, x, kv, cache_index, cfg, window=None, enc_out=None):
    """Attention half of one decode layer: ln1 → self-attention (cache write)
    → residual [→ cross-attention] → ln2.

    ``kv`` is a dict with keys ``k``/``v`` (plus ``k_scale``/``v_scale`` when
    ``cfg.kv_quant``, plus ``bt`` block tables when the cache is paged —
    then ``k``/``v`` are page pools).  Returns
    ``(x_resid, h_ffn, new_kv)``: the post-attention residual stream, the
    normalised FFN input to hand to :func:`moe_stage`, and the updated cache.
    """
    bt = kv.get("bt")
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.kv_quant:
        h, ck, cv, ks, vs = attn_mod.attention_decode(
            lp["attn"], h, kv["k"], kv["v"], cache_index, cfg,
            window=window, k_scale=kv["k_scale"], v_scale=kv["v_scale"],
            block_tables=bt,
        )
        new_kv = {"k": ck, "v": cv, "k_scale": ks, "v_scale": vs}
    else:
        h, ck, cv = attn_mod.attention_decode(
            lp["attn"], h, kv["k"], kv["v"], cache_index, cfg, window=window,
            block_tables=bt,
        )
        new_kv = {"k": ck, "v": cv}
    if bt is not None:
        new_kv["bt"] = bt
    x = x + h
    if enc_out is not None:
        hx = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        x = x + attn_mod.attention_cross(lp["xattn"], hx, enc_out, cfg)
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    return x, h2, new_kv


def attention_stage_full(lp, x, cfg, positions, window=None, enc_out=None, return_kv=False):
    """Full-sequence analogue of :func:`attention_stage` (training/prefill).

    Returns ``(x_resid, h_ffn, kv_or_None)``."""
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if return_kv:
        h, kv = attn_mod.attention_full(
            lp["attn"], h, cfg, positions=positions, window=window, return_kv=True
        )
    else:
        h = attn_mod.attention_full(lp["attn"], h, cfg, positions=positions, window=window)
        kv = None
    x = x + h
    if enc_out is not None:
        hx = rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        x = x + attn_mod.attention_cross(lp["xattn"], hx, enc_out, cfg)
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    return x, h2, kv


def attention_stage_chunk(lp, x, kv, start, cfg, window=None, lengths=None):
    """Chunked-prefill analogue of :func:`attention_stage`: ln1 → chunk
    attention against the cache (writes the chunk's KV at absolute positions
    ``[start, start+c)``) → residual → ln2.

    Same contract as the other stages — ``(x_resid, h_ffn, new_kv)`` — so the
    prefill worker composes it with :func:`moe_stage` exactly like the decode
    executors compose their halves.  Quantised (``cfg.kv_quant``) caches
    carry ``k_scale``/``v_scale`` through the same dict; the chunk is
    quantised once at its boundary (see :func:`attention_prefill_chunk`).

    Batched multi-prompt prefill passes vector ``start`` (``[b]``) and
    ``lengths`` (``[b]`` valid tokens per row, the rest padding).
    """
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.kv_quant:
        h, ck, cv, ks, vs = attn_mod.attention_prefill_chunk(
            lp["attn"], h, kv["k"], kv["v"], start, cfg, window=window,
            k_scale=kv["k_scale"], v_scale=kv["v_scale"], lengths=lengths,
        )
        new_kv = {"k": ck, "v": cv, "k_scale": ks, "v_scale": vs}
    else:
        h, ck, cv = attn_mod.attention_prefill_chunk(
            lp["attn"], h, kv["k"], kv["v"], start, cfg, window=window,
            lengths=lengths,
        )
        new_kv = {"k": ck, "v": cv}
    x = x + h
    h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    return x, h2, new_kv


def attention_stage_verify(lp, x, kv, cache_index, cfg, widths=None):
    """Speculative-verify analogue of :func:`attention_stage`: ``c`` candidate
    rows per slot, row ``j`` attending/writing at ``cache_index[b] + j``.

    Implemented as ``c`` unrolled one-token :func:`attention_stage` calls so
    every primitive runs with exactly the decode shapes — a batched
    ``[b, c, ·]`` formulation is mathematically equal but shape-dependent
    accumulation order can flip bf16 near-tie argmaxes, breaking the
    bit-exactness contract speculative acceptance relies on.  Rows at
    ``j >= widths[b]`` write at the cache's last row (the engine's parked-slot
    position), never at a readable position.

    Same ``(x_resid, h_ffn, new_kv)`` contract and the same ``kv`` dict
    (``bt`` block tables when paged), so the disaggregated executor composes
    it with :func:`moe_stage` exactly like the one-token stage."""
    bt = kv.get("bt")
    if bt is not None:
        cache_len = bt.shape[1] * kv["k"].shape[1]  # blocks × page rows
    else:
        cache_len = kv["k"].shape[1]
    b, c, _ = x.shape
    cache_index = jnp.asarray(cache_index)
    if jnp.ndim(cache_index) == 0:
        cache_index = jnp.full((b,), cache_index)
    xs, h2s = [], []
    cur = kv
    for j in range(c):
        pos_j = cache_index + j
        if widths is not None:
            pos_j = jnp.where(j < widths, jnp.minimum(pos_j, cache_len - 1), cache_len - 1)
        else:
            pos_j = jnp.minimum(pos_j, cache_len - 1)
        xj, h2j, cur = attention_stage(lp, x[:, j : j + 1], cur, pos_j, cfg)
        xs.append(xj)
        h2s.append(h2j)
    return jnp.concatenate(xs, axis=1), jnp.concatenate(h2s, axis=1), cur


def moe_stage(lp, x, h, cfg, moe_ctx=None, with_aux=False):
    """Expert half of one layer: MoE (or dense) FFN on the normalised input
    ``h``, added onto the residual stream ``x``.

    Works for both decode ([b, 1, d]) and full-sequence ([b, s, d]) inputs —
    the stage is position-independent, which is what lets the disaggregated
    executor ship ``h`` across pools.
    """
    if "moe" in lp:
        if with_aux:
            y, aux = moe_mod.moe_layer(lp["moe"], h, cfg, with_aux=True, **(moe_ctx or {}))
            return x + y, aux
        return x + moe_mod.moe_layer(lp["moe"], h, cfg, **(moe_ctx or {}))
    y = x + ffn_mod.ffn(lp["ffn"], h, cfg.ffn_activation)
    return (y, {}) if with_aux else y


# ---------------------------------------------------------------------------
# Full-sequence decoder pass (training / prefill)
# ---------------------------------------------------------------------------


def _layer_full(kind, lp, x, cfg, positions, shared_attn, enc_out, moe_ctx, collect):
    """One layer, full sequence.  Returns (x, cache_dict, aux).

    cache_dict keys (present only when ``collect``): "kv" = (k, v) post-rope,
    "ssm" = final recurrent state.  ssm_hybrid layers produce both.
    """
    aux = {}
    cache = {}
    if kind in ("dense", "dense_local", "moe", "encdec"):
        window = cfg.sliding_window if kind == "dense_local" else None
        x, h2, kv = attention_stage_full(
            lp, x, cfg, positions, window=window,
            enc_out=enc_out if kind == "encdec" else None, return_kv=collect,
        )
        if kv is not None:
            cache["kv"] = kv
        if kind == "moe":
            x, moe_aux = moe_stage(lp, x, h2, cfg, moe_ctx, with_aux=True)
            aux.update({k: v for k, v in moe_aux.items() if k == "lb_loss"})
        else:
            x = moe_stage(lp, x, h2, cfg)
    elif kind in ("ssm", "ssm_hybrid"):
        if kind == "ssm_hybrid":
            x, h2, kv = attention_stage_full(shared_attn, x, cfg, positions, return_kv=collect)
            if kv is not None:
                cache["kv"] = kv
            x = moe_stage(shared_attn, x, h2, cfg)
        y, state, conv_tail = ssm_mod.mamba_seq(lp["mamba"], rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg)
        x = x + y
        if collect:
            cache["ssm"] = state
            cache["conv"] = conv_tail
    else:
        raise ValueError(kind)
    return x, cache, aux


def forward(
    params: Params,
    tokens: jax.Array,
    cfg,
    extra: Optional[Dict[str, Any]] = None,
    collect_caches: bool = False,
    remat: bool = False,
):
    """Full-sequence pass.  Returns (hidden [b,s,d], caches_by_pos, aux).

    ``extra["act_constraint"]`` (optional): callable applied to the residual
    stream between layer periods — used by the distributed step builders for
    sequence-parallel sharding (§Perf Y3).
    """
    period, n_periods = period_pattern(cfg)
    dec_kinds = tuple("encdec" if cfg.encoder_layers else k for k in period)
    x = embed_tokens(params, tokens, cfg, extra)
    if cfg.family == "audio":
        x = x + sinusoidal_at(jnp.arange(x.shape[1]), cfg.d_model).astype(x.dtype)
    positions = jnp.arange(x.shape[1])
    enc_out = None
    if cfg.encoder_layers:
        enc_out = extra["enc_out"] if "enc_out" in (extra or {}) else run_encoder(params, extra["encoder_frames"], cfg)
    shared_attn = params.get("shared_attn")
    moe_ctx = (extra or {}).get("moe_ctx")

    act_constraint = (extra or {}).get("act_constraint")

    def body(carry, block_params):
        x, lb = carry
        caches = {}
        for pos, kind in enumerate(dec_kinds):
            lp = block_params[f"pos{pos}"]
            x, cache, aux = _layer_full(
                kind, lp, x, cfg, positions, shared_attn, enc_out, moe_ctx, collect_caches
            )
            if cache:
                caches[f"pos{pos}"] = cache
            if "lb_loss" in aux:
                lb = lb + aux["lb_loss"]
        if act_constraint is not None:
            x = act_constraint(x)
        return (x, lb), caches

    if remat:
        body = jax.checkpoint(body)
    (x, lb_total), caches = jax.lax.scan(body, (x, jnp.float32(0.0)), params["blocks"])
    aux = {"lb_loss": lb_total / max(1, cfg.num_layers), "enc_out": enc_out}
    return x, caches, aux


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------


def decode_step(
    params: Params,
    tokens: jax.Array,  # [b, 1]
    caches: Dict[str, jax.Array],
    cache_index: jax.Array,  # scalar
    cfg,
    extra: Optional[Dict[str, Any]] = None,
    unroll: bool = False,
):
    """One-token decode.  Returns (logits [b, vocab], new caches)."""
    period, n_periods = period_pattern(cfg)
    dec_kinds = tuple("encdec" if cfg.encoder_layers else k for k in period)
    x = embed_tokens(params, tokens, cfg, extra)
    if cfg.family == "audio":
        pos = cache_index if jnp.ndim(cache_index) == 1 else jnp.full((1,), cache_index)
        pe = sinusoidal_at(pos, cfg.d_model).astype(x.dtype)  # [b or 1, d]
        x = x + pe[:, None, :]
    enc_out = caches.get("enc_out")
    shared_attn = params.get("shared_attn")
    moe_ctx = (extra or {}).get("moe_ctx")

    # group cache arrays by period: [n_X, ...] -> [n_periods, per_period, ...]
    def regroup(name):
        a = caches[name]
        return a.reshape(n_periods, a.shape[0] // n_periods, *a.shape[1:])

    # Block tables (paged "" caches) are slot-indexed, shared by every layer,
    # and read-only inside the step — a scan closure constant, not a carried
    # cache array.
    block_tables = caches.get("block_tables")
    scan_caches = {
        k: regroup(k) for k in caches if k not in ("enc_out", "block_tables")
    }

    # static per-kind position counters inside one period
    def body(x, scanned):
        counters = {"full": 0, "local": 0, "hybrid": 0, "ssm": 0}

        def upd(name, idx, val):
            # functional per-period update of cache slice `name` at sub-index idx
            scanned[name] = scanned[name].at[idx].set(val)

        def kv_slice(suffix, i):
            kk, vk = f"kv_k{suffix}", f"kv_v{suffix}"
            kv = {"k": scanned[kk][i], "v": scanned[vk][i]}
            if cfg.kv_quant:
                kv["k_scale"] = scanned[kk + "_scale"][i]
                kv["v_scale"] = scanned[vk + "_scale"][i]
            if suffix == "" and block_tables is not None:
                kv["bt"] = block_tables
            return kv

        def kv_write(suffix, i, new_kv):
            upd(f"kv_k{suffix}", i, new_kv["k"])
            upd(f"kv_v{suffix}", i, new_kv["v"])
            if cfg.kv_quant:
                upd(f"kv_k{suffix}_scale", i, new_kv["k_scale"])
                upd(f"kv_v{suffix}_scale", i, new_kv["v_scale"])

        for pos, kind in enumerate(dec_kinds):
            lp = scanned["blocks"][f"pos{pos}"]
            if kind in ("dense", "moe", "encdec"):
                i = counters["full"]
                counters["full"] += 1
                x, h2, new_kv = attention_stage(
                    lp, x, kv_slice("", i), cache_index, cfg,
                    enc_out=enc_out if kind == "encdec" else None,
                )
                kv_write("", i, new_kv)
                x = moe_stage(lp, x, h2, cfg, moe_ctx if kind == "moe" else None)
            elif kind == "dense_local":
                i = counters["local"]
                counters["local"] += 1
                x, h2, new_kv = attention_stage(
                    lp, x, kv_slice("_local", i), cache_index, cfg, window=cfg.sliding_window
                )
                kv_write("_local", i, new_kv)
                x = moe_stage(lp, x, h2, cfg)
            elif kind in ("ssm", "ssm_hybrid"):
                if kind == "ssm_hybrid":
                    j = counters["hybrid"]
                    counters["hybrid"] += 1
                    x, h2, new_kv = attention_stage(
                        shared_attn, x, kv_slice("_hybrid", j), cache_index, cfg
                    )
                    kv_write("_hybrid", j, new_kv)
                    x = moe_stage(shared_attn, x, h2, cfg)
                i = counters["ssm"]
                counters["ssm"] += 1
                y, cc, cs = ssm_mod.mamba_step(
                    lp["mamba"], rmsnorm(lp["ln1"], x, cfg.norm_eps),
                    scanned["conv_state"][i], scanned["ssm_state"][i], cfg,
                )
                upd("conv_state", i, cc)
                upd("ssm_state", i, cs)
                x = x + y
        ys = {k: scanned[k] for k in scan_caches}
        return x, ys

    scanned_in = dict(scan_caches)
    scanned_in["blocks"] = params["blocks"]

    def scan_body(x, scanned):
        return body(x, dict(scanned))

    if unroll:
        # §Perf P1: unrolled layer loop — lax.scan double-buffers the cache
        # xs/ys (≥2 extra full-cache copies in temps); the unrolled form with
        # slice+stack measured 32.6 GiB/dev vs 36.2 (scan) and 36.5 (in-place
        # .at[i].set chain — §Perf P2, refuted: serialises buffer versions).
        outs = {k: [] for k in scan_caches}
        for i in range(n_periods):
            sl = {k: jax.tree.map(lambda a: a[i], v) for k, v in scanned_in.items()}
            x, ys = scan_body(x, sl)
            for k in outs:
                outs[k].append(ys[k])
        new_caches = {k: jnp.stack(v) for k, v in outs.items()}
    else:
        x, new_caches = jax.lax.scan(scan_body, x, scanned_in)
    out_caches = {
        k: v.reshape(caches[k].shape) for k, v in new_caches.items()
    }
    if enc_out is not None:
        out_caches["enc_out"] = enc_out
    if block_tables is not None:
        out_caches["block_tables"] = block_tables
    logits = lm_head(params, x[:, 0, :], cfg)
    return logits, out_caches


def supports_speculative_decode(cfg) -> bool:
    """The batched verify step covers dense/moe stacks with full-context
    attention only — the same uniform-grid constraint as batched prefill
    (rolling-window rows cannot share one multi-position write grid, and
    recurrent state consumes tokens serially)."""
    return supports_batched_prefill(cfg)


def decode_step_verify(
    params: Params,
    tokens: jax.Array,  # [b, c] — last accepted token + c-1 draft proposals
    caches: Dict[str, jax.Array],
    cache_index: jax.Array,  # [b] per-request write positions
    cfg,
    extra: Optional[Dict[str, Any]] = None,
    widths: Optional[jax.Array] = None,  # [b] valid rows per slot (≤ c)
):
    """Speculative verify: score ``c`` candidate positions per slot in one
    call.  Returns ``(logits [b, c, vocab], new caches)`` where row ``j``'s
    logits equal what :func:`decode_step` would produce after appending rows
    ``0..j-1`` — *bit-identical by construction*: the verify unrolls ``c``
    :func:`decode_step` computations inside one jit, so every primitive runs
    with exactly the one-token decode shapes.  A batched ``[b, c, ·]``
    formulation is mathematically equal but not bitwise — shape-dependent
    accumulation order can flip greedy argmax on bf16 near-ties, which is an
    observed failure, not a theoretical one — and bitwise is the contract
    the speculative engine's acceptance rule relies on.

    Rows at ``j >= widths[b]`` are parked: their write position is clamped
    to the cache's last row (exactly how the engine decodes parked slots),
    so rejected or padded candidates never dirty a readable cache row —
    rejection is pure position bookkeeping, no rollback."""
    if not supports_speculative_decode(cfg):
        raise ValueError(f"{cfg.name}: architecture does not support speculative decode")
    b, c = tokens.shape
    bt = caches.get("block_tables")
    if bt is not None:
        cache_len = bt.shape[1] * caches["kv_k"].shape[2]  # blocks × page rows
    else:
        cache_len = caches["kv_k"].shape[2]
    cache_index = jnp.asarray(cache_index)
    if jnp.ndim(cache_index) == 0:
        cache_index = jnp.full((b,), cache_index)
    logits_rows = []
    cur = caches
    for j in range(c):
        pos_j = cache_index + j
        if widths is not None:
            pos_j = jnp.where(j < widths, jnp.minimum(pos_j, cache_len - 1), cache_len - 1)
        else:
            pos_j = jnp.minimum(pos_j, cache_len - 1)
        lg, cur = decode_step(params, tokens[:, j : j + 1], cur, pos_j, cfg, extra=extra)
        logits_rows.append(lg)
    return jnp.stack(logits_rows, axis=1), cur


# ---------------------------------------------------------------------------
# Prefill: full pass + cache construction
# ---------------------------------------------------------------------------


def prefill(
    params: Params,
    tokens: jax.Array,  # [b, s]
    cfg,
    cache_len: int,
    extra: Optional[Dict[str, Any]] = None,
):
    """Returns (last-token logits [b, vocab], caches sized for cache_len)."""
    period, n_periods = period_pattern(cfg)
    dec_kinds = tuple("encdec" if cfg.encoder_layers else k for k in period)
    b, s = tokens.shape
    x, caches_by_pos, aux = forward(params, tokens, cfg, extra=extra, collect_caches=True)
    logits = lm_head(params, x[:, -1, :], cfg)

    out: Dict[str, jax.Array] = {}

    def stack_kv(sel):
        ks, vs = [], []
        for pos in sel:
            k, v = caches_by_pos[f"pos{pos}"]["kv"]
            ks.append(k)  # [n_periods, b, s, nkv, hd]
            vs.append(v)
        # interleave positions back into layer order
        K = jnp.stack(ks, axis=1).reshape(-1, b, s, cfg.num_kv_heads, cfg.resolved_head_dim)
        V = jnp.stack(vs, axis=1).reshape(-1, b, s, cfg.num_kv_heads, cfg.resolved_head_dim)
        return K, V

    full_pos = [p for p, k in enumerate(dec_kinds) if k in ("dense", "moe", "encdec")]
    local_pos = [p for p, k in enumerate(dec_kinds) if k == "dense_local"]
    hyb_pos = [p for p, k in enumerate(dec_kinds) if k == "ssm_hybrid"]
    ssm_pos = [p for p, k in enumerate(dec_kinds) if k.startswith("ssm")]

    def pad_to(K, L):
        if K.shape[2] == L:
            return K
        padded = jnp.zeros((K.shape[0], b, L, *K.shape[3:]), K.dtype)
        return jax.lax.dynamic_update_slice_in_dim(padded, K[:, :, :L], 0, axis=2)

    def emit(name, K, V):
        if cfg.kv_quant:
            out[f"kv_k{name}"], out[f"kv_k{name}_scale"] = attn_mod.quantize_kv(K)
            out[f"kv_v{name}"], out[f"kv_v{name}_scale"] = attn_mod.quantize_kv(V)
        else:
            out[f"kv_k{name}"], out[f"kv_v{name}"] = K, V

    if full_pos:
        K, V = stack_kv(full_pos)
        emit("", pad_to(K, cache_len), pad_to(V, cache_len))
    if local_pos:
        W = min(cache_len, cfg.sliding_window or cache_len)
        K, V = stack_kv(local_pos)
        # rolling layout: entry at absolute position p lives in slot p % W
        take = min(W, s)
        pos_abs = jnp.arange(take) + max(0, s - take)
        slots = pos_abs % W
        Kp = jnp.zeros((K.shape[0], b, W, *K.shape[3:]), K.dtype).at[:, :, slots].set(K[:, :, -take:])
        Vp = jnp.zeros((V.shape[0], b, W, *V.shape[3:]), V.dtype).at[:, :, slots].set(V[:, :, -take:])
        emit("_local", Kp, Vp)
    if hyb_pos:
        K, V = stack_kv(hyb_pos)
        emit("_hybrid", pad_to(K, cache_len), pad_to(V, cache_len))
    if ssm_pos:
        states = [caches_by_pos[f"pos{p}"]["ssm"] for p in ssm_pos]
        S = jnp.stack(states, axis=1)  # [n_periods, n_pos, ...]
        out["ssm_state"] = S.reshape(-1, *S.shape[2:]).astype(jnp.float32)
        tails = [caches_by_pos[f"pos{p}"]["conv"] for p in ssm_pos]
        T = jnp.stack(tails, axis=1)
        out["conv_state"] = T.reshape(-1, *T.shape[2:]).astype(x.dtype)
    if aux.get("enc_out") is not None:
        out["enc_out"] = aux["enc_out"]
    return logits, out


# ---------------------------------------------------------------------------
# Chunked prefill: fixed-size prompt chunks against decode-format caches
# ---------------------------------------------------------------------------


def supports_chunked_prefill(cfg) -> bool:
    """Chunked prefill covers pure attention+FFN stacks (dense / dense_local /
    moe), quantised or not.  Recurrent (ssm/hybrid) stacks consume the prompt
    serially through a state that :func:`prefill_chunk` does not carry, and
    encoder-decoder / frontend models need their encoder pass first — those
    fall back to whole-prompt :func:`prefill`.

    ``kv_quant`` configs use chunk-boundary-deterministic quantisation
    (:func:`attention_prefill_chunk`): each chunk is quantised exactly once
    and raw keys are never re-read across a boundary, so all serving paths —
    which share the worker's fixed chunk grid — produce bit-identical
    streams.  (The quantised result differs from whole-prompt
    :func:`prefill`, which attends raw keys, by ordinary quantisation error;
    determinism across admission modes / executors / replay is what the
    serving contract requires, and that holds.)"""
    if cfg.encoder_layers or cfg.frontend or cfg.family in ("audio", "ssm", "hybrid"):
        return False
    period, _ = period_pattern(cfg)
    return all(k in ("dense", "dense_local", "moe") for k in period)


def prefill_chunk(
    params: Params,
    tokens: jax.Array,  # [b, c] — one prompt chunk
    caches: Dict[str, jax.Array],  # decode-format stacked caches (partially filled)
    start: jax.Array,  # scalar int32 — absolute position of the chunk's first token
    cfg,
    extra: Optional[Dict[str, Any]] = None,
):
    """Process one fixed-size prompt chunk against partially-filled decode
    caches: every layer runs :func:`attention_stage_chunk` (chunk queries
    over all previously prefilled positions plus the chunk, chunk KV written
    at ``[start, start+c)``) then :func:`moe_stage`.

    Iterated over a prompt, this is bit-equivalent to whole-prompt
    :func:`prefill` whenever expert capacity is ample (per-chunk MoE packing
    can only *reduce* capacity drops — the same caveat as micro-batch
    ping-pong): per-token projections, rope and routing are position-indexed,
    and chunk-causal attention sees exactly the whole-prompt key sets.

    Returns ``(last-token logits [b, vocab], new caches)``.
    """
    if not supports_chunked_prefill(cfg):
        raise ValueError(f"{cfg.name}: architecture does not support chunked prefill")
    period, n_periods = period_pattern(cfg)
    x = embed_tokens(params, tokens, cfg, extra)
    moe_ctx = (extra or {}).get("moe_ctx")

    def regroup(name):
        a = caches[name]
        return a.reshape(n_periods, a.shape[0] // n_periods, *a.shape[1:])

    scan_caches = {k: regroup(k) for k in caches if k not in ("enc_out",)}

    def body(x, scanned):
        counters = {"full": 0, "local": 0}

        def kv_slice(suffix, i):
            kv = {"k": scanned[f"kv_k{suffix}"][i], "v": scanned[f"kv_v{suffix}"][i]}
            if cfg.kv_quant:
                kv["k_scale"] = scanned[f"kv_k{suffix}_scale"][i]
                kv["v_scale"] = scanned[f"kv_v{suffix}_scale"][i]
            return kv

        def kv_write(suffix, i, new_kv):
            scanned[f"kv_k{suffix}"] = scanned[f"kv_k{suffix}"].at[i].set(new_kv["k"])
            scanned[f"kv_v{suffix}"] = scanned[f"kv_v{suffix}"].at[i].set(new_kv["v"])
            if cfg.kv_quant:
                scanned[f"kv_k{suffix}_scale"] = scanned[f"kv_k{suffix}_scale"].at[i].set(new_kv["k_scale"])
                scanned[f"kv_v{suffix}_scale"] = scanned[f"kv_v{suffix}_scale"].at[i].set(new_kv["v_scale"])

        for pos, kind in enumerate(period):
            lp = scanned["blocks"][f"pos{pos}"]
            if kind in ("dense", "moe"):
                i = counters["full"]
                counters["full"] += 1
                x, h2, new_kv = attention_stage_chunk(lp, x, kv_slice("", i), start, cfg)
                kv_write("", i, new_kv)
            else:  # dense_local
                i = counters["local"]
                counters["local"] += 1
                x, h2, new_kv = attention_stage_chunk(
                    lp, x, kv_slice("_local", i), start, cfg, window=cfg.sliding_window
                )
                kv_write("_local", i, new_kv)
            x = moe_stage(lp, x, h2, cfg, moe_ctx if kind == "moe" else None)
        return x, {k: scanned[k] for k in scan_caches}

    scanned_in = dict(scan_caches)
    scanned_in["blocks"] = params["blocks"]
    x, new_caches = jax.lax.scan(lambda x, sc: body(x, dict(sc)), x, scanned_in)
    out_caches = {k: v.reshape(caches[k].shape) for k, v in new_caches.items()}
    logits = lm_head(params, x[:, -1, :], cfg)
    return logits, out_caches


def supports_batched_prefill(cfg) -> bool:
    """Batched multi-prompt chunked prefill (and the prefix cache, which
    shares its uniform chunk-grid requirement) covers dense/moe stacks with
    full-context attention only.  Rolling-window (``dense_local``) layers
    store position ``p`` at row ``p % window`` — rows from different
    per-request histories cannot share one padded write grid, and a prefix
    hit could not seed the wrapped window rows exactly."""
    if not supports_chunked_prefill(cfg):
        return False
    period, _ = period_pattern(cfg)
    return all(k in ("dense", "moe") for k in period)


def prefill_chunk_batched(
    params: Params,
    tokens: jax.Array,  # [b, c_max] — one chunk per prompt, zero-padded
    caches: Dict[str, jax.Array],  # decode-format caches, batch axis = b
    starts: jax.Array,  # [b] int32 — absolute position of each row's chunk
    lengths: jax.Array,  # [b] int32 — valid tokens per row (≤ c_max)
    cfg,
    extra: Optional[Dict[str, Any]] = None,
):
    """Multi-prompt :func:`prefill_chunk`: row ``b`` processes ``lengths[b]``
    prompt tokens starting at absolute position ``starts[b]``; rows are
    padded to a common width and masked, so several pending prompts share
    one kernel launch.  Rows are computed independently — padding adds query
    rows, never keys (padded cache writes are dropped), so each valid row is
    bit-identical to the serial :func:`prefill_chunk` path.

    Returns ``(per-row last-valid-position logits [b, vocab], new caches)``.
    """
    if not supports_batched_prefill(cfg):
        raise ValueError(f"{cfg.name}: architecture does not support batched prefill")
    period, n_periods = period_pattern(cfg)
    x = embed_tokens(params, tokens, cfg, extra)
    moe_ctx = (extra or {}).get("moe_ctx")

    def regroup(name):
        a = caches[name]
        return a.reshape(n_periods, a.shape[0] // n_periods, *a.shape[1:])

    scan_caches = {k: regroup(k) for k in caches if k not in ("enc_out",)}

    def body(x, scanned):
        counters = {"full": 0}

        def kv_slice(i):
            kv = {"k": scanned["kv_k"][i], "v": scanned["kv_v"][i]}
            if cfg.kv_quant:
                kv["k_scale"] = scanned["kv_k_scale"][i]
                kv["v_scale"] = scanned["kv_v_scale"][i]
            return kv

        def kv_write(i, new_kv):
            scanned["kv_k"] = scanned["kv_k"].at[i].set(new_kv["k"])
            scanned["kv_v"] = scanned["kv_v"].at[i].set(new_kv["v"])
            if cfg.kv_quant:
                scanned["kv_k_scale"] = scanned["kv_k_scale"].at[i].set(new_kv["k_scale"])
                scanned["kv_v_scale"] = scanned["kv_v_scale"].at[i].set(new_kv["v_scale"])

        for pos, kind in enumerate(period):
            lp = scanned["blocks"][f"pos{pos}"]
            i = counters["full"]
            counters["full"] += 1
            x, h2, new_kv = attention_stage_chunk(
                lp, x, kv_slice(i), starts, cfg, lengths=lengths
            )
            kv_write(i, new_kv)
            x = moe_stage(lp, x, h2, cfg, moe_ctx if kind == "moe" else None)
        return x, {k: scanned[k] for k in scan_caches}

    scanned_in = dict(scan_caches)
    scanned_in["blocks"] = params["blocks"]
    x, new_caches = jax.lax.scan(lambda x, sc: body(x, dict(sc)), x, scanned_in)
    out_caches = {k: v.reshape(caches[k].shape) for k, v in new_caches.items()}
    last = jnp.maximum(lengths - 1, 0)
    x_last = x[jnp.arange(x.shape[0]), last]  # [b, d] — each row's own tail
    logits = lm_head(params, x_last, cfg)
    return logits, out_caches
