"""Partition-spec rules for every parameter / input of every architecture.

Strategy (DESIGN.md §5):
  * batch-like axes → the data axes ("pod","data") when divisible;
  * Megatron-style tensor parallelism over the "model" axis for dense layers:
    shard the widest weight axis that divides by the model-axis size,
    preferring structured axes (heads, d_ff, experts, vocab) and falling back
    to the contraction axis (input d_model → psum'd partials) or replication;
  * expert weights shard on the expert/slot axis when divisible (expert
    parallelism — the MoE pool), else on d_ff;
  * decode caches shard batch over data axes and kv-heads over model when
    divisible, else the sequence axis (context parallelism — the long_500k
    path where batch = 1).

Everything returns plain ``PartitionSpec`` trees; ``NamedSharding`` binding
happens in ``repro.launch.steps``.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "idx"):
            names.append(str(p.idx))
    return tuple(names)


def param_pspec(
    names: Tuple[str, ...],
    shape: Tuple[int, ...],
    cfg: ModelConfig,
    n_model: int,
    model_axis: str,
    fsdp_axes: Tuple[str, ...] = (),
    n_fsdp: int = 1,
) -> P:
    """PartitionSpec for one parameter leaf.

    With ``fsdp_axes`` (training), a second weight axis is sharded over the
    data axes so parameters *and optimizer moments* scale with the cluster
    (GSPMD inserts the per-layer all-gathers; the shard_map MoE body gathers
    manually).  Serving passes no fsdp axes: weights are replicated across
    the data axes for latency (Janus attention instances hold full replicas).
    """
    stacked = "blocks" in names or "encoder" in names  # leading n_periods axis
    off = 1 if stacked else 0
    name = names[-1]
    dims = shape[off:]

    def spec(*entries):
        return P(*(((None,) * off) + entries))

    m = model_axis
    f_ = fsdp_axes if fsdp_axes else None

    def fs(dim):  # fsdp spec entry if divisible
        return f_ if f_ and _div(dim, n_fsdp) else None

    # --- embeddings ---------------------------------------------------------
    if name == "embed":
        if _div(shape[0], n_model):
            return P(m, fs(shape[1]))
        return P(None, fs(shape[1]))
    # --- norms / small vectors ----------------------------------------------
    if name in ("scale", "bias", "conv_b", "dt_bias", "A_log", "D", "norm_scale", "router"):
        return P(*((None,) * len(shape)))
    # --- attention ------------------------------------------------------------
    if name in ("wq", "wk", "wv"):
        nh = dims[1]
        if _div(nh, n_model):
            return spec(fs(dims[0]), m, None)
        return spec(m, None, None)  # row-parallel on d_model (psum partials)
    if name == "wo":
        nh, hd = dims[0], dims[1]
        if _div(nh, n_model):
            return spec(m, None, fs(dims[2]))
        if _div(hd, n_model):
            return spec(None, m, fs(dims[2]))
        return spec(None, None, fs(dims[2]))
    # --- MoE expert weights (3D) / dense FFN (2D) -------------------------------
    if name in ("w_gate", "w_up"):
        if len(dims) == 3:  # [E or S_slots, d, f]
            if _div(dims[0], n_model):
                return spec(m, fs(dims[1]), None)
            return spec(None, fs(dims[1]), m)
        return spec(fs(dims[0]), m)  # [d, f]
    if name == "w_down":
        if len(dims) == 3:  # [E, f, d]
            if _div(dims[0], n_model):
                return spec(m, None, fs(dims[2]))
            return spec(None, m, fs(dims[2]))
        return spec(m, fs(dims[1]))  # [f, d]
    # --- mamba -------------------------------------------------------------------
    if name == "in_proj":  # [d, proj_out]
        return spec(fs(dims[0]), m) if _div(dims[1], n_model) else spec(fs(dims[0]), None)
    if name == "out_proj":  # [di, d]
        return spec(m, fs(dims[1])) if _div(dims[0], n_model) else spec(None, fs(dims[1]))
    if name == "x_proj":  # [di, dt_rank + 2N]
        return spec(m, None) if _div(dims[0], n_model) else spec(None, None)
    if name in ("conv_w", "dt_proj"):
        return spec(*((None,) * len(dims)))
    return P(*((None,) * len(shape)))


def param_pspecs(cfg: ModelConfig, params_tree, mesh, fsdp: bool = False) -> Any:
    n_model = mesh.shape.get("model", 1)
    fsdp_axes = batch_axes(mesh) if fsdp else ()
    n_fsdp = 1
    for a in fsdp_axes:
        n_fsdp *= mesh.shape[a]
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(
            _path_names(path), leaf.shape, cfg, n_model, "model", fsdp_axes, n_fsdp
        ),
        params_tree,
    )


def batch_axes(mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def input_pspecs(
    cfg: ModelConfig, shape: InputShape, specs: Dict[str, jax.ShapeDtypeStruct], mesh
) -> Dict[str, P]:
    """PartitionSpecs for the abstract inputs of (cfg, shape)."""
    dp = batch_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    n_model = mesh.shape.get("model", 1)
    B = shape.global_batch
    bspec = dp if _div(B, n_dp) and n_dp > 1 else None

    out: Dict[str, P] = {}
    for name, s in specs.items():
        if name in ("tokens", "labels"):
            out[name] = P(bspec, None)
        elif name == "cache_index":
            out[name] = P()
        elif name.startswith("kv_") and name.endswith("_scale"):
            # [L, B, S, nkv] — mirror the int8 cache sharding minus head_dim
            nkv, S = s.shape[3], s.shape[2]
            if _div(nkv, n_model):
                out[name] = P(None, bspec, None, "model")
            elif bspec is None and _div(S, n_dp * n_model):
                out[name] = P(None, None, dp + ("model",), None)
            elif bspec is None and _div(S, n_dp):
                out[name] = P(None, None, dp, None)
            elif _div(S, n_model):
                out[name] = P(None, bspec, "model", None)
            else:
                out[name] = P(None, bspec, None, None)
        elif name.startswith("kv_"):
            # [L, B, S, nkv, hd]
            nkv, S = s.shape[3], s.shape[2]
            if _div(nkv, n_model):
                out[name] = P(None, bspec, None, "model", None)
            elif bspec is None and _div(S, n_dp * n_model):
                # context parallelism for batch=1 long-context decode
                out[name] = P(None, None, dp + ("model",), None, None)
            elif bspec is None and _div(S, n_dp):
                out[name] = P(None, None, dp, None, None)
            elif _div(S, n_model):
                # kv-heads don't divide the model axis → context-parallel
                # within the model group instead (sequence axis)
                out[name] = P(None, bspec, "model", None, None)
            else:
                out[name] = P(None, bspec, None, None, None)
        elif name == "ssm_state":
            # [L, B, di, N] (v1) or [L, B, H, hd, N] (v2)
            inner = s.shape[2]
            ispec = "model" if _div(inner, n_model) else None
            out[name] = P(None, bspec, ispec, *((None,) * (len(s.shape) - 3)))
        elif name == "conv_state":
            # [L, B, K-1, conv_dim]
            cspec = "model" if _div(s.shape[3], n_model) else None
            out[name] = P(None, bspec, None, cspec)
        elif name in ("enc_out", "encoder_frames", "patch_embeds"):
            out[name] = P(bspec, None, None)
        else:
            out[name] = P(*((None,) * len(s.shape)))
    return out


def activation_pspec(cfg: ModelConfig, mesh, batch: int) -> P:
    dp = batch_axes(mesh)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    return P(dp if _div(batch, n_dp) and n_dp > 1 else None, None, None)
