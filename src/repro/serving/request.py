"""Request model + workload generation (ShareGPT-like lengths, §5.1)."""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float  # seconds
    input_len: int
    output_len: int  # target generation length
    prompt: Optional[np.ndarray] = None  # token ids (synthetic)
    # admission deadline (absolute clock time): a request still waiting for a
    # slot / prefill-queue room past this moment is *rejected* — a clean
    # terminal state counted in metrics()["rejected"] — instead of queuing
    # unboundedly.  None = wait forever (the pre-backpressure behaviour).
    deadline: Optional[float] = None
    # runtime state
    slot: int = -1
    prefill_done: float = -1.0
    generated: int = 0
    token_times: Optional[List[float]] = None
    finished: float = -1.0
    # terminal admission rejection (deadline passed while saturated) — the
    # request never held a slot and emitted no tokens
    rejected: bool = False
    # context window exhausted before output_len tokens were generated — the
    # request still completes, but the cut is no longer silent
    truncated: bool = False
    # greedy token ids emitted for this request (first token from prefill,
    # then one per decode step) — lets tests assert bit-identical streams
    # across executors/admission modes, not just matching counts
    tokens_out: Optional[List[int]] = None
    # -- multi-tenant / SLO fields (trace replay harness) --------------------
    tenant: str = "default"
    klass: str = "chat"  # chat | long-context | batch-offline
    # higher wins under sched="priority": admitted first, and may preempt a
    # strictly lower-priority active slot (its KV spills, no copy)
    priority: int = 0
    ttft_slo: Optional[float] = None  # seconds, arrival → first token
    tpot_slo: Optional[float] = None  # seconds, p99 inter-token gap
    # times this request's slot was preempted (KV spilled, later restored)
    preemptions: int = 0
    # (spill_t, restore_t) spans this request spent parked off-batch between
    # two of its tokens — scheduling wait, not decode latency.  TPOT excludes
    # them so a preempted request's inter-token percentiles measure the same
    # thing as an uninterrupted one's.
    wait_spans: Optional[List[tuple]] = None

    def decode_gaps(self) -> np.ndarray:
        """Inter-token gaps over the decode phase, with any off-batch
        preemption wait split out of the gap it interrupted."""
        if not self.token_times or len(self.token_times) < 2:
            return np.zeros(0)
        times = np.asarray(self.token_times, float)
        gaps = np.diff(times)
        for a, b in self.wait_spans or []:
            i = int(np.searchsorted(times, a, side="right")) - 1
            if 0 <= i < len(gaps):
                gaps[i] = max(0.0, gaps[i] - (b - a))
        return gaps

    def tpot_p(self, q: float) -> float:
        """Per-token latency percentile over the decode phase (off-batch
        preemption waits excluded — see :meth:`decode_gaps`)."""
        gaps = self.decode_gaps()
        if not len(gaps):
            return 0.0
        return float(np.percentile(gaps, q))

    def ttft(self) -> Optional[float]:
        """Arrival → first token, or None if the request was never served."""
        if self.prefill_done < 0:
            return None
        return self.prefill_done - self.arrival

    def slo_ok(self) -> Optional[bool]:
        """Did this request meet its SLOs?  None when it carries none (not
        measured); False when it was rejected or never served — an unserved
        request with a latency target is an SLO miss, not a free pass."""
        if self.ttft_slo is None and self.tpot_slo is None:
            return None
        if self.rejected or self.prefill_done < 0:
            return False
        if self.ttft_slo is not None and self.ttft() > self.ttft_slo:
            return False
        if self.tpot_slo is not None and self.tpot_p(99.0) > self.tpot_slo:
            return False
        return True


@dataclasses.dataclass
class WorkloadSpec:
    """ShareGPT-replay style lengths (paper: avg input 16, avg output 256)."""

    mean_input: float = 16.0
    mean_output: float = 256.0
    vocab_size: int = 32_000
    max_input: int = 512
    max_output: int = 2048
    seed: int = 0
    # every prompt opens with the same ``shared_prefix_len`` tokens (a
    # fleet-wide system prompt) before its unique tail; 0 = fully independent
    # prompts.  The sampled lengths above size the *tails*.
    shared_prefix_len: int = 0


def long_prompt_spec(**overrides) -> WorkloadSpec:
    """Long-prompt preset (document QA / RAG style): heavy-tailed prompts a
    couple of orders longer than ShareGPT chat turns, short generations.
    This is the workload where blocking admission collapses — one 4k-token
    prefill stalls every in-flight decode — and what
    ``benchmarks/prefill_disagg_bench.py`` drives against the prefill pool."""
    spec = dict(mean_input=512.0, mean_output=64.0, max_input=4096, max_output=256)
    spec.update(overrides)
    return WorkloadSpec(**spec)


def shared_prefix_spec(**overrides) -> WorkloadSpec:
    """Shared-system-prompt preset (assistant / agent fleets): every request
    opens with the same long system prompt, then a short unique user turn.
    This is the workload the page-granular prefix cache exists for — after
    the first request, the shared span is pure block-table splicing — and
    what ``benchmarks/prefix_cache_bench.py`` drives."""
    spec = dict(
        mean_input=8.0, mean_output=24.0, max_input=32, max_output=64,
        shared_prefix_len=48,
    )
    spec.update(overrides)
    return WorkloadSpec(**spec)


def sample_lengths(
    spec: WorkloadSpec, n: int, rng: np.random.Generator
) -> "tuple[np.ndarray, np.ndarray]":
    """The single length-sampling path: lognormal with sigma≈1 (heavy tail,
    as observed in ShareGPT traces), scaled to the spec's means.  Both
    ``sample_requests`` and the ``ClusterSimulator`` derive lengths through
    here, so the replayed engine and the analytic simulator see one workload
    distribution instead of two independent guesses."""
    ins = rng.lognormal(mean=0.0, sigma=1.0, size=n)
    ins = np.clip((ins / ins.mean() * spec.mean_input).astype(int) + 1, 1, spec.max_input)
    outs = rng.lognormal(mean=0.0, sigma=1.0, size=n)
    outs = np.clip((outs / outs.mean() * spec.mean_output).astype(int) + 1, 1, spec.max_output)
    return ins, outs


def expected_tokens_per_request(spec: WorkloadSpec, n: int = 4096) -> float:
    """Mean decode length of ``spec``'s output distribution, measured through
    the same sampler ``sample_requests`` uses (clipping and the +1 shift
    included) — what the simulator should feed its per-window token demand
    instead of a hand-picked scalar."""
    rng = np.random.default_rng(spec.seed)
    _ins, outs = sample_lengths(spec, n, rng)
    return float(outs.mean())


def sample_requests(
    spec: WorkloadSpec, arrivals: np.ndarray, with_prompts: bool = False
) -> List[Request]:
    """One request per arrival time, lengths from :func:`sample_lengths`."""
    rng = np.random.default_rng(spec.seed)
    n = len(arrivals)
    ins, outs = sample_lengths(spec, n, rng)
    shared = None
    if spec.shared_prefix_len > 0:
        shared = rng.integers(
            0, spec.vocab_size, size=spec.shared_prefix_len, dtype=np.int32
        )
    reqs = []
    for i, t in enumerate(np.sort(arrivals)):
        prompt = None
        n_in = int(ins[i]) + spec.shared_prefix_len
        if with_prompts:
            prompt = rng.integers(0, spec.vocab_size, size=int(ins[i]), dtype=np.int32)
            if shared is not None:
                prompt = np.concatenate([shared, prompt])
        reqs.append(
            Request(rid=i, arrival=float(t), input_len=n_in, output_len=int(outs[i]), prompt=prompt, token_times=[])
        )
    return reqs
