"""Two-pool disaggregated decode execution — Janus §3.1–§3.3 made runnable.

:class:`DisaggExecutor` drives one continuous-batching decode step across two
real device pools:

* the **attention pool** (``pools.attn_devices``) holds a full
  attention-stack replica per device and a contiguous *batch shard* of the
  in-flight KV caches; every layer's :func:`repro.models.transformer
  .attention_stage` runs there;
* the **MoE pool** (``pools.moe_devices``) holds only each device's expert
  replica-slot weights (plus the replicated router — EGate gates on the MoE
  side, §3.2); every layer's expert FFN runs there over *local slots only*,
  with the AEBS schedule recomputed redundantly per device
  (synchronisation-free, §3.4).

The per-layer hand-off is an explicit transfer whose pattern (case-1 direct
node-to-node vs case-2 pair + multicast) is chosen per step via
:func:`repro.core.comm.adaptive_two_phase` and executed as the grouped
``device_put`` schedule from :func:`repro.core.disagg.plan_exchange`.
Per-step regime, per-fabric bytes and message counts are returned as
telemetry and surfaced by ``ServingEngine.metrics()``.

Numerics: the executor composes the exact op sequence of the monolithic
``decode_step`` (stage split + item-level dispatch + attention-side
combine), so sequential pool mode produces **bit-identical logits** to the
monolithic engine.  Micro-batch ping-pong (``ping_pong=True``, m=2 —
MegaScale-style overlap of attention(i) with MoE(i+1)) routes each
micro-batch independently; it is bit-identical as well whenever expert
capacity is ample (per-micro-batch packing can only *reduce* capacity
drops).

``reconfigure`` actuates a §3.5 scaling decision mid-run: only the pool
whose count changed is re-lowered, and KV caches are re-sharded in place so
in-flight requests continue undisturbed.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.core.comm import TPU_V5E, CommConfig, HardwareSpec, adaptive_two_phase
from repro.core.disagg import DevicePools, DisaggConfig, plan_exchange
from repro.core.disagg import reconfigure as disagg_reconfigure
from repro.models import model as model_mod
from repro.models import moe as moe_mod
from repro.models import transformer
from repro.models.ffn import ffn
from repro.serving.kv_cache import PagedKVCache, PrefixIndex, SpilledKV

_KV_KEYS = {"k": "kv_k", "v": "kv_v", "k_scale": "kv_k_scale", "v_scale": "kv_v_scale"}


@dataclasses.dataclass
class SpilledSlotKV:
    """A preempted slot's detached KV on a disagg executor: the shard-local
    :class:`SpilledKV` record, the shard that owns the pages (ids are
    pool-local, so restores are shard-affine), and the executor-level live
    length to put back into ``_slot_len`` on restore."""

    shard: int
    rec: SpilledKV
    tokens: int


@dataclasses.dataclass
class _Shard:
    """One attention-pool batch shard (a micro-batch slice of one device)."""

    dev_index: int  # index into pools.attn_devices
    mb: int  # micro-batch id (0 in sequential mode)
    lo: int  # global batch row range [lo, hi)
    hi: int

    @property
    def rows(self) -> int:
        return self.hi - self.lo


def _shard_bounds(max_batch: int, n: int) -> List[Tuple[int, int]]:
    sizes = [max_batch // n + (1 if i < max_batch % n else 0) for i in range(n)]
    bounds, lo = [], 0
    for s in sizes:
        bounds.append((lo, lo + s))
        lo += s
    return bounds


class DisaggExecutor:
    """Placement + per-layer cross-pool exchange for one decode deployment."""

    def __init__(
        self,
        cfg,
        params,
        pools: DevicePools,
        layout: ReplicaLayout,
        *,
        max_batch: int,
        cache_len: int,
        scheduler: Callable = aebs_assign,
        capacity: Optional[int] = None,
        ping_pong: bool = False,
        hw: HardwareSpec = TPU_V5E,
        devices: Optional[Sequence[jax.Device]] = None,
        kv_page_size: Optional[int] = None,
        kv_num_pages: Optional[int] = None,
        prefix_cache: bool = False,
        prefix_cache_pages: Optional[int] = None,
        prefix_chunk: int = 64,
    ):
        if not cfg.has_moe:
            raise ValueError("disagg executor requires an MoE architecture")
        period, n_periods = transformer.period_pattern(cfg)
        if cfg.encoder_layers or cfg.frontend or any(
            k not in ("dense", "moe") for k in period
        ):
            raise ValueError(
                f"disagg executor supports attention+FFN stacks only, got {period}"
            )
        if not moe_mod.scheduler_is_single_replica(scheduler):
            raise ValueError(
                "disagg executor requires a single-active-replica scheduler "
                "(AEBS/random) so replica slots carry exact expert semantics"
            )
        if len(pools.attn_devices) < 1:
            raise ValueError("attention pool must have ≥ 1 device")
        self.cfg = cfg
        self.params = params
        self.pools = pools
        self.scheduler = scheduler
        self.capacity = capacity
        self.ping_pong = ping_pong
        self.hw = hw
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.kv_page_size = kv_page_size
        self.kv_num_pages = kv_num_pages
        # per-shard page managers (local-row block tables); None = contiguous
        self._pagers: Optional[List[PagedKVCache]] = None
        # prefix cache: one radix index per attention shard (a slot can only
        # share pages with slots on its own shard — page ids are pool-local)
        self.prefix_cache = bool(prefix_cache) and kv_page_size is not None
        self.prefix_cache_pages = prefix_cache_pages
        self.prefix_chunk = max(1, int(prefix_chunk))
        self._indexes: Optional[List[PrefixIndex]] = None
        self._prefix_carry = {
            "hits": 0, "misses": 0, "saved_tokens": 0,
            "lookup_tokens": 0, "evicted_pages": 0,
        }
        # per-slot live KV length — executor-level so it survives re-sharding
        # (reconfigure / drop_attn_device rebuild block tables from it)
        self._slot_len = np.zeros(max_batch, np.int64)
        # fault-injection hook (repro.serving.faults): called before each
        # cross-pool exchange with (site, layer, micro_batch); may raise
        # PoolFault.  None (the default) keeps the fault-free path untouched.
        self.fault_hook = None
        combo_all = (
            list(pools.attn_devices)
            + list(pools.prefill_devices)
            + list(pools.moe_devices)
        )
        # degenerate single-host test pools alias physical devices; device
        # exclusion and exceeds-available validation are meaningless there
        self._aliased = len({id(d) for d in combo_all}) < len(combo_all)
        if devices is not None:
            self._all_devices = list(devices)
        else:
            # reconfigure must re-split the same universe the pools came
            # from: detect the standard three-way split of the global device
            # list; anything else is a custom pool set — stay inside it.
            universe = jax.devices()
            combo = (
                list(pools.attn_devices)
                + list(pools.prefill_devices)
                + list(pools.moe_devices)
            )
            n_a, n_e = len(pools.attn_devices), len(pools.moe_devices)
            n_p = len(pools.prefill_devices)
            std = (
                universe[:n_a]
                + universe[len(universe) - n_e - n_p : len(universe) - n_e]
                + universe[len(universe) - n_e :]
            )
            self._all_devices = None if combo == std else combo
        self.disagg_cfg = DisaggConfig(
            len(pools.attn_devices), len(pools.moe_devices), layout,
            n_prefill=len(pools.prefill_devices),
        )
        self.relower_log: List[Dict[str, bool]] = []

        # layer enumeration: (period_index, pos, kind, kv cache layer index)
        full_pos = [p for p, k in enumerate(period) if k in ("dense", "moe")]
        rank = {p: r for r, p in enumerate(full_pos)}
        self._layers = [
            (per, pos, kind, per * len(full_pos) + rank[pos])
            for per in range(n_periods)
            for pos, kind in enumerate(period)
        ]

        self._build_moe_side(layout)
        self._build_attn_side(len(pools.attn_devices), caches=None)
        self._build_attn_jits()
        self._build_moe_jits()

    # ------------------------------------------------------------------
    # placement
    # ------------------------------------------------------------------
    def _layer_param(self, per: int, pos: int):
        return jax.tree.map(lambda a: a[per], self.params["blocks"][f"pos{pos}"])

    def _build_attn_side(self, n_attn: int, caches) -> None:
        """(Re-)place attention params and KV cache shards on ``n_attn``
        devices.  ``caches`` is the stacked engine-format cache dict to
        re-shard (zeros when None)."""
        cfg = self.cfg
        if caches is None:
            caches = model_mod.init_decode_caches(cfg, self.max_batch, self.cache_len)

        pools = self.pools
        bounds = _shard_bounds(self.max_batch, n_attn)
        if self.ping_pong and any(hi - lo < 2 for lo, hi in bounds):
            raise ValueError(
                f"ping_pong (m=2) needs ≥2 batch rows per attention device "
                f"(max_batch={self.max_batch}, n_attn={n_attn})"
            )
        self.shards: List[_Shard] = []
        for i, (lo, hi) in enumerate(bounds):
            if self.ping_pong:
                mid = lo + (hi - lo) // 2
                self.shards.append(_Shard(i, 0, lo, mid))
                self.shards.append(_Shard(i, 1, mid, hi))
            else:
                self.shards.append(_Shard(i, 0, lo, hi))
        self.n_micro = 1 + int(any(s.mb == 1 for s in self.shards))

        # attention-side parameters, replicated per pool device
        attn_layers = []
        shared_layers = []
        for per, pos, kind, _ in self._layers:
            lp = self._layer_param(per, pos)
            alp = {k: lp[k] for k in ("ln1", "attn", "ln2")}
            if kind == "dense":
                alp["ffn"] = lp["ffn"]
            attn_layers.append(alp)
            shared_layers.append(
                lp["moe"].get("shared") if kind == "moe" else None
            )
        tree = {
            "embed": self.params["embed"],
            "final_norm": self.params["final_norm"],
            "layers": attn_layers,
            "shared": shared_layers,
        }
        self._attn_params = [
            jax.device_put(tree, dev) for dev in pools.attn_devices
        ]

        # KV cache shards: per shard, per kv-layer, the engine cache rows.
        # Paged mode replaces each shard's [rows, S, ...] slabs with per-shard
        # page pools [P, ps, ...] + a local-row block table, re-paginated from
        # the dense input using the executor-level ``_slot_len`` — page ids
        # change across re-shards, the position→value mapping never does.
        self._kv: List[List[Dict[str, jax.Array]]] = []
        n_kv_layers = len({c for *_x, c in self._layers})
        if self.kv_page_size is not None:
            ps = self.kv_page_size
            np_caches = {
                name: np.asarray(caches[name])
                for name in _KV_KEYS.values()
                if name in caches
            }
            self._pagers = []
            for s in self.shards:
                dev = pools.attn_devices[s.dev_index]
                if self.kv_num_pages is None:
                    shard_pages = None  # full backing for the shard's rows
                else:
                    # split the operator's pool budget proportionally to rows
                    # (each shard keeps its own null page)
                    shard_pages = 1 + max(
                        1, round((self.kv_num_pages - 1) * s.rows / self.max_batch)
                    )
                pager = PagedKVCache(s.rows, self.cache_len, ps, shard_pages)
                for r in range(s.rows):
                    ln = int(self._slot_len[s.lo + r])
                    if ln > 0:
                        pager.ensure(r, ln - 1)
                bt = pager.table_device(dev)
                per_layer = []
                for l in range(n_kv_layers):
                    layer = {}
                    for short, name in _KV_KEYS.items():
                        if name not in np_caches:
                            continue
                        src = np_caches[name][l]  # [B, S, ...]
                        pool = np.zeros(
                            (pager.num_pages, ps, *src.shape[2:]), src.dtype
                        )
                        for r in range(s.rows):
                            ln = int(self._slot_len[s.lo + r])
                            if ln > 0:
                                pages, offs = pager.rows_of(r, 0, ln)
                                pool[pages, offs] = src[s.lo + r, :ln]
                        layer[short] = jax.device_put(jnp.asarray(pool), dev)
                    layer["bt"] = bt
                    per_layer.append(layer)
                self._pagers.append(pager)
                self._kv.append(per_layer)
        else:
            for s in self.shards:
                dev = pools.attn_devices[s.dev_index]
                per_layer = []
                for l in range(n_kv_layers):
                    per_layer.append(
                        {
                            short: jax.device_put(caches[name][l, s.lo : s.hi], dev)
                            for short, name in _KV_KEYS.items()
                            if name in caches
                        }
                    )
                self._kv.append(per_layer)

        # prefix indexes are shard-local: a re-shard re-assigns page ids, so
        # sharing dissolves and the indexes restart empty (correct — exported
        # rows were gathered through the shared pages before the rebuild).
        # Cumulative hit/miss telemetry carries over.
        old_indexes = getattr(self, "_indexes", None)
        if old_indexes:
            carry = self._prefix_carry
            for ix in old_indexes:
                carry["hits"] += ix.hits
                carry["misses"] += ix.misses
                carry["saved_tokens"] += ix.saved_tokens
                carry["lookup_tokens"] += ix.lookup_tokens
                carry["evicted_pages"] += ix.evicted_pages
        self._indexes = None
        if self.prefix_cache and self._pagers is not None:
            self._indexes = []
            for s, pager in zip(self.shards, self._pagers):
                budget = None
                if self.prefix_cache_pages is not None:
                    # split the operator's pin budget proportionally to rows
                    budget = max(
                        1, round(self.prefix_cache_pages * s.rows / self.max_batch)
                    )
                self._indexes.append(
                    PrefixIndex(self.prefix_chunk, pager, max_pages=budget)
                )

        # exchange schedule (regime chosen per step; both plans precomputed)
        self._plans = {r: plan_exchange(self.pools, r) for r in ("case1", "case2")}

    def _build_moe_side(self, layout: ReplicaLayout) -> None:
        cfg = self.cfg
        if layout.num_instances != len(self.pools.moe_devices):
            raise ValueError(
                f"layout has {layout.num_instances} instances but pool has "
                f"{len(self.pools.moe_devices)} MoE devices"
            )
        self.layout = layout
        self.n_moe = layout.num_instances
        self.C = layout.capacity
        self.S_total = layout.total_slots
        self.cap = self.capacity or moe_mod.default_capacity(
            self.max_batch, cfg.top_k, self.S_total, cfg.capacity_factor
        )
        tables = layout.device_tables()
        stx = np.asarray(layout.slot_to_expert)
        self._moe_params = []
        for g, dev in enumerate(self.pools.moe_devices):
            local = np.maximum(stx[g], 0)
            layers = []
            for per, pos, kind, _ in self._layers:
                if kind != "moe":
                    layers.append(None)
                    continue
                mp = self._layer_param(per, pos)["moe"]
                layers.append(
                    {
                        "router": mp["router"],
                        "w": {
                            k: jnp.take(mp[k], jnp.asarray(local), axis=0)
                            for k in ("w_gate", "w_up", "w_down")
                        },
                    }
                )
            self._moe_params.append(
                jax.device_put(
                    {
                        "layers": layers,
                        "tables": tables,
                        "lo": jnp.int32(g * self.C),
                    },
                    dev,
                )
            )

    def _build_attn_jits(self) -> None:
        """Attention-pool stage functions.  Closures depend only on ``cfg``;
        a pool resize changes shard shapes, which jax re-traces under the
        same jit (new entries in the executable cache) — the MoE-pool
        executables are untouched."""
        cfg = self.cfg

        def embed_fn(emb, tokens):
            x = emb[tokens]
            return x * jnp.asarray(cfg.d_model**0.5, x.dtype)

        def attn_fn(lp, x, kv, positions):
            return transformer.attention_stage(lp, x, kv, positions, cfg)

        def dense_fn(lp, x, h2):
            return transformer.moe_stage(lp, x, h2, cfg)

        def head_fn(p, x):
            return transformer.lm_head(
                {"final_norm": p["final_norm"], "embed": p["embed"]}, x[:, 0, :], cfg
            )

        def attn_verify_fn(lp, x, kv, positions, widths):
            return transformer.attention_stage_verify(
                lp, x, kv, positions, cfg, widths=widths
            )

        def head_verify_fn(p, x):  # [rows, c, d] -> [rows, c, vocab]
            # per-position lm_head calls: each column runs with the exact
            # one-token decode shapes, keeping verify logits bitwise equal
            # to sequential decode (a [rows*c, d] matmul is not)
            pp = {"final_norm": p["final_norm"], "embed": p["embed"]}
            cols = [
                transformer.lm_head(pp, x[:, j, :], cfg) for j in range(x.shape[1])
            ]
            return jnp.stack(cols, axis=1)

        self._embed_jit = jax.jit(embed_fn)
        self._attn_jit = jax.jit(attn_fn)
        self._dense_jit = jax.jit(dense_fn)
        self._head_jit = jax.jit(head_fn)
        self._attn_verify_jit = jax.jit(attn_verify_fn)
        self._head_verify_jit = jax.jit(head_verify_fn)

    def _build_moe_jits(self) -> None:
        """MoE-pool stage functions + the attention-side combine.  Closures
        bake in the layout constants (n_e, C, cap), so these — and only
        these — are re-lowered when the MoE pool or layout changes."""
        cfg = self.cfg
        scheduler = self.scheduler
        n_moe, C, cap = self.n_moe, self.C, self.cap

        def moe_fn(mp, tables, lo, h):
            h2d = h.reshape(-1, h.shape[-1])
            gates, eids, _ = moe_mod.route(mp["router"], h2d, cfg.top_k)
            slot_ids, load, _ = scheduler(eids, tables, n_moe)
            local = (slot_ids >= lo) & (slot_ids < lo + C)
            buckets = jnp.where(local, slot_ids - lo, -1)
            y_items, keep = moe_mod.grouped_dispatch_items(
                h2d, buckets, C, cap, mp["w"], backend="einsum"
            )
            return y_items, keep, local.reshape(-1), gates, load

        def combine_fn(x, h2, shared_p, parts, gates):
            b = x.shape[0]
            d = x.shape[-1]
            dt = h2.dtype
            I = b * cfg.top_k
            y_items = jnp.zeros((I, d), dt)
            keep = jnp.zeros((I,), bool)
            for yg, kg, lg in parts:
                y_items = jnp.where(lg[:, None], yg, y_items)
                keep = jnp.where(lg, kg, keep)
            gflat = (gates.reshape(-1) * keep).astype(dt)
            y2d = (y_items * gflat[:, None]).reshape(b, cfg.top_k, -1).sum(axis=1)
            if shared_p is not None:
                y2d = y2d + ffn(shared_p, h2.reshape(b, d), "swiglu")
            return x + y2d.reshape(b, 1, d)

        def moe_verify_fn(mp, tables, lo, h):
            # per-position unroll of moe_fn: each candidate column routes and
            # dispatches exactly like one sequential decode step (same token
            # count, same baked capacity, same drop order), so expert outputs
            # are bitwise what the equivalent one-token steps would produce.
            # The ``c`` columns still arrived in ONE exchange — only compute
            # is per-position, the transfer amortisation is untouched.
            # Outputs are re-packed token-major (row, c) to match the h
            # flattening the combine-side slicing assumes.
            rows, c, d = h.shape
            k = cfg.top_k
            outs = [moe_fn(mp, tables, lo, h[:, j : j + 1]) for j in range(c)]
            y_items = jnp.stack(
                [o[0].reshape(rows, k, d) for o in outs], axis=1
            ).reshape(rows * c * k, d)
            keep = jnp.stack(
                [o[1].reshape(rows, k) for o in outs], axis=1
            ).reshape(rows * c * k)
            local = jnp.stack(
                [o[2].reshape(rows, k) for o in outs], axis=1
            ).reshape(rows * c * k)
            gates = jnp.stack([o[3] for o in outs], axis=1).reshape(rows * c, k)
            load = jnp.stack([o[4] for o in outs])
            return y_items, keep, local, gates, load

        def combine_verify_fn(x, h2, shared_p, parts, gates):
            # per-position combine_fn calls on the token-major packed parts —
            # same one-token shapes as the sequential decode combine
            b, s, d = x.shape
            k = cfg.top_k
            cols = []
            for j in range(s):
                parts_j = [
                    (
                        yg.reshape(b, s, k, d)[:, j].reshape(b * k, d),
                        kg.reshape(b, s, k)[:, j].reshape(b * k),
                        lg.reshape(b, s, k)[:, j].reshape(b * k),
                    )
                    for yg, kg, lg in parts
                ]
                gates_j = gates.reshape(b, s, k)[:, j]
                cols.append(
                    combine_fn(
                        x[:, j : j + 1], h2[:, j : j + 1], shared_p, parts_j, gates_j
                    )
                )
            return jnp.concatenate(cols, axis=1)

        self._moe_jit = jax.jit(moe_fn)
        self._combine_jit = jax.jit(combine_fn)
        self._moe_verify_jit = jax.jit(moe_verify_fn)
        self._combine_verify_jit = jax.jit(combine_verify_fn)

    # ------------------------------------------------------------------
    # cache interop (engine format: stacked [L, b, S, ...])
    # ------------------------------------------------------------------
    def scatter_prefill(self, one_caches: Dict[str, jax.Array], slot: int) -> None:
        """Write a single-request prefill cache (batch dim 1) into ``slot`` —
        the whole-prompt special case of the streamed chunk hand-off."""
        length = next(
            one_caches[name].shape[2]
            for short, name in _KV_KEYS.items()
            if short in self._kv[0][0]
        )
        self.scatter_prefill_chunk(one_caches, slot, 0, length)

    def scatter_prefill_chunk(
        self, one_caches: Dict[str, jax.Array], slot: int, start: int, length: int
    ) -> None:
        """Stream one prefill chunk's KV slab into ``slot``: only the rows
        holding prompt positions ``[start, start+length)`` cross the wire
        (prefill pool → owning attention shard), never the whole prompt
        cache.  Row mapping via :func:`repro.serving.kv_cache.chunk_rows`
        (shared with the mono engine's scatter)."""
        from repro.serving.kv_cache import chunk_rows

        shard = next(s for s in self.shards if s.lo <= slot < s.hi)
        si = self.shards.index(shard)
        dev = self.pools.attn_devices[shard.dev_index]
        local = slot - shard.lo
        self._slot_len[slot] = max(self._slot_len[slot], start + length)
        if self._pagers is not None:
            pager = self._pagers[si]
            pager.ensure(local, start + length - 1)
            pages, offs = pager.rows_of(local, start, length)
            positions = start + np.arange(length)
            for l, layer_kv in enumerate(self._kv[si]):
                for short, name in _KV_KEYS.items():
                    if short in layer_kv:
                        rows = jax.device_put(one_caches[name][l, 0, positions], dev)
                        layer_kv[short] = (
                            layer_kv[short].at[pages, offs].set(rows.astype(layer_kv[short].dtype))
                        )
            return
        for l, layer_kv in enumerate(self._kv[si]):
            for short, name in _KV_KEYS.items():
                if short in layer_kv:
                    idx = chunk_rows(one_caches[name].shape[2], start, length)
                    rows = jax.device_put(one_caches[name][l, 0, idx], dev)
                    layer_kv[short] = (
                        layer_kv[short].at[local, idx].set(rows.astype(layer_kv[short].dtype))
                    )

    def load_caches(
        self, caches: Dict[str, jax.Array], lengths: Optional[np.ndarray] = None
    ) -> None:
        """Adopt an engine-format stacked cache dict (re-shards onto the pool).
        ``lengths`` (per-slot live rows) drives paged re-pagination; defaults
        to treating every slot as fully live."""
        if lengths is not None:
            self._slot_len = np.asarray(lengths, np.int64).copy()
        elif self.kv_page_size is not None:
            self._slot_len = np.full(self.max_batch, self.cache_len, np.int64)
        self._build_attn_side(len(self.pools.attn_devices), caches=caches)

    def export_caches(self) -> Dict[str, jax.Array]:
        """Reassemble the engine-format stacked cache dict (global row order).
        Paged shards gather their pages back into dense rows (unbacked rows
        come back as zeros), so the export format is layout-independent."""
        order = sorted(range(len(self.shards)), key=lambda i: self.shards[i].lo)
        out: Dict[str, jax.Array] = {}
        n_layers = len(self._kv[0])
        host = jax.devices()[0]
        for short, name in _KV_KEYS.items():
            if short not in self._kv[0][0]:
                continue
            per_layer = []
            for l in range(n_layers):
                rows = []
                for i in order:
                    arr = jax.device_put(self._kv[i][l][short], host)
                    if self._pagers is not None:
                        pager = self._pagers[i]
                        pool = np.asarray(arr)  # [P, ps, ...]
                        dense = np.zeros(
                            (pager.max_batch, pager.cache_len, *pool.shape[2:]),
                            pool.dtype,
                        )
                        for r in range(pager.max_batch):
                            nb = pager.slot_blocks(r)
                            if nb:
                                pages = pager.tables[r, :nb]
                                dense[r, : nb * pager.page_size] = pool[pages].reshape(
                                    nb * pager.page_size, *pool.shape[2:]
                                )
                        arr = jnp.asarray(dense)
                    rows.append(arr)
                per_layer.append(jnp.concatenate(rows, axis=0))
            out[name] = jnp.stack(per_layer)
        return out

    # ------------------------------------------------------------------
    # paged slot lifecycle
    # ------------------------------------------------------------------
    def _shard_of(self, slot: int) -> int:
        return next(si for si, s in enumerate(self.shards) if s.lo <= slot < s.hi)

    def ensure_slot_pages(self, slot: int, pos: int) -> None:
        """Back ``slot``'s write position with a page (alloc on append)."""
        self._slot_len[slot] = max(self._slot_len[slot], pos + 1)
        if self._pagers is None:
            return
        si = self._shard_of(slot)
        self._pagers[si].ensure(slot - self.shards[si].lo, pos)

    def truncate_slot(self, slot: int, tokens: int) -> None:
        """Clamp ``slot``'s live length down to ``tokens`` rows (speculative
        verify backed and wrote candidate rows past the accepted prefix —
        pure bookkeeping, the decode mask never reads past the position)."""
        self._slot_len[slot] = min(int(self._slot_len[slot]), int(tokens))
        if self._pagers is None:
            return
        si = self._shard_of(slot)
        self._pagers[si].truncate(slot - self.shards[si].lo, tokens)

    def release_slot(self, slot: int) -> None:
        """Free a released slot's pages and forget its live length."""
        self._slot_len[slot] = 0
        if self._pagers is None:
            return
        si = self._shard_of(slot)
        self._pagers[si].release(slot - self.shards[si].lo)

    def shard_of(self, slot: int) -> int:
        """Which attention shard owns ``slot`` (spill/restore must re-attach
        on the same shard — page ids are pool-local)."""
        return self._shard_of(slot)

    def spill_slot(self, slot: int) -> Tuple["SpilledSlotKV", int]:
        """Detach ``slot``'s KV pages for preemption (no copy): the shard
        pager's block-table row moves into a :class:`SpilledSlotKV` record
        and the executor forgets the slot's live length.  Returns the record
        and the shard index a restore must target.

        The record is only valid while this shard's page pool lives: any
        attention re-shard (device loss, reconfigure, degrade-to-mono)
        rebuilds the pools from slot-owned pages and dissolves detached
        ones — the engine then falls back to restore-by-replay."""
        if self._pagers is None:
            raise RuntimeError("spill requires paged KV (kv_page_size)")
        si = self._shard_of(slot)
        rec = self._pagers[si].spill(slot - self.shards[si].lo)
        payload = SpilledSlotKV(shard=si, rec=rec, tokens=int(self._slot_len[slot]))
        self._slot_len[slot] = 0
        return payload, si

    def restore_slot(self, slot: int, payload: "SpilledSlotKV") -> None:
        """Re-attach a spilled record to fresh ``slot`` on its home shard."""
        si = self._shard_of(slot)
        if si != payload.shard:
            raise RuntimeError(
                f"slot {slot} lives on shard {si}, spilled KV belongs to "
                f"shard {payload.shard}"
            )
        self._pagers[si].restore(slot - self.shards[si].lo, payload.rec)
        self._slot_len[slot] = payload.tokens

    def drop_spilled(self, payload: "SpilledSlotKV") -> None:
        """Abandon a spilled record: return its page references to the pool."""
        self._pagers[payload.shard].drop_spilled(payload.rec)

    # ------------------------------------------------------------------
    # prefix cache (shard-local radix reuse)
    # ------------------------------------------------------------------
    def splice_prefix(self, slot: int, tokens: np.ndarray, limit: int):
        """Serve the longest cached prefix of ``tokens`` from ``slot``'s own
        shard: splice the shared pages into the local block table (per-layer
        copy-on-write for a trailing partial page) and gather the matched KV
        rows into worker-seed format (``kv name → [L, match, ...]``).
        Returns ``(match, seed_caches)`` — ``(0, None)`` on a miss."""
        if self._indexes is None:
            return 0, None
        si = self._shard_of(slot)
        local = slot - self.shards[si].lo
        match, pages = self._indexes[si].lookup(tokens, limit)
        if not match:
            return 0, None
        pager = self._pagers[si]
        cow = pager.splice(local, pages, match)
        if cow is not None:
            src, dst, rows = cow
            for layer_kv in self._kv[si]:
                for short in _KV_KEYS:
                    if short in layer_kv:
                        layer_kv[short] = layer_kv[short].at[dst, :rows].set(
                            layer_kv[short][src, :rows]
                        )
        # the spliced rows are live KV: a re-shard/fault rebuild must carry
        # them (export gathers through the shared pages, re-pagination gives
        # the slot exclusive copies — streams stay bit-identical)
        self._slot_len[slot] = max(int(self._slot_len[slot]), match)
        pgs, offs = pager.rows_of(local, 0, match)
        seed: Dict[str, np.ndarray] = {}
        for short, name in _KV_KEYS.items():
            if short not in self._kv[si][0]:
                continue
            seed[name] = np.stack(
                [np.asarray(layer_kv[short])[pgs, offs] for layer_kv in self._kv[si]]
            )
        return match, seed

    def publish_prefix(self, slot: int, tokens: np.ndarray, upto: int) -> None:
        """Index the chunk-aligned prefix KV ``slot`` just prefilled (pins
        the backing pages on its shard's index)."""
        if self._indexes is None:
            return
        si = self._shard_of(slot)
        self._indexes[si].publish(tokens, upto, slot - self.shards[si].lo)

    def prefix_stats(self) -> Optional[Dict[str, float]]:
        """Aggregated prefix-cache telemetry across the attention shards
        (plus counters carried over from pre-re-shard indexes)."""
        if self._indexes is None:
            return None
        c = dict(self._prefix_carry)
        shared = nodes = 0
        for ix in self._indexes:
            c["hits"] += ix.hits
            c["misses"] += ix.misses
            c["saved_tokens"] += ix.saved_tokens
            c["lookup_tokens"] += ix.lookup_tokens
            c["evicted_pages"] += ix.evicted_pages
            shared += ix.held_pages
            nodes += len(ix._nodes)
        total = c["hits"] + c["misses"]
        return {
            "hits": c["hits"],
            "misses": c["misses"],
            "hit_rate": c["hits"] / total if total else 0.0,
            "saved_tokens": c["saved_tokens"],
            "saved_frac": (
                c["saved_tokens"] / c["lookup_tokens"] if c["lookup_tokens"] else 0.0
            ),
            "shared_pages": shared,
            "evicted_pages": c["evicted_pages"],
            "nodes": nodes,
        }

    def _sync_tables(self) -> None:
        """Push dirty block tables into every layer's kv dict before decode."""
        if self._pagers is None:
            return
        for si, pager in enumerate(self._pagers):
            if pager.dirty:
                dev = self.pools.attn_devices[self.shards[si].dev_index]
                bt = pager.table_device(dev)
                for layer_kv in self._kv[si]:
                    layer_kv["bt"] = bt

    def slot_lengths(self) -> np.ndarray:
        """Per-slot live KV lengths (rows written), global row order."""
        return self._slot_len.copy()

    def page_stats(self) -> Optional[Dict[str, float]]:
        """Aggregated page telemetry across the attention shards."""
        if self._pagers is None:
            return None
        num_pages = sum(p.num_pages for p in self._pagers)
        in_use = sum(p.allocator.in_use for p in self._pagers)
        peak = sum(p.allocator.peak_in_use for p in self._pagers)
        free = sum(p.allocator.num_free for p in self._pagers)
        used_rows = sum(int(p.hiwater.sum()) for p in self._pagers)
        alloc_rows = in_use * self.kv_page_size
        allocatable = sum(p.num_pages - 1 for p in self._pagers)
        return {
            "page_size": self.kv_page_size,
            "num_pages": num_pages,
            "pages_in_use": in_use,
            "pages_peak": peak,
            "pages_free": free,
            "occupancy": in_use / max(1, allocatable),
            "fragmentation": 1.0 - used_rows / alloc_rows if alloc_rows else 0.0,
        }

    # ------------------------------------------------------------------
    # reconfigure (§3.5): re-lower only the affected pool
    # ------------------------------------------------------------------
    def reconfigure(
        self,
        n_attn: Optional[int] = None,
        n_moe: Optional[int] = None,
        layout: Optional[ReplicaLayout] = None,
        n_prefill: Optional[int] = None,
    ) -> Dict[str, bool]:
        cur_a = len(self.pools.attn_devices)
        cur_e = len(self.pools.moe_devices)
        cur_p = len(self.pools.prefill_devices)
        n_attn = cur_a if n_attn is None else int(n_attn)
        n_moe = cur_e if n_moe is None else int(n_moe)
        n_prefill = cur_p if n_prefill is None else int(n_prefill)
        # validate before any state mutates: a bad size must surface as a
        # clear ValueError naming the pool, not an opaque downstream JAX error
        if n_attn < 1:
            raise ValueError(
                f"attention pool size must be ≥ 1, got n_attn={n_attn} "
                "(the engine cannot decode without an attention pool)"
            )
        if n_moe < 1:
            raise ValueError(
                f"MoE pool size must be ≥ 1, got n_moe={n_moe} "
                "(expert layers need at least one MoE device)"
            )
        if n_prefill < 0:
            raise ValueError(f"prefill pool size must be ≥ 0, got n_prefill={n_prefill}")
        avail = len(self._all_devices if self._all_devices is not None else jax.devices())
        if not self._aliased and n_attn + n_moe + n_prefill > avail:
            raise ValueError(
                f"pool sizes {n_attn} (attn) + {n_moe} (moe) + {n_prefill} "
                f"(prefill) = {n_attn + n_moe + n_prefill} exceed the {avail} "
                "available devices"
            )
        relower = {
            "attn": n_attn != cur_a,
            "moe": n_moe != cur_e or layout is not None,
            # a MoE resize re-anchors the (tail-anchored) prefill pool too
            "prefill": n_prefill != cur_p or (n_prefill > 0 and n_moe != cur_e),
        }
        if not (relower["attn"] or relower["moe"] or relower["prefill"]):
            self.relower_log.append(relower)
            return relower

        caches = self.export_caches() if relower["attn"] else None
        devs = self._all_devices
        allow_reuse = len(devs or jax.devices()) < n_attn + n_moe + n_prefill
        self.pools = DevicePools.split(
            n_attn, n_moe, devs, node_size=self.pools.node_size,
            allow_reuse=allow_reuse, n_prefill=n_prefill,
        )
        new_layout = layout or (
            self.layout
            if n_moe == cur_e
            else ReplicaLayout.round_robin(self.cfg.num_experts, n_moe, self.C)
        )
        if relower["moe"]:
            self._build_moe_side(new_layout)
            self._build_moe_jits()  # layout constants changed → re-lower MoE stages
        if relower["attn"]:
            # in-flight KV caches are preserved: re-shard the exported rows;
            # attention jits re-trace for the new shard shapes on first use
            self._build_attn_side(n_attn, caches=caches)
        else:
            # MoE-only change still needs fresh exchange plans (pool changed)
            self._plans = {r: plan_exchange(self.pools, r) for r in ("case1", "case2")}
        self.disagg_cfg = disagg_reconfigure(
            self.disagg_cfg, n_attn, n_moe, new_layout, n_prefill=n_prefill
        )
        self.relower_log.append(relower)
        return relower

    # ------------------------------------------------------------------
    # fault recovery: device loss
    # ------------------------------------------------------------------
    def exclude_device(self, pool: str, index: int) -> None:
        """Remove a dead device from the executor's universe so the next
        ``reconfigure`` re-splits onto survivors only.  With aliased
        (device-reusing) single-host test pools the exclusion is skipped —
        the loss is logical and recovery proceeds on the shared device."""
        devs = {
            "attn": self.pools.attn_devices,
            "moe": self.pools.moe_devices,
            "prefill": self.pools.prefill_devices,
        }[pool][index]
        universe = list(
            self._all_devices if self._all_devices is not None else jax.devices()
        )
        hits = [i for i, d in enumerate(universe) if d is devs]
        if self._aliased or len(hits) != 1:
            return
        universe.pop(hits[0])
        self._all_devices = universe

    def drop_attn_device(self, dead: int) -> List[int]:
        """Attention device ``dead`` died: destroy its batch-shard KV rows
        (a real failure loses that memory — recovery must *rebuild*, not
        read), shrink the pool to the survivors, and return the lost global
        batch rows so the engine can re-prefill their requests.  Needs ≥ 2
        attention devices — with one, there is nothing to shrink to and the
        engine degrades to the mono path instead."""
        n_attn = len(self.pools.attn_devices)
        if not 0 <= dead < n_attn:
            raise ValueError(f"no attention device {dead} (pool has {n_attn})")
        if n_attn < 2:
            raise ValueError("cannot drop the last attention device — degrade instead")
        lost: List[int] = []
        for si, s in enumerate(self.shards):
            if s.dev_index != dead:
                continue
            lost.extend(range(s.lo, s.hi))
            self._kv[si] = [
                {k: jnp.zeros_like(v) for k, v in layer.items()}
                for layer in self._kv[si]
            ]
            if self._pagers is not None:
                for r in range(s.rows):
                    self._pagers[si].release(r)
        if lost:
            # the dead shard's pages (and their block tables) died with it —
            # survivors re-paginate from zero length and replay rebuilds them
            self._slot_len[np.asarray(lost)] = 0
        self.exclude_device("attn", dead)
        self.reconfigure(n_attn=n_attn - 1)
        return sorted(lost)

    # ------------------------------------------------------------------
    # the exchange: realised two-phase transfer
    # ------------------------------------------------------------------
    def _dev_of(self, addr: Tuple[str, int]) -> jax.Device:
        pool, idx = addr
        return (self.pools.attn_devices if pool == "attn" else self.pools.moe_devices)[idx]

    def _run_exchange(self, h2s: Dict[int, jax.Array], regime: str, tel: Dict) -> List[jax.Array]:
        """Land the concatenation of all shards' ``h2`` on every MoE device
        following the per-regime ``device_put`` schedule.  ``h2s`` maps
        attention-device index → this micro-batch's activation slice."""
        chunks, steps = self._plans[regime]
        have: Dict[Tuple[int, Tuple[str, int]], jax.Array] = {}
        node_payload: Dict[Tuple[int, ...], jax.Array] = {}
        for cid, ch in enumerate(chunks):
            leader = ("attn", ch.members[0])
            if ch.members not in node_payload:
                parts = [jax.device_put(h2s[i], self._dev_of(leader)) for i in ch.members]
                node_payload[ch.members] = (
                    parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=0)
                )
            payload = node_payload[ch.members]
            if ch.n_subs > 1:  # case-2 pair split: ≈ total/pairs rows per chunk
                payload = jnp.array_split(payload, ch.n_subs, axis=0)[ch.sub]
            have[(cid, leader)] = payload
        for st in steps:
            if st.phase == 1:
                tel["bytes_fast"] += h2s[st.src[1]].nbytes
                tel["msgs_fast"] += 1
                continue
            arr = have[(st.chunk, st.src)]
            have[(st.chunk, st.dst)] = jax.device_put(arr, self._dev_of(st.dst))
            tel[f"bytes_{st.fabric}"] += arr.nbytes
            tel[f"msgs_{st.fabric}"] += 1
        outs = []
        for g in range(len(self.pools.moe_devices)):
            got = [have[(cid, ("moe", g))] for cid in range(len(chunks))]
            outs.append(got[0] if len(got) == 1 else jnp.concatenate(got, axis=0))
        return outs

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def decode_step(
        self, tokens, positions, collect_stage_times: bool = False
    ) -> Tuple[jax.Array, Dict]:
        """One batched decode step.  Returns (logits [b, vocab], telemetry)."""
        return self._decode_impl(tokens, positions, None, collect_stage_times)

    def decode_step_verify(
        self, tokens, positions, widths, collect_stage_times: bool = False
    ) -> Tuple[jax.Array, Dict]:
        """One batched speculative-verify step: ``tokens`` is [b, c] (last
        accepted token + drafts), ``widths`` the per-slot valid row counts.
        Returns (logits [b, c, vocab], telemetry).  Each per-layer exchange
        ships c rows per slot instead of one, so the transfer-bytes telemetry
        directly shows the amortisation speculation buys."""
        return self._decode_impl(tokens, positions, widths, collect_stage_times)

    def _decode_impl(
        self, tokens, positions, widths, collect_stage_times: bool = False
    ) -> Tuple[jax.Array, Dict]:
        verify = widths is not None
        self._sync_tables()
        cfg = self.cfg
        pools = self.pools
        dtype_bytes = jnp.dtype(jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32).itemsize
        c = CommConfig(
            n_attn=len(pools.attn_devices),
            n_moe=self.n_moe,
            bytes_per_token=cfg.d_model * dtype_bytes,
            batch=self.max_batch,
            hw=dataclasses.replace(self.hw, devices_per_node=max(1, pools.node_size)),
        )
        t_pred, regime = adaptive_two_phase(c)
        tel: Dict = {
            "regime": regime,
            "t_comm_pred": t_pred,
            "bytes_slow": 0,
            "bytes_fast": 0,
            "msgs_slow": 0,
            "msgs_fast": 0,
        }
        times: Dict[str, float] = {"attn": 0.0, "exchange": 0.0, "moe": 0.0, "combine": 0.0}

        def _tick(key, arrs, t0):
            if collect_stage_times:
                jax.block_until_ready(arrs)
                times[key] += time.perf_counter() - t0
            return time.perf_counter()

        # shard inputs + embed (attention pool)
        xs: List[jax.Array] = []
        poss: List[jax.Array] = []
        wids: List[Optional[jax.Array]] = []
        for si, s in enumerate(self.shards):
            dev = pools.attn_devices[s.dev_index]
            tok = jax.device_put(jnp.asarray(tokens)[s.lo : s.hi], dev)
            pos = jax.device_put(jnp.asarray(positions)[s.lo : s.hi], dev)
            poss.append(pos)
            wids.append(
                jax.device_put(jnp.asarray(widths)[s.lo : s.hi], dev)
                if verify
                else None
            )
            xs.append(self._embed_jit(self._attn_params[s.dev_index]["embed"], tok))

        mbs = [
            [si for si, s in enumerate(self.shards) if s.mb == m]
            for m in range(self.n_micro)
        ]
        # per-micro-batch item offsets (token order = shard order within the mb)
        offs = []
        for group in mbs:
            o, acc = {}, 0
            for si in group:
                o[si] = acc
                acc += self.shards[si].rows
            offs.append((o, acc))

        amax_parts: List[jax.Array] = []
        for li, (per, pos_idx, kind, cidx) in enumerate(self._layers):
            h2s_all: List[Optional[jax.Array]] = [None] * len(self.shards)

            def attn_mb(group, li=li, cidx=cidx):
                t0 = time.perf_counter()
                for si in group:
                    s = self.shards[si]
                    lp = self._attn_params[s.dev_index]["layers"][li]
                    if verify:
                        x, h2, new_kv = self._attn_verify_jit(
                            lp, xs[si], self._kv[si][cidx], poss[si], wids[si]
                        )
                    else:
                        x, h2, new_kv = self._attn_jit(
                            lp, xs[si], self._kv[si][cidx], poss[si]
                        )
                    xs[si], h2s_all[si] = x, h2
                    self._kv[si][cidx] = new_kv
                _tick("attn", [xs[si] for si in group], t0)

            if kind == "dense":
                for group in mbs:
                    attn_mb(group)
                    for si in group:
                        lp = self._attn_params[self.shards[si].dev_index]["layers"][li]
                        xs[si] = self._dense_jit(lp, xs[si], h2s_all[si])
                continue

            # MoE layer: per micro-batch attention → exchange → expert → combine,
            # dispatched in ping-pong order: micro-batch m's expert stage is in
            # flight (MoE pool) while m+1's attention runs (attention pool), and
            # m's combine (attention pool) overlaps m+1's expert stage (§6 /
            # MegaScale micro-batch pipelining).
            pending: List[Tuple[int, List[int], List]] = []
            moe_jit = self._moe_verify_jit if verify else self._moe_jit
            for m, group in enumerate(mbs):
                attn_mb(group)
                t0 = time.perf_counter()
                h2s = {self.shards[si].dev_index: h2s_all[si] for si in group}
                if self.fault_hook is not None:
                    self.fault_hook("exchange", li, m)
                h_on_moe = self._run_exchange(h2s, regime, tel)
                t0 = _tick("exchange", h_on_moe, t0)
                res = [
                    moe_jit(
                        self._moe_params[g]["layers"][li],
                        self._moe_params[g]["tables"],
                        self._moe_params[g]["lo"],
                        h_on_moe[g],
                    )
                    for g in range(self.n_moe)
                ]
                _tick("moe", [r[0] for r in res], t0)
                if pending:
                    self._combine_mb(
                        *pending.pop(0), xs, h2s_all, offs, li, tel, times,
                        collect_stage_times, amax_parts, verify,
                    )
                pending.append((m, group, res))
            while pending:
                self._combine_mb(
                    *pending.pop(0), xs, h2s_all, offs, li, tel, times,
                    collect_stage_times, amax_parts, verify,
                )

        t0 = time.perf_counter()
        head_jit = self._head_verify_jit if verify else self._head_jit
        logit_shards = {}
        for si, s in enumerate(self.shards):
            p = self._attn_params[s.dev_index]
            logit_shards[s.lo] = head_jit(
                {"final_norm": p["final_norm"], "embed": p["embed"]}, xs[si]
            )
        logits = jnp.concatenate(
            [
                jax.device_put(logit_shards[lo], jax.devices()[0])
                for lo in sorted(logit_shards)
            ],
            axis=0,
        )
        if collect_stage_times:
            logits.block_until_ready()
            times["head"] = time.perf_counter() - t0
            tel["stage_times"] = times
        tel["a_max"] = int(np.max([np.asarray(a) for a in amax_parts])) if amax_parts else 0
        tel["bytes_total"] = tel["bytes_slow"] + tel["bytes_fast"]
        return logits, tel

    def _combine_mb(
        self, m, group, res, xs, h2s_all, offs, li, tel, times, collect,
        amax_parts, verify=False,
    ) -> None:
        """Ship expert partials back to the owning attention shards and run
        the gate-combine there (mono-identical op order).  In verify mode a
        shard's rows carry ``c`` candidate tokens each, so item/gate slices
        scale by the per-row token width."""
        t0 = time.perf_counter()
        k = self.cfg.top_k
        # per-row token width: 1 in decode, c (candidate rows) in verify
        w = xs[group[0]].shape[1] if verify else 1
        off, _total = offs[m]
        amax_parts.append(jnp.max(res[0][4]))  # load from instance 0 (redundant copies agree)
        for si in group:
            s = self.shards[si]
            dev = self.pools.attn_devices[s.dev_index]
            r0, r1 = off[si] * w, (off[si] + s.rows) * w
            parts = []
            for y_items, keep, local, _gates, _load in res:
                part = (
                    jax.device_put(y_items[r0 * k : r1 * k], dev),
                    jax.device_put(keep[r0 * k : r1 * k], dev),
                    jax.device_put(local[r0 * k : r1 * k], dev),
                )
                tel["bytes_slow"] += sum(a.nbytes for a in part)
                tel["msgs_slow"] += 1
                parts.append(part)
            gates = jax.device_put(res[0][3][r0:r1], dev)
            tel["bytes_slow"] += gates.nbytes
            tel["msgs_slow"] += 1
            shared = self._attn_params[s.dev_index]["shared"][li]
            combine = self._combine_verify_jit if verify else self._combine_jit
            xs[si] = combine(xs[si], h2s_all[si], shared, parts, gates)
        if collect:
            jax.block_until_ready([xs[si] for si in group])
            times["combine"] += time.perf_counter() - t0
