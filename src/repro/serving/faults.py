"""Fault injection, health monitoring and recovery for disaggregated serving.

Independently managed sub-clusters mean independent failure domains: at
fleet scale a lost device, a hung cross-pool exchange, or a failed prefill
chunk is a steady-state event, not an exception.  This module gives the
engine a *typed* fault model instead of an opaque JAX traceback:

* :class:`FaultSpec` / :class:`FaultPlan` — an injectable, seeded,
  step-scheduled description of what fails and when (device loss in any of
  the three pools, exchange timeout/delay, prefill-chunk failure; transient
  faults heal after ``fail_count`` hits, permanent ones do not).  Plans are
  JSON round-trippable (``launch/serve.py --fault-plan``) and
  :meth:`FaultPlan.random` draws reproducible plans from a seed.
* :class:`PoolFault` — the typed signal every detection path raises, naming
  the pool, device index and fault kind, so the engine can route recovery
  instead of dying.
* :class:`Watchdog` — per-site deadlines: an exchange whose (injected)
  latency exceeds the deadline is *cancelled* and surfaced as a transient
  timeout after charging the deadline, never a hang.
* :class:`RetryPolicy` — exponential backoff with a bounded retry budget;
  pure functions of the attempt number so tests drive them with a fake
  clock.
* :class:`FaultRuntime` — the engine-side state machine: fires scheduled
  injections as the decode step counter passes them, answers health polls
  (heartbeat: any armed device loss in a pool the engine is about to use
  becomes a :class:`PoolFault` *before* the step runs), serves as the
  ``fault_hook`` for the :class:`~repro.serving.disagg.DisaggExecutor`
  exchange path and the :class:`~repro.serving.prefill.PrefillWorker`
  chunk loop, and accumulates :class:`FaultStats` for ``metrics()``.

The fault-free hot path is untouched: executors and workers carry a
``fault_hook`` that is ``None`` unless a plan is armed, and the engine only
consults the runtime when one exists.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

DEVICE_LOSS = "device_loss"
EXCHANGE_TIMEOUT = "exchange_timeout"
EXCHANGE_DELAY = "exchange_delay"
PREFILL_CHUNK_FAIL = "prefill_chunk_fail"

FAULT_KINDS = (DEVICE_LOSS, EXCHANGE_TIMEOUT, EXCHANGE_DELAY, PREFILL_CHUNK_FAIL)
POOLS = ("attn", "moe", "prefill")


class PoolFault(Exception):
    """A detected fault, typed by pool / device / kind.

    Raised by health polls and fault hooks instead of letting a dead device
    surface as a hang or an opaque backend error.  ``transient`` faults are
    retried under the engine's :class:`RetryPolicy`; permanent ones route to
    pool-specific recovery (re-plan / re-prefill / requeue / degrade).
    """

    def __init__(self, pool: str, index: int, kind: str, transient: bool,
                 detail: str = ""):
        self.pool = pool
        self.index = index
        self.kind = kind
        self.transient = transient
        self.detail = detail
        flavor = "transient" if transient else "permanent"
        super().__init__(
            f"{flavor} {kind} in {pool} pool (device {index})"
            + (f": {detail}" if detail else "")
        )


@dataclasses.dataclass
class FaultSpec:
    """One scheduled fault.

    ``at_step`` is the engine's global decode-step ordinal for decode-side
    faults, and the worker's global chunk ordinal for
    ``prefill_chunk_fail`` — both deterministic counters, so a plan replays
    identically across runs.  ``fail_count`` is how many consecutive
    attempts a *transient* fault poisons before healing; permanent faults
    ignore it.
    """

    kind: str
    pool: str = "attn"
    index: int = 0  # device index within the pool
    at_step: int = 0
    transient: bool = False
    fail_count: int = 1
    delay_s: float = 0.0  # EXCHANGE_DELAY magnitude (seconds)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind: {self.kind!r} (one of {FAULT_KINDS})")
        if self.kind == DEVICE_LOSS and self.pool not in POOLS:
            raise ValueError(f"unknown pool: {self.pool!r} (one of {POOLS})")
        if self.kind == DEVICE_LOSS and self.transient:
            raise ValueError("device_loss is permanent by definition")
        if self.kind in (EXCHANGE_TIMEOUT, EXCHANGE_DELAY, PREFILL_CHUNK_FAIL):
            # non-loss faults are transient unless explicitly escalated
            pass


@dataclasses.dataclass
class FaultPlan:
    """A reproducible schedule of faults (seeded + step-scheduled)."""

    faults: List[FaultSpec] = dataclasses.field(default_factory=list)
    seed: int = 0

    # -- construction --------------------------------------------------------
    @staticmethod
    def random(
        seed: int,
        n_faults: int = 3,
        max_step: int = 50,
        kinds: Sequence[str] = FAULT_KINDS,
        pools: Sequence[str] = POOLS,
        pool_sizes: Optional[Dict[str, int]] = None,
    ) -> "FaultPlan":
        """Draw a reproducible plan: same seed → same schedule, always."""
        rng = np.random.default_rng(seed)
        sizes = pool_sizes or {p: 1 for p in pools}
        faults = []
        for _ in range(n_faults):
            kind = str(rng.choice(list(kinds)))
            pool = str(rng.choice(list(pools))) if kind == DEVICE_LOSS else "attn"
            faults.append(
                FaultSpec(
                    kind=kind,
                    pool=pool,
                    index=int(rng.integers(0, max(1, sizes.get(pool, 1)))),
                    at_step=int(rng.integers(1, max_step)),
                    transient=kind != DEVICE_LOSS,
                    fail_count=int(rng.integers(1, 3)),
                    delay_s=float(rng.uniform(0.001, 0.05)) if kind == EXCHANGE_DELAY else 0.0,
                )
            )
        faults.sort(key=lambda f: f.at_step)
        return FaultPlan(faults, seed=seed)

    # -- (de)serialisation ---------------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {"seed": self.seed, "faults": [dataclasses.asdict(f) for f in self.faults]},
            indent=2,
        )

    @staticmethod
    def from_json(text: str) -> "FaultPlan":
        obj = json.loads(text)
        if isinstance(obj, list):  # bare fault list is accepted too
            obj = {"faults": obj}
        return FaultPlan(
            faults=[FaultSpec(**f) for f in obj.get("faults", [])],
            seed=int(obj.get("seed", 0)),
        )


@dataclasses.dataclass
class RetryPolicy:
    """Bounded exponential backoff for transient faults.

    Pure: ``delay(attempt)`` is a function of the attempt number only, so a
    fake clock can assert the exact charged backoff.  ``recovery_charge_s``
    is the modeled wall cost of one permanent-fault recovery (charged to the
    engine clock when the engine runs a modeled ``step_time_fn`` — real
    wall time is charged otherwise).
    """

    base_delay_s: float = 0.05
    factor: float = 2.0
    max_retries: int = 3
    recovery_charge_s: float = 0.0

    def delay(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based)."""
        return self.base_delay_s * self.factor ** max(0, attempt - 1)


@dataclasses.dataclass
class Watchdog:
    """Per-site deadlines: turn would-be hangs into typed timeouts.

    ``exchange_deadline_s`` bounds one cross-pool exchange;
    ``prefill_deadline_s`` bounds one prefill chunk.  An injected delay at
    or beyond the deadline is detected (the transfer is cancelled after
    ``deadline`` seconds and surfaced as a transient ``exchange_timeout``);
    a delay below it is charged as latency but is not a fault.
    """

    exchange_deadline_s: float = 1.0
    prefill_deadline_s: float = 5.0


@dataclasses.dataclass
class FaultStats:
    """Counters surfaced through ``ServingEngine.metrics()['faults']``."""

    injected: int = 0
    detected: int = 0
    retries: int = 0
    recoveries: int = 0
    requeued: int = 0  # requests re-driven through the prefill queue
    replayed_slots: int = 0  # KV slots rebuilt by deterministic replay
    degraded: int = 0  # disagg → mono last-resort transitions
    fault_stall_s: float = 0.0  # clock charged to backoff + recovery
    recovery_latency_s: List[float] = dataclasses.field(default_factory=list)

    def as_dict(self) -> Dict:
        lat = self.recovery_latency_s
        return {
            "injected": self.injected,
            "detected": self.detected,
            "retries": self.retries,
            "recoveries": self.recoveries,
            "requeued": self.requeued,
            "replayed_slots": self.replayed_slots,
            "degraded": self.degraded,
            "fault_stall_s": self.fault_stall_s,
            "recovery_latency_mean_s": float(np.mean(lat)) if lat else 0.0,
            "recovery_latency_max_s": float(np.max(lat)) if lat else 0.0,
        }


@dataclasses.dataclass
class _Armed:
    """Runtime state of one scheduled fault."""

    spec: FaultSpec
    fired: bool = False  # injection happened (step counter passed at_step)
    handled: bool = False  # recovery / healing completed
    hits: int = 0  # transient: failures delivered so far


class FaultRuntime:
    """Engine-side fault state: injection schedule, health polls, hooks.

    The engine owns one runtime per armed :class:`FaultPlan`.  Decode-side
    faults key off the engine's global step counter (``advance_to_step``);
    prefill-chunk faults key off the worker's global chunk counter (the
    hook receives it).  Detection is split by mechanism:

    * **heartbeat** (``poll_health``): armed device losses surface *before*
      the engine uses the pool — a dead device never silently serves;
    * **exchange hook** (``exchange_hook``): transient timeout/delay faults
      fire inside the executor's exchange path, bounded by the
      :class:`Watchdog` deadline;
    * **prefill hook** (``prefill_hook``): chunk failures fire inside the
      worker's chunk loop before any compute, so a retry is trivially safe.
    """

    def __init__(
        self,
        plan: FaultPlan,
        policy: Optional[RetryPolicy] = None,
        watchdog: Optional[Watchdog] = None,
    ):
        self.plan = plan
        self.policy = policy or RetryPolicy()
        self.watchdog = watchdog or Watchdog()
        self.stats = FaultStats()
        self._armed = [_Armed(spec=f) for f in plan.faults]
        self._pending_delay = 0.0
        self._step = -1

    # -- injection schedule --------------------------------------------------
    def advance_to_step(self, step: int) -> None:
        """Fire every decode-side fault whose ``at_step`` the counter passed."""
        self._step = step
        for a in self._armed:
            if a.fired or a.spec.kind == PREFILL_CHUNK_FAIL:
                continue
            if a.spec.at_step <= step:
                a.fired = True
                self.stats.injected += 1

    # -- heartbeat: device-loss detection ------------------------------------
    def poll_health(self, pool_sizes: Dict[str, int]) -> Optional[PoolFault]:
        """Return the next unhandled device loss touching a live pool.

        ``pool_sizes`` maps pool name → current device count; a loss whose
        index fell outside the (already shrunk) pool is marked handled
        rather than re-detected.
        """
        for a in self._armed:
            if not a.fired or a.handled or a.spec.kind != DEVICE_LOSS:
                continue
            n = pool_sizes.get(a.spec.pool, 0)
            if a.spec.index >= n:
                a.handled = True  # pool already shrank past this device
                continue
            self.stats.detected += 1
            return PoolFault(a.spec.pool, a.spec.index, DEVICE_LOSS, transient=False)
        return None

    def mark_handled(self, fault: PoolFault) -> None:
        for a in self._armed:
            if (
                a.fired
                and not a.handled
                and a.spec.kind == fault.kind
                and (fault.kind != DEVICE_LOSS or
                     (a.spec.pool == fault.pool and a.spec.index == fault.index))
            ):
                a.handled = True
                return

    # -- exchange path hook (installed as DisaggExecutor.fault_hook) ---------
    def exchange_hook(self, site: str, layer: int, micro_batch: int) -> None:
        """Called by the executor before each cross-pool exchange."""
        for a in self._armed:
            if not a.fired or a.handled:
                continue
            if a.spec.kind == EXCHANGE_TIMEOUT:
                a.hits += 1
                self.stats.detected += 1
                if a.hits >= a.spec.fail_count and a.spec.transient:
                    a.handled = True  # heals after this delivery
                raise PoolFault(
                    "moe", a.spec.index, EXCHANGE_TIMEOUT,
                    transient=a.spec.transient,
                    detail=f"exchange deadline ({self.watchdog.exchange_deadline_s}s) "
                           f"exceeded at layer {layer}",
                )
            if a.spec.kind == EXCHANGE_DELAY:
                a.hits += 1
                if a.hits >= a.spec.fail_count:
                    a.handled = True
                if a.spec.delay_s >= self.watchdog.exchange_deadline_s:
                    # the watchdog cancels the transfer at the deadline and
                    # surfaces a timeout — the engine charges the deadline,
                    # not the full (unbounded) delay
                    self._pending_delay += self.watchdog.exchange_deadline_s
                    self.stats.detected += 1
                    raise PoolFault(
                        "moe", a.spec.index, EXCHANGE_TIMEOUT,
                        transient=True,
                        detail=f"injected delay {a.spec.delay_s}s ≥ deadline",
                    )
                self._pending_delay += a.spec.delay_s  # slow, but no fault

    # -- prefill chunk hook (installed as PrefillWorker.fault_hook) ----------
    def prefill_hook(self, slot: int, dev_index: int, chunk_ordinal: int) -> None:
        """Called by the worker before each chunk's compute."""
        for a in self._armed:
            if a.handled or a.spec.kind != PREFILL_CHUNK_FAIL:
                continue
            if not a.fired:
                if chunk_ordinal >= a.spec.at_step:
                    a.fired = True
                    self.stats.injected += 1
                else:
                    continue
            a.hits += 1
            self.stats.detected += 1
            if a.hits >= a.spec.fail_count and a.spec.transient:
                a.handled = True
            raise PoolFault(
                "prefill", dev_index, PREFILL_CHUNK_FAIL,
                transient=a.spec.transient,
                detail=f"chunk {chunk_ordinal} (slot {slot})",
            )

    # -- injected latency ----------------------------------------------------
    def consume_delay(self) -> float:
        """Drain delay accumulated by under-deadline EXCHANGE_DELAY faults."""
        d, self._pending_delay = self._pending_delay, 0.0
        return d

    @property
    def has_pending(self) -> bool:
        return any(not a.handled for a in self._armed)
