"""Continuous-batching decode engine with the Janus scheduled-MoE path.

The engine serves a stream of requests against one model deployment:

  * admission: waiting requests are prefetched into free batch slots
    (per-request prefill, scattered into the batched caches);
  * decode: one batched decode per iteration with *per-slot* positions
    (continuous batching — slots join/leave independently), through one of
    two executors sharing identical semantics and telemetry:

      - ``executor="mono"``: the jitted monolithic ``decode_step`` on the
        default device (single-instance baseline);
      - ``executor="disagg"``: the two-pool
        :class:`repro.serving.disagg.DisaggExecutor` — attention stages on
        ``n_attn`` pool devices, expert stages on the MoE pool, with the
        adaptive two-phase exchange realised per layer and per-step
        regime / transfer-byte / ``a_max`` telemetry recorded;

  * MoE architectures route through the scheduled slot path: routing →
    AEBS (or a baseline scheduler) → replica-slot dispatch, with per-layer
    ``a_max`` telemetry surfaced to the controller.  Dispatch defaults to
    the sort-based grouped path (``repro.models.moe.grouped_dispatch_ffn``)
    — no per-step ``[S_total, d, f]`` weight materialisation;
  * timing: wall-clock by default, or a pluggable ``step_time_fn`` driven by
    the analytic performance model (used in tests and the simulator);
  * scaling: :meth:`ServingEngine.reconfigure` actuates a controller
    decision mid-run (§3.5) — pool counts move independently, in-flight KV
    caches are preserved.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.core import baselines
from repro.core.disagg import DevicePools
from repro.kernels.aebs.ops import aebs_schedule
from repro.models import model as model_mod
from repro.models import transformer
from repro.serving.kv_cache import SlotManager, scatter_prefill_caches
from repro.serving.request import Request

SCHEDULERS = {
    "aebs": aebs_assign,
    "aebs_kernel": aebs_schedule,  # Pallas TPU kernel, same Algorithm-1 contract
    "random": baselines.random_assign,
    "token_hash": baselines.token_hash_assign,
    "none": None,
}


class ServingEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        max_batch: int = 8,
        cache_len: int = 512,
        layout: Optional[ReplicaLayout] = None,
        scheduler: str = "aebs",
        capacity_tokens: Optional[int] = None,
        dispatch: str = "grouped",  # grouped = slot-indirect hot path (no weight copy)
        step_time_fn: Optional[Callable[[int], float]] = None,
        extra_builder: Optional[Callable[[int], Dict]] = None,
        executor: str = "mono",  # mono | disagg
        n_attn: int = 1,
        pools: Optional[DevicePools] = None,
        node_size: int = 1,
        ping_pong: bool = False,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = SlotManager(max_batch, cache_len)
        self.cache_len = cache_len
        self.max_batch = max_batch
        self.layout = layout
        self.scheduler_name = scheduler
        self.step_time_fn = step_time_fn
        self.extra_builder = extra_builder
        self.executor_name = executor
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.clock = 0.0
        self.amax_log: List[int] = []
        self.regime_log: List[str] = []
        self.transfer_bytes_log: List[int] = []
        self.completed: List[Request] = []

        moe_ctx = None
        if cfg.has_moe and layout is not None and scheduler != "none":
            moe_ctx = dict(
                dispatch=dispatch,
                layout_tables=layout.device_tables(),
                slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
                num_instances=layout.num_instances,
                scheduler=SCHEDULERS[scheduler],
                capacity=capacity_tokens,
            )
        self._moe_ctx = moe_ctx

        self.disagg: Optional["DisaggExecutor"] = None
        if executor == "disagg":
            from repro.serving.disagg import DisaggExecutor

            if layout is None or scheduler == "none":
                raise ValueError("executor='disagg' needs a replica layout and scheduler")
            if pools is None:
                pools = DevicePools.split(
                    n_attn, layout.num_instances, node_size=node_size,
                    allow_reuse=len(jax.devices()) < n_attn + layout.num_instances,
                )
            self.disagg = DisaggExecutor(
                cfg, params, pools, layout,
                max_batch=max_batch, cache_len=cache_len,
                scheduler=SCHEDULERS[scheduler], capacity=capacity_tokens,
                ping_pong=ping_pong,
            )
            self.caches = None  # cache residency moves to the executor's pool
        elif executor == "mono":
            self.caches = model_mod.init_decode_caches(cfg, max_batch, cache_len)
        else:
            raise ValueError(f"unknown executor: {executor}")

        def _decode(params, tokens, caches, positions):
            extra = {"moe_ctx": moe_ctx} if moe_ctx else None
            return model_mod.decode_step(params, tokens, caches, positions, cfg, extra=extra)

        self._decode_jit = jax.jit(_decode)

        def _prefill(params, tokens, extra):
            return model_mod.prefill(params, tokens, cfg, cache_len, extra=extra)

        self._prefill_jit = jax.jit(_prefill)

    # ------------------------------------------------------------------
    def _prefill_request(self, req: Request) -> None:
        slot = self.slots.admit(req)
        prompt = req.prompt
        if prompt is None:
            rng = np.random.default_rng(req.rid)
            prompt = rng.integers(0, self.cfg.vocab_size, size=req.input_len, dtype=np.int32)
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        extra = self.extra_builder(1) if self.extra_builder else None
        t0 = time.perf_counter()
        logits, one_caches = self._prefill_jit(self.params, toks, extra)
        logits.block_until_ready()
        dt = time.perf_counter() - t0
        if self.disagg is not None:
            self.disagg.scatter_prefill(one_caches, slot)
        else:
            self.caches = scatter_prefill_caches(self.caches, one_caches, slot)
        first = int(np.argmax(np.asarray(logits[0])))
        self.tokens = self.tokens.at[slot, 0].set(first)
        self.clock += dt if self.step_time_fn is None else 0.0
        req.prefill_done = self.clock
        req.token_times.append(self.clock)

    # ------------------------------------------------------------------
    def _decode_iteration(self) -> None:
        positions = self.slots.positions_device()
        t0 = time.perf_counter()
        if self.disagg is not None:
            logits, tel = self.disagg.decode_step(self.tokens, positions)
            logits.block_until_ready()
            self.regime_log.append(tel["regime"])
            self.transfer_bytes_log.append(tel["bytes_total"])
            self.amax_log.append(tel["a_max"])
        else:
            logits, self.caches = self._decode_jit(self.params, self.tokens, self.caches, positions)
            logits.block_until_ready()
        wall = time.perf_counter() - t0
        self.clock += self.step_time_fn(self.slots.num_active) if self.step_time_fn else wall

        next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        new = self.tokens
        for s in self.slots.active_slots:
            req = self.slots.slot_req[s]
            req.generated += 1
            req.token_times.append(self.clock)
            self.slots.advance(s)
            new = new.at[s, 0].set(int(next_tokens[s]))
            if req.generated >= req.output_len or self.slots.positions[s] >= self.cache_len - 2:
                req.finished = self.clock
                self.completed.append(self.slots.release(s))
        self.tokens = new

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_steps: int = 100_000) -> Dict:
        """Serve all requests (arrivals gated by the engine clock)."""
        waiting = sorted(requests, key=lambda r: r.arrival)
        steps = 0
        while (waiting or self.slots.num_active) and steps < max_steps:
            # admit arrived requests into free slots
            while waiting and waiting[0].arrival <= self.clock and self.slots.free_slots:
                self._prefill_request(waiting.pop(0))
            if self.slots.num_active == 0:
                if waiting:  # idle: jump to next arrival
                    self.clock = max(self.clock, waiting[0].arrival)
                    continue
                break
            self._decode_iteration()
            steps += 1
        return self.metrics()

    # ------------------------------------------------------------------
    def reconfigure(
        self,
        n_attn: Optional[int] = None,
        n_moe: Optional[int] = None,
        layout: Optional[ReplicaLayout] = None,
    ) -> Dict[str, bool]:
        """Actuate a scaling decision mid-run (§3.5): only the pool whose
        count changed is re-lowered; in-flight KV caches are preserved.
        Disagg executor only — the monolithic engine re-lowers wholesale."""
        if self.disagg is not None:
            relower = self.disagg.reconfigure(n_attn=n_attn, n_moe=n_moe, layout=layout)
            self.layout = self.disagg.layout
            return relower
        raise NotImplementedError(
            "mid-run reconfigure requires executor='disagg' (the monolithic "
            "engine re-lowers wholesale — rebuild the engine instead)"
        )

    # ------------------------------------------------------------------
    def metrics(self) -> Dict:
        done = self.completed
        out: Dict = {"completed": len(done), "tokens": sum(r.generated for r in done)}
        # disaggregated-exchange telemetry (satellite of amax_log): which
        # two-phase regime served each step, and the bytes it moved
        if self.regime_log:
            out["regime_counts"] = {
                r: self.regime_log.count(r) for r in sorted(set(self.regime_log))
            }
            out["transfer_bytes_total"] = int(sum(self.transfer_bytes_log))
            out["transfer_bytes_per_step"] = float(
                np.mean(self.transfer_bytes_log)
            )
        if self.amax_log:
            out["amax_mean"] = float(np.mean(self.amax_log))
            out["amax_max"] = int(np.max(self.amax_log))
        if not done:
            return out
        gaps = np.concatenate(
            [np.diff(r.token_times) for r in done if len(r.token_times) > 1]
        )
        span = max(r.finished for r in done) - min(r.arrival for r in done)
        out.update(
            throughput_tok_s=out["tokens"] / max(span, 1e-9),
            tpot_mean=float(gaps.mean()) if len(gaps) else 0.0,
            tpot_p99=float(np.percentile(gaps, 99)) if len(gaps) else 0.0,
            clock=self.clock,
        )
        return out
