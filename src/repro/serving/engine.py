"""Continuous-batching decode engine with the Janus scheduled-MoE path.

The engine serves a stream of requests against one model deployment:

  * admission: waiting requests are placed into free batch slots through one
    of two admission paths:

      - ``admission="blocking"`` (legacy *schedule*): each request's whole
        prompt is prefilled before the next decode iteration, charging the
        decode clock — one long prompt stalls every in-flight request.  The
        compute itself goes through the same worker/chunking as the
        pipelined path, so the two admission modes are bit-identical in
        what they serve and differ only in when;
      - ``admission="pipelined"`` (default when a prefill pool exists): the
        request *reserves* a slot and is handed to the
        :class:`repro.serving.prefill.PrefillWorker`, which chunks the
        prompt on the dedicated prefill pool and streams each finished
        chunk's KV slab straight into the decode-side batched caches; the
        slot walks ``reserved → prefilling → active`` and the decode loop
        never waits on a prompt;

  * decode: one batched decode per iteration with *per-slot* positions
    (continuous batching — slots join/leave independently), through one of
    two executors sharing identical semantics and telemetry:

      - ``executor="mono"``: the jitted monolithic ``decode_step`` on the
        default device (single-instance baseline);
      - ``executor="disagg"``: the two-decode-pool
        :class:`repro.serving.disagg.DisaggExecutor` — attention stages on
        ``n_attn`` pool devices, expert stages on the MoE pool, with the
        adaptive two-phase exchange realised per layer and per-step
        regime / transfer-byte / ``a_max`` telemetry recorded;

  * MoE architectures route through the scheduled slot path: routing →
    AEBS (or a baseline scheduler) → replica-slot dispatch, with per-layer
    ``a_max`` telemetry surfaced to the controller.  Dispatch defaults to
    the sort-based grouped path (``repro.models.moe.grouped_dispatch_ffn``)
    — no per-step ``[S_total, d, f]`` weight materialisation;
  * timing: wall-clock by default, or pluggable ``step_time_fn`` /
    ``prefill_time_fn`` driven by the analytic performance model (used in
    tests and the simulator); the prefill pool keeps its own concurrent
    timeline, so pipelined admission never charges prompt work to the
    decode clock;
  * scaling: :meth:`ServingEngine.reconfigure` actuates a controller
    decision mid-run (§3.5) — prefill, attention and MoE pool counts move
    independently, in-flight KV caches are preserved.
"""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.core import baselines
from repro.core.disagg import DevicePools
from repro.kernels.aebs.ops import aebs_schedule
from repro.models import model as model_mod
from repro.serving.kv_cache import (
    SlotManager,
    scatter_prefill_caches,
    scatter_prefill_chunk_caches,
)
from repro.serving.prefill import PrefillEvent, PrefillWorker
from repro.serving.request import Request

SCHEDULERS = {
    "aebs": aebs_assign,
    "aebs_kernel": aebs_schedule,  # Pallas TPU kernel, same Algorithm-1 contract
    "random": baselines.random_assign,
    "token_hash": baselines.token_hash_assign,
    "none": None,
}


class ServingEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        max_batch: int = 8,
        cache_len: int = 512,
        layout: Optional[ReplicaLayout] = None,
        scheduler: str = "aebs",
        capacity_tokens: Optional[int] = None,
        prefill_capacity_tokens: Optional[int] = None,  # default: capacity_tokens
        dispatch: str = "grouped",  # grouped = slot-indirect hot path (no weight copy)
        step_time_fn: Optional[Callable[[int], float]] = None,
        prefill_time_fn: Optional[Callable[[int], float]] = None,
        extra_builder: Optional[Callable[[int], Dict]] = None,
        executor: str = "mono",  # mono | disagg
        n_attn: int = 1,
        n_prefill: int = 0,
        admission: Optional[str] = None,  # blocking | pipelined (default: pipelined iff n_prefill)
        prefill_chunk: int = 64,
        pools: Optional[DevicePools] = None,
        node_size: int = 1,
        ping_pong: bool = False,
    ):
        self.cfg = cfg
        self.params = params
        self.slots = SlotManager(max_batch, cache_len)
        self.cache_len = cache_len
        self.max_batch = max_batch
        self.layout = layout
        self.scheduler_name = scheduler
        self.step_time_fn = step_time_fn
        self.prefill_time_fn = prefill_time_fn
        self.extra_builder = extra_builder
        self.executor_name = executor
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.clock = 0.0
        self.amax_log: List[int] = []
        self.regime_log: List[str] = []
        self.transfer_bytes_log: List[int] = []
        self.completed: List[Request] = []
        self.decode_stall_time = 0.0  # prefill time charged while decodes were in flight

        moe_ctx = None
        if cfg.has_moe and layout is not None and scheduler != "none":
            moe_ctx = dict(
                dispatch=dispatch,
                layout_tables=layout.device_tables(),
                slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
                num_instances=layout.num_instances,
                scheduler=SCHEDULERS[scheduler],
                capacity=capacity_tokens,
            )
        self._moe_ctx = moe_ctx

        self.disagg: Optional["DisaggExecutor"] = None
        if executor == "disagg":
            from repro.serving.disagg import DisaggExecutor

            if layout is None or scheduler == "none":
                raise ValueError("executor='disagg' needs a replica layout and scheduler")
            if pools is None:
                pools = DevicePools.split(
                    n_attn, layout.num_instances, node_size=node_size,
                    n_prefill=n_prefill,
                    allow_reuse=len(jax.devices()) < n_attn + layout.num_instances + n_prefill,
                )
            self.disagg = DisaggExecutor(
                cfg, params, pools, layout,
                max_batch=max_batch, cache_len=cache_len,
                scheduler=SCHEDULERS[scheduler], capacity=capacity_tokens,
                ping_pong=ping_pong,
            )
            self.caches = None  # cache residency moves to the executor's pool
        elif executor == "mono":
            if pools is None and n_prefill:
                pools = DevicePools.split(
                    0, 0, n_prefill=n_prefill,
                    allow_reuse=len(jax.devices()) < n_prefill,
                )
            self.caches = model_mod.init_decode_caches(cfg, max_batch, cache_len)
        else:
            raise ValueError(f"unknown executor: {executor}")

        def _decode(params, tokens, caches, positions):
            extra = {"moe_ctx": moe_ctx} if moe_ctx else None
            return model_mod.decode_step(params, tokens, caches, positions, cfg, extra=extra)

        self._decode_jit = jax.jit(_decode)

        # prefill path: logical-expert routing (no scheduling — prompts don't
        # route through replica slots) on the sort-based grouped dispatch.
        # Capacity is drop-free by default: the worker fills a None capacity
        # with each call's own token count (an expert can receive at most
        # that many tokens), so blocking, pipelined and chunked prefill all
        # see zero drops and stay bit-identical regardless of the decode
        # budget.  ``prefill_capacity_tokens`` overrides this with a fixed
        # cap for operators who deliberately want prompt-side drops.
        prefill_moe_ctx = (
            {"capacity": prefill_capacity_tokens, "dispatch": "grouped"}
            if cfg.has_moe
            else None
        )

        # admission pipeline (tentpole): all prompt work goes through the
        # PrefillWorker — "pipelined" overlaps it with decode via the slot
        # state machine, "blocking" drains it synchronously per request and
        # charges the decode clock (the legacy schedule).  Sharing one worker
        # keeps the two admission modes' numerics identical by construction
        # (same chunking, same jitted programs), so token streams are
        # bit-equal across admission modes, not just across executors.
        if admission is None:
            admission = "pipelined" if n_prefill else "blocking"
        if admission not in ("blocking", "pipelined"):
            raise ValueError(f"unknown admission mode: {admission}")
        self.admission = admission
        self._ready: List[PrefillEvent] = []
        prefill_devices = list(pools.prefill_devices) if pools is not None else []
        worker_extra = self.extra_builder(1) if self.extra_builder else None
        if prefill_moe_ctx is not None:
            worker_extra = dict(worker_extra or {})
            worker_extra["moe_ctx"] = prefill_moe_ctx
        # under a modeled decode clock with no prefill model, prefill is free
        # (legacy semantics) — never mix wall-clock stamps into a modeled
        # timeline, or activation times become meaningless hybrids
        worker_time_fn = prefill_time_fn
        if step_time_fn is not None and prefill_time_fn is None:
            worker_time_fn = lambda n_tok: 0.0
        self.prefill_worker = PrefillWorker(
            cfg, params, prefill_devices,
            cache_len=cache_len, chunk=prefill_chunk,
            extra=worker_extra, prefill_time_fn=worker_time_fn,
        )

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _prefill_request(self, req: Request) -> None:
        """Blocking admission: drain the prefill worker synchronously for this
        one request — the legacy schedule (the decode loop stalls for the
        whole prompt), with the same chunked compute as the pipelined path."""
        stalled = self.slots.num_active > 0
        slot = self.slots.reserve(req)
        self.slots.start_prefill(slot)
        now = max(self.clock, req.arrival)
        self.prefill_worker.submit(req, slot, now=now)
        events: List[PrefillEvent] = []
        while not events:
            events = self.prefill_worker.poll(self._chunk_sink)
        ev = events[0]
        # legacy clock semantics: modeled prefill time when calibrated, wall
        # otherwise (zero under a modeled decode clock with no prefill model —
        # the worker's time_fn is already pinned to 0 for that combination)
        dt = ev.finish_t - now
        self.slots.activate(slot)
        self.tokens = self.tokens.at[slot, 0].set(ev.first_token)
        self.clock += dt
        if stalled:
            self.decode_stall_time += dt
        req.prefill_done = self.clock
        req.token_times.append(self.clock)
        req.tokens_out = [ev.first_token]

    def _submit_request(self, req: Request) -> None:
        """Pipelined admission: reserve the slot, queue the prompt for the
        prefill pool — the decode clock is never charged."""
        slot = self.slots.reserve(req)
        self.slots.start_prefill(slot)
        self.prefill_worker.submit(req, slot, now=max(self.clock, req.arrival))

    def _chunk_sink(self, slot: int, start: int, length: int, one_caches: Dict) -> None:
        """Land one streamed prefill chunk (or a whole-prompt fallback cache,
        ``length == -1``) in the decode-side caches."""
        if self.disagg is not None:
            if length < 0:
                self.disagg.scatter_prefill(one_caches, slot)
            else:
                self.disagg.scatter_prefill_chunk(one_caches, slot, start, length)
        elif length < 0:
            self.caches = scatter_prefill_caches(self.caches, one_caches, slot)
        else:
            self.caches = scatter_prefill_chunk_caches(
                self.caches, one_caches, slot, start, length
            )

    def _poll_prefill(self) -> None:
        """Advance the prefill pipeline and activate any finished requests
        whose completion stamp the decode clock has passed."""
        self._ready.extend(self.prefill_worker.poll(self._chunk_sink))
        still: List[PrefillEvent] = []
        for ev in self._ready:
            if ev.finish_t <= self.clock:
                self.slots.activate(ev.slot)
                self.tokens = self.tokens.at[ev.slot, 0].set(ev.first_token)
                ev.req.prefill_done = ev.finish_t
                ev.req.token_times.append(ev.finish_t)
                ev.req.tokens_out = [ev.first_token]
            else:
                still.append(ev)
        self._ready = still

    def _prefill_pending(self) -> int:
        return self.prefill_worker.num_pending + len(self._ready)

    # ------------------------------------------------------------------
    def _decode_iteration(self) -> None:
        positions = self.slots.positions_device()
        t0 = time.perf_counter()
        if self.disagg is not None:
            logits, tel = self.disagg.decode_step(self.tokens, positions)
            logits.block_until_ready()
            self.regime_log.append(tel["regime"])
            self.transfer_bytes_log.append(tel["bytes_total"])
            self.amax_log.append(tel["a_max"])
        else:
            logits, self.caches = self._decode_jit(self.params, self.tokens, self.caches, positions)
            logits.block_until_ready()
        wall = time.perf_counter() - t0
        self.clock += self.step_time_fn(self.slots.num_active) if self.step_time_fn else wall

        next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        new = self.tokens
        for s in self.slots.active_slots:
            req = self.slots.slot_req[s]
            req.generated += 1
            req.token_times.append(self.clock)
            self.slots.advance(s)
            new = new.at[s, 0].set(int(next_tokens[s]))
            if req.tokens_out is not None:
                req.tokens_out.append(int(next_tokens[s]))
            if req.generated >= req.output_len or self.slots.positions[s] >= self.cache_len - 2:
                if req.generated < req.output_len:
                    req.truncated = True  # context exhausted before target length
                req.finished = self.clock
                self.completed.append(self.slots.release(s))
        self.tokens = new

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_steps: int = 100_000) -> Dict:
        """Serve all requests (arrivals gated by the engine clock)."""
        waiting = sorted(requests, key=lambda r: r.arrival)
        steps = 0
        while (waiting or self.slots.num_active or self._prefill_pending()) and steps < max_steps:
            # admit arrived requests into free slots
            while waiting and waiting[0].arrival <= self.clock and self.slots.free_slots:
                req = waiting.pop(0)
                if self.admission == "pipelined":
                    self._submit_request(req)
                else:
                    self._prefill_request(req)
            self._poll_prefill()
            if self.slots.num_active == 0:
                if self._ready:  # idle: jump to the next prefill completion
                    self.clock = max(self.clock, min(ev.finish_t for ev in self._ready))
                    continue
                if self._prefill_pending():  # chunks still streaming: keep polling
                    continue
                if waiting:  # idle: jump to next arrival
                    self.clock = max(self.clock, waiting[0].arrival)
                    continue
                break
            self._decode_iteration()
            steps += 1
        return self.metrics()

    # ------------------------------------------------------------------
    def reconfigure(
        self,
        n_attn: Optional[int] = None,
        n_moe: Optional[int] = None,
        layout: Optional[ReplicaLayout] = None,
        n_prefill: Optional[int] = None,
    ) -> Dict[str, bool]:
        """Actuate a scaling decision mid-run (§3.5): only the pools whose
        counts changed are re-lowered; in-flight KV caches are preserved and
        in-progress chunked prefills migrate with the prefill pool.
        Disagg executor only — the monolithic engine re-lowers wholesale."""
        if self.disagg is None:
            raise NotImplementedError(
                "mid-run reconfigure requires executor='disagg' (the monolithic "
                "engine re-lowers wholesale — rebuild the engine instead)"
            )
        relower = self.disagg.reconfigure(
            n_attn=n_attn, n_moe=n_moe, layout=layout, n_prefill=n_prefill
        )
        self.layout = self.disagg.layout
        if relower.get("prefill"):
            self.prefill_worker.set_devices(
                self.disagg.pools.prefill_devices, self.params
            )
        return relower

    # ------------------------------------------------------------------
    def metrics(self) -> Dict:
        done = self.completed
        out: Dict = {"completed": len(done), "tokens": sum(r.generated for r in done)}
        out["truncated"] = sum(1 for r in done if r.truncated)
        out["decode_stall_time"] = self.decode_stall_time
        out["prefill_chunks"] = self.prefill_worker.chunks_done
        # disaggregated-exchange telemetry (satellite of amax_log): which
        # two-phase regime served each step, and the bytes it moved
        if self.regime_log:
            out["regime_counts"] = {
                r: self.regime_log.count(r) for r in sorted(set(self.regime_log))
            }
            out["transfer_bytes_total"] = int(sum(self.transfer_bytes_log))
            out["transfer_bytes_per_step"] = float(
                np.mean(self.transfer_bytes_log)
            )
        if self.amax_log:
            out["amax_mean"] = float(np.mean(self.amax_log))
            out["amax_max"] = int(np.max(self.amax_log))
        if not done:
            return out
        # TTFT: prompt turnaround (arrival → first token) — the metric the
        # prefill pool exists to improve; TPOT alone can't see prefill wins
        ttfts = np.array([r.prefill_done - r.arrival for r in done if r.prefill_done >= 0])
        if len(ttfts):
            out["ttft_mean"] = float(ttfts.mean())
            out["ttft_p99"] = float(np.percentile(ttfts, 99))
        gaps = np.concatenate(
            [np.diff(r.token_times) for r in done if len(r.token_times) > 1]
        )
        span = max(r.finished for r in done) - min(r.arrival for r in done)
        out.update(
            throughput_tok_s=out["tokens"] / max(span, 1e-9),
            tpot_mean=float(gaps.mean()) if len(gaps) else 0.0,
            tpot_p99=float(np.percentile(gaps, 99)) if len(gaps) else 0.0,
            clock=self.clock,
        )
        return out
