"""Continuous-batching decode engine with the Janus scheduled-MoE path.

The engine serves a stream of requests against one model deployment:

  * admission: waiting requests are placed into free batch slots through one
    of two admission paths:

      - ``admission="blocking"`` (legacy *schedule*): each request's whole
        prompt is prefilled before the next decode iteration, charging the
        decode clock — one long prompt stalls every in-flight request.  The
        compute itself goes through the same worker/chunking as the
        pipelined path, so the two admission modes are bit-identical in
        what they serve and differ only in when;
      - ``admission="pipelined"`` (default when a prefill pool exists): the
        request *reserves* a slot and is handed to the
        :class:`repro.serving.prefill.PrefillWorker`, which chunks the
        prompt on the dedicated prefill pool and streams each finished
        chunk's KV slab straight into the decode-side batched caches; the
        slot walks ``reserved → prefilling → active`` and the decode loop
        never waits on a prompt;

  * decode: one batched decode per iteration with *per-slot* positions
    (continuous batching — slots join/leave independently), through one of
    two executors sharing identical semantics and telemetry:

      - ``executor="mono"``: the jitted monolithic ``decode_step`` on the
        default device (single-instance baseline);
      - ``executor="disagg"``: the two-decode-pool
        :class:`repro.serving.disagg.DisaggExecutor` — attention stages on
        ``n_attn`` pool devices, expert stages on the MoE pool, with the
        adaptive two-phase exchange realised per layer and per-step
        regime / transfer-byte / ``a_max`` telemetry recorded;

  * MoE architectures route through the scheduled slot path: routing →
    AEBS (or a baseline scheduler) → replica-slot dispatch, with per-layer
    ``a_max`` telemetry surfaced to the controller.  Dispatch defaults to
    the sort-based grouped path (``repro.models.moe.grouped_dispatch_ffn``)
    — no per-step ``[S_total, d, f]`` weight materialisation;
  * timing: wall-clock by default, or pluggable ``step_time_fn`` /
    ``prefill_time_fn`` driven by the analytic performance model (used in
    tests and the simulator); the prefill pool keeps its own concurrent
    timeline, so pipelined admission never charges prompt work to the
    decode clock;
  * scaling: :meth:`ServingEngine.reconfigure` actuates a controller
    decision mid-run (§3.5) — prefill, attention and MoE pool counts move
    independently, in-flight KV caches are preserved.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aebs import ReplicaLayout, aebs_assign
from repro.core import baselines
from repro.core.disagg import DevicePools
from repro.core.placement import layout_for_survivors
from repro.kernels.aebs.ops import aebs_schedule
from repro.models import model as model_mod
from repro.serving.faults import (
    DEVICE_LOSS,
    FaultPlan,
    FaultRuntime,
    PoolFault,
    RetryPolicy,
    Watchdog,
)
from repro.serving.kv_cache import (
    ACTIVE,
    PAGED_KEYS,
    PREFILLING,
    PagedKVCache,
    PrefixIndex,
    SlotManager,
    make_paged_caches,
    paginate_caches,
    scatter_prefill_caches,
    scatter_prefill_chunk_caches,
    scatter_prefill_chunk_paged,
    zero_slots,
)
from repro.serving.prefill import PrefillEvent, PrefillWorker
from repro.serving.request import Request

SCHEDULERS = {
    "aebs": aebs_assign,
    "aebs_kernel": aebs_schedule,  # Pallas TPU kernel, same Algorithm-1 contract
    "random": baselines.random_assign,
    "token_hash": baselines.token_hash_assign,
    "none": None,
}

# request-level admission schedulers (``sched=``, distinct from the MoE
# replica-slot ``scheduler=``): fifo = strict arrival order, priority =
# higher Request.priority first with preemption via KV spill/restore
ADMISSION_SCHEDS = ("fifo", "priority")


@dataclasses.dataclass
class _SpillRecord:
    """A preempted request waiting off-batch: its detached KV payload (a
    mono :class:`SpilledKV` or disagg ``SpilledSlotKV``) and, on disagg, the
    shard the pages must re-attach to.  ``payload=None`` means the pages
    were dissolved by an attention re-shard while spilled — the restore
    falls back to deterministic replay."""

    req: Request
    payload: Optional[Any]
    shard: Optional[int] = None
    spilled_at: float = 0.0  # engine clock at preemption (TPOT wait split)


class ServingEngine:
    def __init__(
        self,
        cfg,
        params,
        *,
        max_batch: int = 8,
        cache_len: int = 512,
        layout: Optional[ReplicaLayout] = None,
        scheduler: str = "aebs",
        capacity_tokens: Optional[int] = None,
        prefill_capacity_tokens: Optional[int] = None,  # default: capacity_tokens
        dispatch: str = "grouped",  # grouped = slot-indirect hot path (no weight copy)
        step_time_fn: Optional[Callable[[int], float]] = None,
        prefill_time_fn: Optional[Callable[[int], float]] = None,
        extra_builder: Optional[Callable[[int], Dict]] = None,
        executor: str = "mono",  # mono | disagg
        n_attn: int = 1,
        n_prefill: int = 0,
        admission: Optional[str] = None,  # blocking | pipelined (default: pipelined iff n_prefill)
        prefill_chunk: int = 64,
        pools: Optional[DevicePools] = None,
        node_size: int = 1,
        ping_pong: bool = False,
        fault_plan: Optional[FaultPlan] = None,
        retry_policy: Optional[RetryPolicy] = None,
        watchdog: Optional[Watchdog] = None,
        max_prefill_queue: Optional[int] = None,  # admission backpressure bound
        kv_page_size: Optional[int] = None,  # page the "" KV caches (None = contiguous)
        kv_num_pages: Optional[int] = None,  # pool size (default: full backing + null)
        prefix_cache: bool = False,  # page-granular radix prefix reuse (needs paging)
        prefix_cache_pages: Optional[int] = None,  # index pin budget (None = unbounded)
        prefill_batch: int = 1,  # prompts fused per prefill-device chunk call
        sched: str = "fifo",  # request admission: fifo | priority (preemptive)
        draft_config=None,  # small config drafting tokens → speculative decode
        draft_params=None,  # default: shared weights (self-draft) or fresh init
        spec_k: int = 0,  # drafts per verify step (0 + draft_config → 2)
    ):
        self.cfg = cfg
        self.params = params
        self.slots = SlotManager(max_batch, cache_len)
        self.cache_len = cache_len
        self.max_batch = max_batch
        self.layout = layout
        self.scheduler_name = scheduler
        self.step_time_fn = step_time_fn
        self.prefill_time_fn = prefill_time_fn
        self.extra_builder = extra_builder
        self.executor_name = executor
        self.tokens = jnp.zeros((max_batch, 1), jnp.int32)
        self.clock = 0.0
        self.amax_log: List[int] = []
        self.regime_log: List[str] = []
        self.transfer_bytes_log: List[int] = []
        self.completed: List[Request] = []
        self.rejected: List[Request] = []
        self.decode_stall_time = 0.0  # prefill time charged while decodes were in flight
        self.steps_done = 0  # global decode-step ordinal (fault schedules key off it)
        if max_prefill_queue is not None and max_prefill_queue < 1:
            raise ValueError(
                f"max_prefill_queue must be ≥ 1, got {max_prefill_queue} "
                "(a zero bound would close admission permanently)"
            )
        self.max_prefill_queue = max_prefill_queue
        if sched not in ADMISSION_SCHEDS:
            raise ValueError(
                f"unknown admission scheduler {sched!r}; choose from "
                f"{ADMISSION_SCHEDS}"
            )
        self.sched = sched
        self._spilled: List[_SpillRecord] = []  # preempted, awaiting restore
        self.preempt_count = 0
        self.restore_count = 0
        self.spill_replay_count = 0  # restores that had to replay (pages lost)
        self.kv_page_size = kv_page_size
        self.kv_num_pages = kv_num_pages
        self.paged: Optional[PagedKVCache] = None  # mono-executor page manager
        self.prefix_cache = bool(prefix_cache)
        self.prefix_cache_pages = prefix_cache_pages
        self.prefix: Optional[PrefixIndex] = None  # mono-executor radix index
        if self.prefix_cache:
            if kv_page_size is None:
                raise ValueError(
                    "prefix_cache requires paged KV storage (set kv_page_size) "
                    "— a hit is served by block-table page sharing"
                )
            if not model_mod.supports_batched_prefill(cfg):
                raise ValueError(
                    "prefix_cache requires an architecture whose decode caches "
                    "are all full-attention (dense/moe periods) — rolling-"
                    "window / recurrent state cannot be seeded positionally"
                )
        # effective worker chunk (mirrors PrefillWorker's sliding-window
        # clamp) — the prefix index's chunk grid must match it exactly
        eff_chunk = max(1, int(prefill_chunk))
        if getattr(cfg, "sliding_window", None):
            eff_chunk = min(eff_chunk, min(cache_len, cfg.sliding_window))
        self.faults: Optional[FaultRuntime] = None
        self.degraded_reason: Optional[str] = None
        # subscribers notified on permanent device loss: fn(fault, clock).
        # The AutoScaler attaches here so lost capacity feeds its next decision.
        self.fault_listeners: List[Callable[[PoolFault, float], None]] = []

        moe_ctx = None
        if cfg.has_moe and layout is not None and scheduler != "none":
            moe_ctx = dict(
                dispatch=dispatch,
                layout_tables=layout.device_tables(),
                slot_to_expert=jnp.asarray(layout.slot_to_expert.reshape(-1)),
                num_instances=layout.num_instances,
                scheduler=SCHEDULERS[scheduler],
                capacity=capacity_tokens,
            )
        self._moe_ctx = moe_ctx

        self.disagg: Optional["DisaggExecutor"] = None
        if executor == "disagg":
            from repro.serving.disagg import DisaggExecutor

            if layout is None or scheduler == "none":
                raise ValueError("executor='disagg' needs a replica layout and scheduler")
            if pools is None:
                pools = DevicePools.split(
                    n_attn, layout.num_instances, node_size=node_size,
                    n_prefill=n_prefill,
                    allow_reuse=len(jax.devices()) < n_attn + layout.num_instances + n_prefill,
                )
            self.disagg = DisaggExecutor(
                cfg, params, pools, layout,
                max_batch=max_batch, cache_len=cache_len,
                scheduler=SCHEDULERS[scheduler], capacity=capacity_tokens,
                ping_pong=ping_pong,
                kv_page_size=kv_page_size, kv_num_pages=kv_num_pages,
                prefix_cache=self.prefix_cache,
                prefix_cache_pages=prefix_cache_pages,
                prefix_chunk=eff_chunk,
            )
            self.caches = None  # cache residency moves to the executor's pool
        elif executor == "mono":
            if pools is None and n_prefill:
                pools = DevicePools.split(
                    0, 0, n_prefill=n_prefill,
                    allow_reuse=len(jax.devices()) < n_prefill,
                )
            self.caches = model_mod.init_decode_caches(cfg, max_batch, cache_len)
            if kv_page_size is not None:
                self.paged, self.caches = make_paged_caches(
                    self.caches, max_batch, cache_len, kv_page_size, kv_num_pages
                )
        else:
            raise ValueError(f"unknown executor: {executor}")

        def _decode(params, tokens, caches, positions):
            extra = {"moe_ctx": moe_ctx} if moe_ctx else None
            return model_mod.decode_step(params, tokens, caches, positions, cfg, extra=extra)

        self._decode_jit = jax.jit(_decode)

        # --- speculative decode: draft model + batched greedy verify -------
        # The draft proposes ``spec_k`` tokens per iteration; one
        # ``decode_step_verify`` call scores all of them (plus the last
        # accepted token) in a single pass and the longest greedy-matching
        # prefix is accepted.  Verification is against the target's own
        # argmax, so the emitted stream is bit-identical to non-speculative
        # greedy decode no matter what the draft proposes — the draft only
        # moves the acceptance rate, never the tokens.
        if spec_k < 0:
            raise ValueError(f"spec_k must be ≥ 0, got {spec_k}")
        if spec_k and draft_config is None:
            raise ValueError("spec_k > 0 requires a draft_config")
        if draft_config is not None and spec_k == 0:
            spec_k = 2
        self.spec_k = int(spec_k)
        self.draft_config = draft_config if self.spec_k else None
        self.spec_steps = 0  # verify iterations taken
        self.spec_slot_steps = 0  # per-slot verify participations
        self.spec_draft_tokens = 0  # draft proposals scored
        self.spec_draft_accepted = 0  # draft proposals accepted
        self.spec_emitted_tokens = 0  # tokens emitted by verify steps
        self._draft_params = None
        self._draft_caches = None
        # slot → (rid, n): draft cache rows [0, n) mirror request rid's true
        # token stream; anything less at decode position forces a rebuild
        self._draft_stream: Dict[int, tuple] = {}
        if self.spec_k:
            dcfg = draft_config
            if not model_mod.supports_speculative_decode(cfg):
                raise ValueError(
                    "speculative decode requires full-context dense/moe decode "
                    "layers (rolling-window / recurrent state has no batched "
                    "multi-position verify)"
                )
            if dcfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab ({dcfg.vocab_size}) must match target vocab "
                    f"({cfg.vocab_size}) — draft tokens are verified verbatim"
                )
            if draft_params is not None:
                self._draft_params = draft_params
            elif dcfg is cfg or dcfg.name == cfg.name:
                self._draft_params = params  # self-draft: share target weights
            else:
                self._draft_params = model_mod.init_params(dcfg, seed=0)
            self._draft_caches = model_mod.init_decode_caches(
                dcfg, max_batch, cache_len
            )

            def _draft_decode(dparams, tokens, caches, positions):
                return model_mod.decode_step(dparams, tokens, caches, positions, dcfg)

            def _draft_prefill(dparams, tokens):
                return model_mod.prefill(dparams, tokens, dcfg, cache_len)

            def _verify(params, tokens, caches, positions, widths):
                # same extra as the base decode closure: the verify unrolls
                # per-position decode steps, so each routes exactly b tokens
                # under the unchanged capacity budget — identical drop
                # patterns to sequential decode by construction
                extra = {"moe_ctx": moe_ctx} if moe_ctx else None
                return model_mod.decode_step_verify(
                    params, tokens, caches, positions, cfg, extra=extra, widths=widths
                )

            self._draft_decode_jit = jax.jit(_draft_decode)
            self._draft_prefill_jit = jax.jit(_draft_prefill)
            self._verify_jit = jax.jit(_verify)

        # prefill path: logical-expert routing (no scheduling — prompts don't
        # route through replica slots) on the sort-based grouped dispatch.
        # Capacity is drop-free by default: the worker fills a None capacity
        # with each call's own token count (an expert can receive at most
        # that many tokens), so blocking, pipelined and chunked prefill all
        # see zero drops and stay bit-identical regardless of the decode
        # budget.  ``prefill_capacity_tokens`` overrides this with a fixed
        # cap for operators who deliberately want prompt-side drops.
        prefill_moe_ctx = (
            {"capacity": prefill_capacity_tokens, "dispatch": "grouped"}
            if cfg.has_moe
            else None
        )

        # admission pipeline (tentpole): all prompt work goes through the
        # PrefillWorker — "pipelined" overlaps it with decode via the slot
        # state machine, "blocking" drains it synchronously per request and
        # charges the decode clock (the legacy schedule).  Sharing one worker
        # keeps the two admission modes' numerics identical by construction
        # (same chunking, same jitted programs), so token streams are
        # bit-equal across admission modes, not just across executors.
        if admission is None:
            admission = "pipelined" if n_prefill else "blocking"
        if admission not in ("blocking", "pipelined"):
            raise ValueError(f"unknown admission mode: {admission}")
        self.admission = admission
        self._ready: List[PrefillEvent] = []
        prefill_devices = list(pools.prefill_devices) if pools is not None else []
        worker_extra = self.extra_builder(1) if self.extra_builder else None
        if prefill_moe_ctx is not None:
            worker_extra = dict(worker_extra or {})
            worker_extra["moe_ctx"] = prefill_moe_ctx
        # under a modeled decode clock with no prefill model, prefill is free
        # (legacy semantics) — never mix wall-clock stamps into a modeled
        # timeline, or activation times become meaningless hybrids
        worker_time_fn = prefill_time_fn
        if step_time_fn is not None and prefill_time_fn is None:
            worker_time_fn = lambda n_tok: 0.0
        self.prefill_worker = PrefillWorker(
            cfg, params, prefill_devices,
            cache_len=cache_len, chunk=prefill_chunk,
            extra=worker_extra, prefill_time_fn=worker_time_fn,
            batch=prefill_batch,
        )
        if self.prefix_cache and self.paged is not None:
            self.prefix = PrefixIndex(
                self.prefill_worker.chunk, self.paged,
                max_pages=prefix_cache_pages,
            )

        if fault_plan is not None:
            self.arm_faults(fault_plan, policy=retry_policy, watchdog=watchdog)

    # ------------------------------------------------------------------
    # fault injection / health monitoring
    # ------------------------------------------------------------------
    def arm_faults(
        self,
        plan: FaultPlan,
        policy: Optional[RetryPolicy] = None,
        watchdog: Optional[Watchdog] = None,
    ) -> FaultRuntime:
        """Arm a fault plan: build the runtime and install its hooks on the
        executor exchange path and the prefill worker's chunk loop.  With no
        plan armed neither hook exists and the hot path is untouched."""
        self.faults = FaultRuntime(plan, policy=policy, watchdog=watchdog)
        if self.disagg is not None:
            self.disagg.fault_hook = self.faults.exchange_hook
        self.prefill_worker.fault_hook = self.faults.prefill_hook
        return self.faults

    def _pool_sizes(self) -> Dict[str, int]:
        sizes = {"attn": 0, "moe": 0}
        if self.disagg is not None:
            sizes["attn"] = len(self.disagg.pools.attn_devices)
            sizes["moe"] = len(self.disagg.pools.moe_devices)
        sizes["prefill"] = len(self.prefill_worker.devices)
        return sizes

    def _charge(self, dt: float) -> None:
        """Advance the clock for fault handling (backoff, recovery) and book
        the stall so operators can see what faults cost."""
        if dt <= 0:
            return
        self.clock += dt
        if self.faults is not None:
            self.faults.stats.fault_stall_s += dt

    def _fault_preflight(self) -> None:
        """Heartbeat: fire any step-scheduled faults, then poll pool health
        and recover from every detected device loss before decoding."""
        self.faults.advance_to_step(self.steps_done)
        while True:
            fault = self.faults.poll_health(self._pool_sizes())
            if fault is None:
                return
            self._recover(fault)

    def _recover(self, fault: PoolFault) -> None:
        """Dispatch recovery for a permanent fault and book its latency."""
        t0 = time.perf_counter()
        if fault.pool == "moe":
            self._recover_moe_loss(fault)
        elif fault.pool == "attn":
            self._recover_attn_loss(fault)
        elif fault.pool == "prefill":
            self._recover_prefill_loss(fault)
        else:  # unknown pool: last resort
            self._degrade_to_mono(f"unrecoverable fault: {fault}")
        self.faults.mark_handled(fault)
        wall = time.perf_counter() - t0
        stats = self.faults.stats
        stats.recoveries += 1
        stats.recovery_latency_s.append(wall)
        # modeled clocks charge the policy constant (deterministic tests);
        # wall clocks charge what recovery actually took
        self._charge(
            self.faults.policy.recovery_charge_s if self.step_time_fn else wall
        )
        if fault.kind == DEVICE_LOSS:
            for listener in self.fault_listeners:
                listener(fault, self.clock)

    def _recover_moe_loss(self, fault: PoolFault) -> None:
        """Permanent MoE-device loss: re-plan expert placement onto the
        survivors and re-lower only the MoE pool.  Every expert keeps a seat,
        so expert semantics — hence token streams — are unchanged."""
        ex = self.disagg
        if ex is None:
            return  # already degraded to mono: there is no MoE pool to lose
        n_moe = len(ex.pools.moe_devices)
        if n_moe <= 1:
            self._degrade_to_mono("lost the last MoE device")
            return
        ex.exclude_device("moe", fault.index)
        new_layout = layout_for_survivors(self.cfg.num_experts, n_moe - 1)
        self.reconfigure(n_moe=n_moe - 1, layout=new_layout)

    def _recover_attn_loss(self, fault: PoolFault) -> None:
        """Permanent attention-device loss: the dead shard's KV rows are
        gone.  Re-shard the batch over the survivors, then rebuild each lost
        slot by deterministic replay (re-prefill + re-decode of its own
        history) — bit-exact because every row is rewritten by the same
        jitted program that originally produced it."""
        ex = self.disagg
        if ex is None:
            return
        if len(ex.pools.attn_devices) <= 1:
            # no surviving shard to host the batch: degrade, then rebuild
            # everything (the whole batch lived on the dead device)
            self._degrade_to_mono(
                "lost the last attention device",
                lost_rows=list(range(self.max_batch)),
            )
            return
        lost_rows = ex.drop_attn_device(fault.index)
        # the re-shard rebuilt every shard's page pool from slot-owned pages
        # — KV detached into spill records dissolved with the old pools
        self._invalidate_spills()
        self._rebuild_lost_slots(lost_rows)

    def _recover_prefill_loss(self, fault: PoolFault) -> None:
        """Prefill-worker/device failure: drop its in-flight prefill, shrink
        the pool, and requeue the displaced request from chunk 0 — chunked
        prefill is deterministic, so the restart serves identical tokens."""
        worker = self.prefill_worker
        displaced = worker.fail_device(fault.index)
        if self.disagg is not None and len(self.disagg.pools.prefill_devices) > 0:
            self.disagg.exclude_device("prefill", fault.index)
            self.reconfigure(
                n_prefill=len(self.disagg.pools.prefill_devices) - 1
            )  # syncs worker.set_devices (falls back to the default device at 0)
        else:
            survivors = [d for i, d in enumerate(worker.devices) if i != fault.index]
            worker.set_devices(survivors, self.params)
        for req in displaced:
            slot = req.slot
            self.slots.fail(slot)
            self.slots.requeue(slot)
            self.slots.start_prefill(slot)
            # drop the slot's pages — including any prefix-cache pins — and
            # re-splice fresh: the restart must not leak reservations
            self._release_pages(slot)
            start, seed = self._prefix_splice(req, slot)
            worker.submit(
                req, slot, now=max(self.clock, req.arrival),
                start=start, seed_caches=seed,
            )
            self.faults.stats.requeued += 1

    def _rebuild_lost_slots(self, lost_rows: List[int]) -> None:
        """Restore every occupied slot whose KV rows a dead attention shard
        took with it: ACTIVE slots replay their full history; PREFILLING
        slots requeue (their already-streamed chunks landed on the dead
        shard); RESERVED/FREE slots had nothing to lose."""
        stats = self.faults.stats
        for slot in lost_rows:
            state = self.slots.state[slot]
            if state == ACTIVE:
                self._replay_slot(slot)
                stats.replayed_slots += 1
            elif state == PREFILLING:
                req = self.prefill_worker.cancel_slot(slot)
                if req is None:
                    # prefill already finished; its event is waiting for
                    # activation but every streamed chunk is lost — drop the
                    # event and restart the prompt
                    for ev in self._ready:
                        if ev.slot == slot:
                            req = ev.req
                    self._ready = [ev for ev in self._ready if ev.slot != slot]
                if req is None:
                    continue
                self.slots.fail(slot)
                self.slots.requeue(slot)
                self.slots.start_prefill(slot)
                self._release_pages(slot)
                start, seed = self._prefix_splice(req, slot)
                self.prefill_worker.submit(
                    req, slot, now=max(self.clock, req.arrival),
                    start=start, seed_caches=seed,
                )
                stats.requeued += 1

    def _replay_slot(self, slot: int) -> None:
        """Deterministically rebuild one slot's KV: re-prefill the prompt
        through the worker (same chunk boundaries, same jitted program →
        bit-exact), then re-decode the generated tokens one at a time with
        every other slot parked at the scratch row — each row is rebuilt by
        the machinery that originally wrote it, and every replayed token is
        checked against the recorded stream."""
        req = self.slots.slot_req[slot]
        first = self.prefill_worker.run_sync(
            self._prompt_tokens(req), slot, self._chunk_sink
        )
        if req.tokens_out and first != req.tokens_out[0]:
            raise RuntimeError(
                f"recovery replay diverged at the first token of slot {slot}: "
                f"{first} != {req.tokens_out[0]}"
            )
        for t in range(req.generated):
            toks = np.zeros((self.max_batch, 1), np.int32)
            toks[slot, 0] = req.tokens_out[t]
            pos = np.full((self.max_batch,), self.cache_len - 1, np.int32)
            pos[slot] = req.input_len + t
            self._ensure_slot_page(slot, req.input_len + t)
            if self.disagg is not None:
                logits, _ = self.disagg.decode_step(
                    jnp.asarray(toks), jnp.asarray(pos)
                )
            else:
                logits, self.caches = self._decode_jit(
                    self.params, jnp.asarray(toks), self.caches, jnp.asarray(pos)
                )
            nxt = int(np.argmax(np.asarray(logits[slot])))
            if nxt != req.tokens_out[t + 1]:
                raise RuntimeError(
                    f"recovery replay diverged at generated token {t} of slot "
                    f"{slot}: {nxt} != {req.tokens_out[t + 1]}"
                )

    def _degrade_to_mono(
        self, reason: str, lost_rows: Optional[List[int]] = None
    ) -> None:
        """Last resort: collapse the disaggregated executor onto the default
        device.  Surviving KV is exported; ``lost_rows`` (rows a dead shard
        destroyed) are zeroed and rebuilt by replay after the switch."""
        ex = self.disagg
        if self.faults is not None:
            self.faults.stats.degraded += 1
        if ex is None:
            return
        caches = ex.export_caches()
        if lost_rows:
            caches = zero_slots(caches, lost_rows)
        if self.kv_page_size is not None:
            # re-paginate the dense export: fresh page ids, same position→
            # value mapping, so replayed streams stay bit-identical
            lengths = np.array(ex.slot_lengths(), np.int64)
            if lost_rows:
                lengths[np.asarray(lost_rows)] = 0
            self.paged, caches = paginate_caches(
                caches, lengths, self.kv_page_size, self.kv_num_pages
            )
            if self.prefix_cache:
                # sharing dissolved with the shard pagers; restart a fresh
                # mono index over the re-paginated pool
                self.prefix = PrefixIndex(
                    self.prefill_worker.chunk, self.paged,
                    max_pages=self.prefix_cache_pages,
                )
        self.caches = jax.device_put(caches, jax.devices()[0])
        self.disagg = None
        self.executor_name = "mono"
        self.degraded_reason = reason
        # shard pagers died with the executor: spilled KV restores by replay
        self._invalidate_spills()
        if lost_rows:
            self._rebuild_lost_slots(lost_rows)

    def _guarded_decode(self, positions, spec=None) -> tuple:
        """One decode step with the fault envelope: transient exchange faults
        retry the (idempotent) step under exponential backoff; a spent retry
        budget or an unrecoverable fault degrades to mono; injected
        sub-deadline delays are charged to the clock."""
        if self.faults is None:
            return self._decode_once(positions, spec)
        attempt = 0
        while True:
            try:
                logits, tel = self._decode_once(positions, spec)
            except PoolFault as fault:
                if not fault.transient:
                    self._recover(fault)
                    continue
                attempt += 1
                self.faults.stats.retries += 1
                if attempt > self.faults.policy.max_retries:
                    self.faults.mark_handled(fault)
                    self._degrade_to_mono(f"retry budget exhausted: {fault}")
                    continue
                self._charge(self.faults.policy.delay(attempt))
                continue
            self._charge(self.faults.consume_delay())
            return logits, tel

    def _decode_once(self, positions, spec=None) -> tuple:
        if spec is not None:
            vtokens, widths = spec
            if self.disagg is not None:
                logits, tel = self.disagg.decode_step_verify(
                    vtokens, positions, widths
                )
                logits.block_until_ready()
                return logits, tel
            logits, self.caches = self._verify_jit(
                self.params, vtokens, self.caches, positions, widths
            )
            logits.block_until_ready()
            return logits, None
        if self.disagg is not None:
            logits, tel = self.disagg.decode_step(self.tokens, positions)
            logits.block_until_ready()
            return logits, tel
        logits, self.caches = self._decode_jit(
            self.params, self.tokens, self.caches, positions
        )
        logits.block_until_ready()
        return logits, None

    def _worker_poll(self) -> List[PrefillEvent]:
        """Poll the prefill worker under the fault envelope: transient chunk
        faults retry (the hook fires before any compute, so the chunk is
        untouched); a spent budget escalates to device loss on that device."""
        if self.faults is None:
            return self.prefill_worker.poll(self._chunk_sink)
        attempt = 0
        while True:
            try:
                return self.prefill_worker.poll(self._chunk_sink)
            except PoolFault as fault:
                if not fault.transient:
                    self._recover(fault)
                    continue
                attempt += 1
                self.faults.stats.retries += 1
                if attempt > self.faults.policy.max_retries:
                    self.faults.mark_handled(fault)
                    self._recover(
                        PoolFault(
                            "prefill", fault.index, DEVICE_LOSS,
                            transient=False,
                            detail="chunk retry budget exhausted",
                        )
                    )
                    attempt = 0
                    continue
                self._charge(self.faults.policy.delay(attempt))

    def _reject(self, req: Request) -> None:
        """Admission control: the request waited past its deadline while the
        engine was saturated — reject it without ever holding a slot."""
        req.rejected = True
        req.finished = self.clock
        self.rejected.append(req)

    def cancel_slot(self, slot: int) -> Optional[Request]:
        """Withdraw a reserved/prefilling request before activation: pull it
        from the prefill worker (or its finished-but-unactivated event),
        release the slot's pages — dropping any prefix-cache pins — and free
        the slot.  Returns the withdrawn request, or None if the slot holds
        nothing cancellable (free or already active)."""
        req = self.prefill_worker.cancel_slot(slot)
        if req is None:
            for ev in self._ready:
                if ev.slot == slot:
                    req = ev.req
            self._ready = [ev for ev in self._ready if ev.slot != slot]
        if req is None:
            held = self.slots.slot_req[slot]
            if held is not None and self.slots.state[slot] != ACTIVE:
                req = held
        if req is None:
            return None
        self._release_pages(slot)
        self.slots.release(slot)
        return req

    def _admission_open(self) -> bool:
        """Backpressure: stop admitting when the prefill queue is saturated."""
        if self.max_prefill_queue is None:
            return True
        return self.prefill_worker.num_pending < self.max_prefill_queue

    # ------------------------------------------------------------------
    # prefix cache (page-granular radix reuse)
    # ------------------------------------------------------------------
    def _prompt_tokens(self, req: Request) -> np.ndarray:
        """The request's prompt tokens, materialising the seeded synthetic
        prompt when none was given (same rng contract as the worker)."""
        if req.prompt is not None:
            return np.asarray(req.prompt, np.int32)
        rng = np.random.default_rng(req.rid)
        return rng.integers(0, self.cfg.vocab_size, size=req.input_len, dtype=np.int32)

    def _prefix_splice(self, req: Request, slot: int):
        """Serve the longest cached prefix of ``req``'s prompt into the
        freshly reserved ``slot``: shared pages are spliced into its block
        table (copy-on-write for a trailing partial page) and the matched KV
        rows are gathered for worker seeding.  Returns ``(start,
        seed_caches)`` for :meth:`PrefillWorker.submit` — ``(0, None)`` when
        the cache is off or misses.  The match is capped at the largest chunk
        boundary strictly below the prompt length so at least one token
        always prefills (activation needs first-token logits)."""
        if not self.prefix_cache:
            return 0, None
        tokens = self._prompt_tokens(req)
        chunk = self.prefill_worker.chunk
        limit = ((len(tokens) - 1) // chunk) * chunk
        if limit <= 0:
            return 0, None
        if self.disagg is not None:
            return self.disagg.splice_prefix(slot, tokens, limit)
        match, pages = self.prefix.lookup(tokens, limit)
        if not match:
            return 0, None
        cow = self.paged.splice(slot, pages, match)
        caches = dict(self.caches)
        if cow is not None:
            src, dst, rows = cow
            for k in PAGED_KEYS:
                if k in caches:
                    caches[k] = caches[k].at[:, dst, :rows].set(
                        caches[k][:, src, :rows]
                    )
        pgs, offs = self.paged.rows_of(slot, 0, match)
        seed = {k: caches[k][:, pgs, offs] for k in PAGED_KEYS if k in caches}
        caches["block_tables"] = self.paged.table_device()
        self.caches = caches
        return match, seed

    def _prefix_publish(self, req: Request, slot: int) -> None:
        """Index the chunk-aligned span of the prompt ``slot`` just finished
        prefilling (called at activation, when every row is written)."""
        if not self.prefix_cache:
            return
        tokens = self._prompt_tokens(req)
        chunk = self.prefill_worker.chunk
        upto = (len(tokens) // chunk) * chunk
        if upto <= 0:
            return
        if self.disagg is not None:
            self.disagg.publish_prefix(slot, tokens, upto)
        else:
            self.prefix.publish(tokens, upto, slot)

    # ------------------------------------------------------------------
    # priority scheduling: preemption via KV spill/restore
    # ------------------------------------------------------------------
    def _preempt_capable(self) -> bool:
        """Preemption needs paged KV — spill is a block-table detach, and a
        contiguous cache has no tables to detach."""
        if self.paged is not None:
            return True
        return self.disagg is not None and self.disagg._pagers is not None

    def _find_slot(self, shard: Optional[int]) -> Optional[int]:
        """Lowest free slot, restricted to one disagg shard when a spilled
        record must re-attach where its pages live."""
        free = self.slots.free_slots
        if shard is None or self.disagg is None:
            return free[0] if free else None
        for s in free:
            if self.disagg.shard_of(s) == shard:
                return s
        return None

    def _pick_victim(self, priority: int, shard: Optional[int]) -> Optional[int]:
        """The active slot to preempt for a priority-``priority`` candidate:
        strictly lower priority only (equal priority never preempts — that
        would thrash), preferring the least-generated victim (least work
        parked off-batch), slot index breaking ties deterministically."""
        best = None
        for s in self.slots.active_slots:
            if shard is not None and self.disagg is not None:
                if self.disagg.shard_of(s) != shard:
                    continue
            req = self.slots.slot_req[s]
            if req.priority >= priority:
                continue
            key = (req.priority, req.generated, s)
            if best is None or key < best[0]:
                best = (key, s)
        return None if best is None else best[1]

    def preempt_slot(self, slot: int) -> Request:
        """Preempt an ACTIVE slot: detach its KV pages into a spill record
        (block-table move, zero copy — prefix-cache pins ride along via
        their refcounts) and free the slot.  The request keeps its stream
        state (``tokens_out``, ``generated``) and resumes bit-identically
        when a slot frees up or its priority wins one back."""
        if self.slots.state[slot] != ACTIVE:
            raise RuntimeError(
                f"slot {slot} is {self.slots.state[slot]}, cannot preempt"
            )
        if self.paged is not None:
            payload, shard = self.paged.spill(slot), None
        elif self.disagg is not None and self.disagg._pagers is not None:
            payload, shard = self.disagg.spill_slot(slot)
        else:
            raise RuntimeError("preemption requires paged KV (set kv_page_size)")
        req = self.slots.release(slot)
        # slot's pages moved to the record, so the usual free-on-release is
        # a no-op — but the call keeps release paths uniform (and drops
        # nothing because spill already emptied the ownership list)
        self._release_pages(slot)
        req.preemptions += 1
        self._spilled.append(
            _SpillRecord(
                req=req, payload=payload, shard=shard, spilled_at=self.clock
            )
        )
        self.preempt_count += 1
        self._draft_stream.pop(slot, None)
        return req

    def _restore_record(self, rec: _SpillRecord, slot: int) -> None:
        """Re-admit a spilled request into free ``slot``: re-attach its
        pages (or rebuild them by deterministic replay when a re-shard
        dissolved the pool they lived in) and resume decode at
        ``input_len + generated`` with the last emitted token as input."""
        req = rec.req
        self.slots.reserve(req, slot=slot)
        if rec.payload is None:
            self.slots.resume(slot)
            self._replay_slot(slot)
            self.spill_replay_count += 1
        else:
            if self.paged is not None:
                self.paged.restore(slot, rec.payload)
            else:
                self.disagg.restore_slot(slot, rec.payload)
            self.slots.resume(slot)
        self.tokens = self.tokens.at[slot, 0].set(req.tokens_out[-1])
        # the park time between two of the request's tokens is scheduling
        # wait, not decode latency — record it so TPOT can split it out
        if req.wait_spans is None:
            req.wait_spans = []
        req.wait_spans.append((rec.spilled_at, self.clock))
        self.restore_count += 1

    def _drop_spill(self, rec: _SpillRecord) -> None:
        """Abandon a spill record (deadline lapsed): free its pages."""
        if rec.payload is None:
            return
        if self.paged is not None:
            self.paged.drop_spilled(rec.payload)
        elif self.disagg is not None:
            self.disagg.drop_spilled(rec.payload)

    def _invalidate_spills(self) -> None:
        """An attention re-shard (device loss, reconfigure, degrade) rebuilt
        the page pools from slot-owned pages — detached spill payloads
        dissolved with the old pools.  Downgrade every record to
        restore-by-replay (bit-exact by construction, like fault replay)."""
        for rec in self._spilled:
            rec.payload = None
            rec.shard = None

    def _schedule_admission(self, waiting: List[Request]) -> List[Request]:
        """Place arrived work into slots.  ``sched="fifo"`` is the legacy
        strict-arrival-order loop.  ``sched="priority"`` merges spilled
        (restorable) and new requests into one candidate order — priority
        first, restores before fresh admits on ties, then arrival — and,
        when no slot is free, spills the lowest-priority active slot for a
        strictly higher-priority candidate."""
        if self.sched == "fifo":
            while (
                waiting
                and waiting[0].arrival <= self.clock
                and self.slots.free_slots
                and self._admission_open()
            ):
                req = waiting.pop(0)
                if self.admission == "pipelined":
                    self._submit_request(req)
                else:
                    self._prefill_request(req)
            return waiting
        while True:
            cands: List[tuple] = []
            for rec in self._spilled:
                cands.append(
                    (-rec.req.priority, 0, rec.req.arrival, rec.req.rid, rec)
                )
            for r in waiting:
                if r.arrival <= self.clock:
                    cands.append((-r.priority, 1, r.arrival, r.rid, r))
            cands.sort(key=lambda c: c[:4])
            progressed = False
            for key in cands:
                item = key[-1]
                is_restore = isinstance(item, _SpillRecord)
                # restores bypass prefill backpressure: they need no prefill
                if not is_restore and not self._admission_open():
                    continue
                shard = item.shard if is_restore else None
                slot = self._find_slot(shard)
                if slot is None and self._preempt_capable():
                    prio = item.req.priority if is_restore else item.priority
                    victim = self._pick_victim(prio, shard)
                    if victim is not None:
                        self.preempt_slot(victim)
                        slot = self._find_slot(shard)
                if slot is None:
                    continue
                if is_restore:
                    self._spilled.remove(item)
                    self._restore_record(item, slot)
                else:
                    waiting.remove(item)
                    if self.admission == "pipelined":
                        self._submit_request(item)
                    else:
                        self._prefill_request(item)
                progressed = True
                break
            if not progressed:
                return waiting

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _prefill_request(self, req: Request) -> None:
        """Blocking admission: drain the prefill worker synchronously for this
        one request — the legacy schedule (the decode loop stalls for the
        whole prompt), with the same chunked compute as the pipelined path."""
        stalled = self.slots.num_active > 0
        slot = self.slots.reserve(req)
        self.slots.start_prefill(slot)
        now = max(self.clock, req.arrival)
        start, seed = self._prefix_splice(req, slot)
        self.prefill_worker.submit(req, slot, now=now, start=start, seed_caches=seed)
        events: List[PrefillEvent] = []
        while not events:
            events = self._worker_poll()
        ev = events[0]
        # legacy clock semantics: modeled prefill time when calibrated, wall
        # otherwise (zero under a modeled decode clock with no prefill model —
        # the worker's time_fn is already pinned to 0 for that combination)
        dt = ev.finish_t - now
        self.slots.activate(slot)
        self.tokens = self.tokens.at[slot, 0].set(ev.first_token)
        self.clock += dt
        if stalled:
            self.decode_stall_time += dt
        req.prefill_done = self.clock
        req.token_times.append(self.clock)
        req.tokens_out = [ev.first_token]
        self._prefix_publish(req, slot)

    def _submit_request(self, req: Request) -> None:
        """Pipelined admission: reserve the slot, queue the prompt for the
        prefill pool — the decode clock is never charged."""
        slot = self.slots.reserve(req)
        self.slots.start_prefill(slot)
        start, seed = self._prefix_splice(req, slot)
        self.prefill_worker.submit(
            req, slot, now=max(self.clock, req.arrival),
            start=start, seed_caches=seed,
        )

    def _chunk_sink(self, slot: int, start: int, length: int, one_caches: Dict) -> None:
        """Land one streamed prefill chunk (or a whole-prompt fallback cache,
        ``length == -1``) in the decode-side caches."""
        if self.disagg is not None:
            if length < 0:
                self.disagg.scatter_prefill(one_caches, slot)
            else:
                self.disagg.scatter_prefill_chunk(one_caches, slot, start, length)
        elif self.paged is not None:
            if length < 0:
                # whole-prompt fallback: the prompt's rows are one big chunk;
                # positionless state (ssm/enc_out) takes the contiguous path
                start, length = 0, self.slots.slot_req[slot].input_len
                rest = {
                    k: v for k, v in one_caches.items() if not k.startswith("kv_")
                }
                if rest:
                    self.caches = scatter_prefill_caches(self.caches, rest, slot)
            self.caches = scatter_prefill_chunk_paged(
                self.caches, one_caches, slot, start, length, self.paged
            )
        elif length < 0:
            self.caches = scatter_prefill_caches(self.caches, one_caches, slot)
        else:
            self.caches = scatter_prefill_chunk_caches(
                self.caches, one_caches, slot, start, length
            )

    # ------------------------------------------------------------------
    # paged-KV slot lifecycle
    # ------------------------------------------------------------------
    def _ensure_pages(self, widths=None) -> None:
        """Back every active slot's next write position with a page (alloc on
        append) and refresh the device block table if anything changed.  A
        speculative verify writes ``widths[s]`` rows starting at the slot's
        position, so its whole candidate span is backed up front."""
        if self.paged is not None:
            for s in self.slots.active_slots:
                extent = int(widths[s]) - 1 if widths is not None else 0
                self.paged.ensure(s, int(self.slots.positions[s]) + extent)
            if self.paged.dirty:
                self.caches = dict(self.caches)
                self.caches["block_tables"] = self.paged.table_device()
        elif self.disagg is not None:
            for s in self.slots.active_slots:
                extent = int(widths[s]) - 1 if widths is not None else 0
                self.disagg.ensure_slot_pages(
                    s, int(self.slots.positions[s]) + extent
                )

    def _ensure_slot_page(self, slot: int, pos: int) -> None:
        """Replay-path variant of :meth:`_ensure_pages` for a single slot."""
        if self.paged is not None:
            self.paged.ensure(slot, pos)
            if self.paged.dirty:
                self.caches = dict(self.caches)
                self.caches["block_tables"] = self.paged.table_device()
        elif self.disagg is not None:
            self.disagg.ensure_slot_pages(slot, pos)

    def _release_pages(self, slot: int) -> None:
        """Free a released slot's pages (free-on-release)."""
        if self.paged is not None:
            self.paged.release(slot)
        elif self.disagg is not None:
            self.disagg.release_slot(slot)

    def _poll_prefill(self) -> None:
        """Advance the prefill pipeline and activate any finished requests
        whose completion stamp the decode clock has passed."""
        self._ready.extend(self._worker_poll())
        still: List[PrefillEvent] = []
        for ev in self._ready:
            if ev.finish_t <= self.clock:
                self.slots.activate(ev.slot)
                self.tokens = self.tokens.at[ev.slot, 0].set(ev.first_token)
                ev.req.prefill_done = ev.finish_t
                ev.req.token_times.append(ev.finish_t)
                ev.req.tokens_out = [ev.first_token]
                self._prefix_publish(ev.req, ev.slot)
            else:
                still.append(ev)
        self._ready = still

    def _prefill_pending(self) -> int:
        return self.prefill_worker.num_pending + len(self._ready)

    # ------------------------------------------------------------------
    # speculative decode: draft → batched verify → greedy acceptance
    # ------------------------------------------------------------------
    def _draft_ensure(self, slot: int) -> None:
        """Make the draft cache mirror ``slot``'s true token stream up to its
        decode position.  Fresh activations, restores into a new slot, and
        slot reuse all land here and rebuild by whole-history draft prefill;
        a slot that advanced through speculation rounds is already covered.
        The rebuild need not be numerically identical to the incremental
        path — emitted tokens never depend on draft numerics, only the
        acceptance rate does."""
        req = self.slots.slot_req[slot]
        pos = int(self.slots.positions[slot])
        rid, have = self._draft_stream.get(slot, (None, -1))
        if rid == req.rid and have >= pos:
            return
        history = self._prompt_tokens(req)
        if req.generated:
            history = np.concatenate(
                [history, np.asarray(req.tokens_out[:-1], np.int32)]
            )
        _, one = self._draft_prefill_jit(
            self._draft_params, jnp.asarray(history[None, :])
        )
        self._draft_caches = scatter_prefill_caches(self._draft_caches, one, slot)
        self._draft_stream[slot] = (req.rid, pos)

    def _spec_widths(self) -> np.ndarray:
        """Per-slot verify width: ``spec_k + 1`` rows (last accepted token +
        drafts), clamped so a slot never scores past its remaining output
        budget or the cache rows non-speculative decode could have written
        (positions ≤ cache_len - 3 before the truncation check)."""
        c = self.spec_k + 1
        widths = np.zeros(self.max_batch, np.int32)
        for s in self.slots.active_slots:
            req = self.slots.slot_req[s]
            pos = int(self.slots.positions[s])
            w = min(c, req.output_len - req.generated, self.cache_len - 2 - pos)
            widths[s] = max(1, w)
        return widths

    def _spec_iteration(self) -> None:
        """One speculative decode iteration: k + 1 draft forwards (the extra
        one keeps the draft cache exactly one token behind the feed so a
        fully accepted round never leaves a stale row), one batched verify,
        then per-slot greedy acceptance.  Each slot gains between 1 and
        ``spec_k + 1`` tokens; rejected rows are left beyond the advanced
        position where the decode mask never reads them, and the paged
        high-water mark is truncated back to honesty."""
        if self.faults is not None:
            self._fault_preflight()
        active = list(self.slots.active_slots)
        widths = self._spec_widths()
        self._ensure_pages(widths)
        for s in active:
            self._draft_ensure(s)
        t0 = time.perf_counter()
        c = self.spec_k + 1
        drafts = np.zeros((self.max_batch, self.spec_k), np.int32)
        feed = self.tokens
        for j in range(c):
            dpos = jnp.asarray(
                np.minimum(self.slots.positions + j, self.cache_len - 1)
            )
            dlogits, self._draft_caches = self._draft_decode_jit(
                self._draft_params, feed, self._draft_caches, dpos
            )
            if j < self.spec_k:
                nxt = np.asarray(jnp.argmax(dlogits, axis=-1), np.int32)
                drafts[:, j] = nxt
                feed = jnp.asarray(nxt[:, None])
        vtokens = np.zeros((self.max_batch, c), np.int32)
        vtokens[:, 0] = np.asarray(self.tokens[:, 0])
        vtokens[:, 1:] = drafts
        positions = self.slots.positions_device()
        logits, tel = self._guarded_decode(
            positions, spec=(jnp.asarray(vtokens), jnp.asarray(widths))
        )
        if tel is not None:
            self.regime_log.append(tel["regime"])
            self.transfer_bytes_log.append(tel["bytes_total"])
            self.amax_log.append(tel["a_max"])
        wall = time.perf_counter() - t0
        self.clock += (
            self.step_time_fn(self.slots.num_active) if self.step_time_fn else wall
        )
        self.steps_done += 1
        self.spec_steps += 1

        greedy = np.asarray(jnp.argmax(logits, axis=-1), np.int32)  # [b, c]
        new = self.tokens
        for s in active:
            if self.slots.state[s] != ACTIVE:
                continue  # released by a recovery path mid-iteration
            req = self.slots.slot_req[s]
            w = int(widths[s])
            a = 0
            while a < w - 1 and drafts[s, a] == greedy[s, a]:
                a += 1
            gained = a + 1
            self.spec_slot_steps += 1
            self.spec_draft_tokens += w - 1
            self.spec_draft_accepted += a
            self.spec_emitted_tokens += gained
            for j in range(gained):
                req.generated += 1
                req.token_times.append(self.clock)
                self.slots.advance(s)
                if req.tokens_out is not None:
                    req.tokens_out.append(int(greedy[s, j]))
            new = new.at[s, 0].set(int(greedy[s, a]))
            pos = int(self.slots.positions[s])
            # verify backed w rows but only `gained` advanced: clamp the
            # high-water mark so spill records and occupancy stay honest
            if self.paged is not None:
                self.paged.truncate(s, pos)
            elif self.disagg is not None:
                self.disagg.truncate_slot(s, pos)
            self._draft_stream[s] = (req.rid, pos)
            if req.generated >= req.output_len or pos >= self.cache_len - 2:
                if req.generated < req.output_len:
                    req.truncated = True  # context exhausted before target
                req.finished = self.clock
                self.completed.append(self.slots.release(s))
                self._release_pages(s)
                self._draft_stream.pop(s, None)
        self.tokens = new

    # ------------------------------------------------------------------
    def _decode_iteration(self) -> None:
        if self.spec_k:
            self._spec_iteration()
            return
        if self.faults is not None:
            self._fault_preflight()
        self._ensure_pages()
        positions = self.slots.positions_device()
        t0 = time.perf_counter()
        logits, tel = self._guarded_decode(positions)
        if tel is not None:
            self.regime_log.append(tel["regime"])
            self.transfer_bytes_log.append(tel["bytes_total"])
            self.amax_log.append(tel["a_max"])
        wall = time.perf_counter() - t0
        self.clock += self.step_time_fn(self.slots.num_active) if self.step_time_fn else wall
        self.steps_done += 1

        next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        new = self.tokens
        for s in self.slots.active_slots:
            req = self.slots.slot_req[s]
            req.generated += 1
            req.token_times.append(self.clock)
            self.slots.advance(s)
            new = new.at[s, 0].set(int(next_tokens[s]))
            if req.tokens_out is not None:
                req.tokens_out.append(int(next_tokens[s]))
            if req.generated >= req.output_len or self.slots.positions[s] >= self.cache_len - 2:
                if req.generated < req.output_len:
                    req.truncated = True  # context exhausted before target length
                req.finished = self.clock
                self.completed.append(self.slots.release(s))
                self._release_pages(s)
        self.tokens = new

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_steps: int = 100_000) -> Dict:
        """Serve all requests (arrivals gated by the engine clock)."""
        waiting = sorted(requests, key=lambda r: r.arrival)
        steps = 0
        while (
            waiting or self._spilled or self.slots.num_active or self._prefill_pending()
        ) and steps < max_steps:
            # admission control: reject arrived requests whose deadline lapsed
            # while the engine was saturated (they never held a slot)
            if any(r.deadline is not None for r in waiting):
                still_waiting: List[Request] = []
                for r in waiting:
                    if (
                        r.deadline is not None
                        and r.arrival <= self.clock
                        and self.clock > r.deadline
                    ):
                        self._reject(r)
                    else:
                        still_waiting.append(r)
                waiting = still_waiting
            # a reserved/prefilling request whose deadline lapsed mid-queue is
            # cancelled: its slot and pages (including prefix pins) return to
            # the pool instead of finishing a prompt nobody will wait for
            for slot in self.slots.pending_slots:
                req = self.slots.slot_req[slot]
                if (
                    req is not None
                    and req.deadline is not None
                    and self.clock > req.deadline
                ):
                    if self.cancel_slot(slot) is not None:
                        self._reject(req)
            # a spilled (preempted) request whose deadline lapsed off-batch
            # is dropped: its detached pages return to the pool
            for rec in list(self._spilled):
                if rec.req.deadline is not None and self.clock > rec.req.deadline:
                    self._spilled.remove(rec)
                    self._drop_spill(rec)
                    self._reject(rec.req)
            # admit arrived requests into slots (fifo or priority/preemptive)
            waiting = self._schedule_admission(waiting)
            self._poll_prefill()
            if self.slots.num_active == 0:
                if self._ready:  # idle: jump to the next prefill completion
                    self.clock = max(self.clock, min(ev.finish_t for ev in self._ready))
                    continue
                if self._prefill_pending():  # chunks still streaming: keep polling
                    continue
                if waiting:  # idle: jump to next arrival
                    self.clock = max(self.clock, waiting[0].arrival)
                    continue
                break
            self._decode_iteration()
            steps += 1
        return self.metrics()

    # ------------------------------------------------------------------
    def reconfigure(
        self,
        n_attn: Optional[int] = None,
        n_moe: Optional[int] = None,
        layout: Optional[ReplicaLayout] = None,
        n_prefill: Optional[int] = None,
    ) -> Dict[str, bool]:
        """Actuate a scaling decision mid-run (§3.5): only the pools whose
        counts changed are re-lowered; in-flight KV caches are preserved and
        in-progress chunked prefills migrate with the prefill pool.
        Disagg executor only — the monolithic engine re-lowers wholesale."""
        if self.disagg is None:
            raise NotImplementedError(
                "mid-run reconfigure requires executor='disagg' (the monolithic "
                "engine re-lowers wholesale — rebuild the engine instead)"
            )
        relower = self.disagg.reconfigure(
            n_attn=n_attn, n_moe=n_moe, layout=layout, n_prefill=n_prefill
        )
        if relower.get("attn"):
            # attention re-shard rebuilt the page pools: detached spill
            # payloads dissolved — downgrade them to restore-by-replay
            self._invalidate_spills()
        self.layout = self.disagg.layout
        if relower.get("prefill"):
            self.prefill_worker.set_devices(
                self.disagg.pools.prefill_devices, self.params
            )
        return relower

    # ------------------------------------------------------------------
    def metrics(self) -> Dict:
        done = self.completed
        out: Dict = {"completed": len(done), "tokens": sum(r.generated for r in done)}
        out["truncated"] = sum(1 for r in done if r.truncated)
        out["rejected"] = len(self.rejected)
        out["preemptions"] = self.preempt_count
        out["restores"] = self.restore_count
        if self.spill_replay_count:
            out["spill_replays"] = self.spill_replay_count
        # SLO attainment over every *measured* request (one that carries a
        # TTFT or TPOT target): rejected/unserved requests count as misses,
        # so shedding load can never inflate attainment
        measured = [
            r for r in done + self.rejected if r.slo_ok() is not None
        ]
        if measured:
            per_tenant: Dict[str, List[bool]] = {}
            for r in measured:
                per_tenant.setdefault(r.tenant, []).append(bool(r.slo_ok()))
            out["slo"] = {
                "measured": len(measured),
                "attained": sum(1 for r in measured if r.slo_ok()),
                "attainment": sum(1 for r in measured if r.slo_ok()) / len(measured),
                "per_tenant": {
                    t: sum(v) / len(v) for t, v in sorted(per_tenant.items())
                },
            }
        out["decode_stall_time"] = self.decode_stall_time
        out["prefill_chunks"] = self.prefill_worker.chunks_done
        if self.spec_k:
            out["spec"] = {
                "k": self.spec_k,
                "steps": self.spec_steps,
                "draft_tokens": self.spec_draft_tokens,
                "accepted_draft_tokens": self.spec_draft_accepted,
                "emitted_tokens": self.spec_emitted_tokens,
                "accepted_per_step": (
                    self.spec_emitted_tokens / self.spec_slot_steps
                    if self.spec_slot_steps
                    else 0.0
                ),
                "acceptance_rate": (
                    self.spec_draft_accepted / self.spec_draft_tokens
                    if self.spec_draft_tokens
                    else 0.0
                ),
            }
        if self.paged is not None:
            out["kv_pages"] = self.paged.stats()
        elif self.disagg is not None:
            page_stats = self.disagg.page_stats()
            if page_stats is not None:
                out["kv_pages"] = page_stats
        if self.prefix is not None:
            out["prefix_cache"] = self.prefix.stats()
        elif self.disagg is not None:
            prefix_stats = self.disagg.prefix_stats()
            if prefix_stats is not None:
                out["prefix_cache"] = prefix_stats
        if self.faults is not None:
            out["faults"] = self.faults.stats.as_dict()
            if self.degraded_reason is not None:
                out["degraded_reason"] = self.degraded_reason
        # disaggregated-exchange telemetry (satellite of amax_log): which
        # two-phase regime served each step, and the bytes it moved
        if self.regime_log:
            out["regime_counts"] = {
                r: self.regime_log.count(r) for r in sorted(set(self.regime_log))
            }
            out["transfer_bytes_total"] = int(sum(self.transfer_bytes_log))
            out["transfer_bytes_per_step"] = float(
                np.mean(self.transfer_bytes_log)
            )
        if self.amax_log:
            out["amax_mean"] = float(np.mean(self.amax_log))
            out["amax_max"] = int(np.max(self.amax_log))
        if not done:
            return out
        # TTFT: prompt turnaround (arrival → first token) — the metric the
        # prefill pool exists to improve; TPOT alone can't see prefill wins
        ttfts = np.array([r.prefill_done - r.arrival for r in done if r.prefill_done >= 0])
        if len(ttfts):
            out["ttft_mean"] = float(ttfts.mean())
            out["ttft_p99"] = float(np.percentile(ttfts, 99))
        gaps = np.concatenate(
            [r.decode_gaps() for r in done if len(r.token_times) > 1]
        )
        span = max(r.finished for r in done) - min(r.arrival for r in done)
        out.update(
            throughput_tok_s=out["tokens"] / max(span, 1e-9),
            tpot_mean=float(gaps.mean()) if len(gaps) else 0.0,
            tpot_p99=float(np.percentile(gaps, 99)) if len(gaps) else 0.0,
            clock=self.clock,
        )
        return out
