"""Slot-based batched cache manager for continuous-batching decode.

The engine holds model caches with a fixed ``max_batch`` of request slots
(batch axis 1 of every cache array).  The manager tracks slot occupancy and
per-slot positions; a freed slot is immediately reusable because attention
masks are position-bounded per request.

Inactive slots park their write position at ``cache_len - 1`` (a reserved
scratch entry no live context may reach), so the batched decode step can run
unconditionally without corrupting live entries.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass
class SlotManager:
    max_batch: int
    cache_len: int

    def __post_init__(self):
        self.slot_req: List[Optional[Request]] = [None] * self.max_batch
        self.positions = np.full(self.max_batch, self.cache_len - 1, np.int32)

    @property
    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    @property
    def active_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is not None]

    @property
    def num_active(self) -> int:
        return len(self.active_slots)

    def admit(self, req: Request) -> int:
        free = self.free_slots
        if not free:
            raise RuntimeError("no free slot")
        s = free[0]
        self.slot_req[s] = req
        req.slot = s
        self.positions[s] = req.input_len
        return s

    def advance(self, slot: int) -> None:
        self.positions[slot] += 1

    def release(self, slot: int) -> Request:
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        self.positions[slot] = self.cache_len - 1
        return req

    def positions_device(self) -> jax.Array:
        return jnp.asarray(self.positions)

    def active_mask(self) -> np.ndarray:
        return np.array([r is not None for r in self.slot_req])


def scatter_prefill_caches(
    batch_caches: Dict[str, jax.Array],
    one_caches: Dict[str, jax.Array],
    slot: int,
) -> Dict[str, jax.Array]:
    """Write a single-request prefill cache (batch dim 1) into slot ``slot``
    of the batched caches.  Batch axis is 1 for stacked caches, 0 for
    ``enc_out``."""
    out = dict(batch_caches)
    for k, v in one_caches.items():
        if k == "enc_out":
            out[k] = batch_caches[k].at[slot].set(v[0])
        else:
            out[k] = batch_caches[k].at[:, slot].set(v[:, 0])
    return out
