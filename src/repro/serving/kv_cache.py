"""Slot-based batched cache manager for continuous-batching decode.

The engine holds model caches with a fixed ``max_batch`` of request slots
(batch axis 1 of every cache array).  The manager tracks slot occupancy and
per-slot positions; a freed slot is immediately reusable because attention
masks are position-bounded per request.

Slot lifecycle (pipelined admission, prefill-pool disaggregation)::

    FREE ──reserve──▶ RESERVED ──start_prefill──▶ PREFILLING ──activate──▶ ACTIVE
      ▲                              ▲                 │                     │
      │                              │fail + requeue   │fail                 │
      │                         REQUEUED ◀──requeue── FAILED                 │
      └────────────────────────────── release ◀──────────────────────────────┘

``admit`` is the legacy blocking path: FREE → ACTIVE in one call.  Reserved
and prefilling slots are *owned* (not free) but not decoded: the decode loop
only batches ACTIVE slots, so a request whose prompt is still streaming in
chunk-by-chunk never corrupts (or stalls) the in-flight batch.

``FAILED``/``REQUEUED`` are the fault-recovery detour: a prefill-worker
failure (or a lost attention shard mid-prefill) marks the slot FAILED, the
engine requeues the request, and prefill restarts from chunk 0 — chunked
prefill is deterministic, so the restarted request emits the same tokens it
would have without the fault.

Inactive slots park their write position at ``cache_len - 1`` (a reserved
scratch entry no live context may reach), so the batched decode step can run
unconditionally without corrupting live entries.

Paged KV storage (PagedAttention-style block indirection)
---------------------------------------------------------

The full-attention decode caches (``kv_k``/``kv_v`` and their int8 scales)
can optionally be stored *page-indirectly* instead of as contiguous
``[slots, cache_len]`` slabs: a shared pool of fixed-size pages
(``[num_pages, page_size, ...]``) plus a per-slot **block table**
(``[max_batch, cache_len // page_size]`` int32) mapping each slot's
position block to a pool page.  Pages are allocated on append (prefill
chunk / decode write) and freed on slot release, so resident KV memory
tracks *live* context instead of ``slots × cache_len`` — the stranded-
memory recovery that lets the attention pool host several times more
concurrent slots at the same budget.

Page 0 is the reserved **null page**: unallocated block-table entries point
at it, and the parked scratch write of inactive slots lands in it.  Rows
read through the null page (or through a page's unwritten tail) are always
masked by the position-bounded attention mask, so paged and contiguous
layouts are bit-identical for every live stream.

:class:`PageAllocator` owns the free list; :class:`PagedKVCache` owns the
block tables and the slot lifecycle (``ensure``/``release``), plus the
dense↔paged conversion used by reconfigure/degrade migration.  Rolling-
window (``_local``), hybrid and recurrent caches stay contiguous — their
buffers are already bounded by the window/state size.

Prefix cache (page-granular radix reuse)
----------------------------------------

Pages are refcounted, so a page can back several block tables at once:
:class:`PrefixIndex` is a radix/trie over chunk-aligned hashes of prompt
token prefixes whose nodes pin (refcount) the pages holding that chunk's
KV rows.  Serving a hit is pure block-table surgery —
:meth:`PagedKVCache.splice` maps the matched positions of a fresh slot
onto the shared pages (copy-on-write for a trailing partial page), so a
warm prefix costs zero recompute and zero KV copy.  Because one block
table serves every layer of the pool arrays (`[L, num_pages, ...]`), a
page run shares all layers' rows at once.  Shared pages return to the
free list only when the last holder (slot *or* index node) drops them;
eviction is LRU over unpinned leaf nodes under a page budget.
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.request import Request

FREE = "free"
RESERVED = "reserved"
PREFILLING = "prefilling"
ACTIVE = "active"
FAILED = "failed"  # prefill lost to a fault; awaiting requeue
REQUEUED = "requeued"  # re-admitted to the prefill queue after a fault


@dataclasses.dataclass
class SlotManager:
    max_batch: int
    cache_len: int

    def __post_init__(self):
        self.slot_req: List[Optional[Request]] = [None] * self.max_batch
        self.state: List[str] = [FREE] * self.max_batch
        self.positions = np.full(self.max_batch, self.cache_len - 1, np.int32)

    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.state) if s == FREE]

    @property
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.state) if s == ACTIVE]

    @property
    def pending_slots(self) -> List[int]:
        """Slots owned by a request whose prefill has not finished."""
        return [
            i
            for i, s in enumerate(self.state)
            if s in (RESERVED, PREFILLING, FAILED, REQUEUED)
        ]

    @property
    def num_active(self) -> int:
        return len(self.active_slots)

    # -- legacy blocking admission: FREE → ACTIVE in one call ----------------
    def admit(self, req: Request) -> int:
        s = self.reserve(req)
        self.activate(s)
        return s

    # -- pipelined admission -------------------------------------------------
    def reserve(self, req: Request, slot: Optional[int] = None) -> int:
        """Reserve the lowest free slot, or a specific free ``slot`` (the
        spill/restore path needs shard affinity on disagg executors)."""
        free = self.free_slots
        if not free:
            raise RuntimeError("no free slot")
        if slot is None:
            s = free[0]
        elif slot in free:
            s = slot
        else:
            raise RuntimeError(f"slot {slot} is {self.state[slot]}, not free")
        self.slot_req[s] = req
        self.state[s] = RESERVED
        req.slot = s
        return s

    def start_prefill(self, slot: int) -> None:
        if self.state[slot] not in (RESERVED, REQUEUED):
            raise RuntimeError(
                f"slot {slot} is {self.state[slot]}, expected {RESERVED} or {REQUEUED}"
            )
        self.state[slot] = PREFILLING

    # -- fault-recovery detour: prefilling → failed → requeued → prefilling --
    def fail(self, slot: int) -> None:
        """Mark a slot whose in-flight prefill was lost to a fault."""
        if self.state[slot] not in (RESERVED, PREFILLING):
            raise RuntimeError(f"slot {slot} is {self.state[slot]}, cannot fail")
        self.state[slot] = FAILED

    def requeue(self, slot: int) -> None:
        """Hand a failed slot back to the prefill queue (restart at chunk 0)."""
        if self.state[slot] != FAILED:
            raise RuntimeError(f"slot {slot} is {self.state[slot]}, expected {FAILED}")
        self.state[slot] = REQUEUED

    def activate(self, slot: int) -> None:
        if self.state[slot] not in (RESERVED, PREFILLING):
            raise RuntimeError(f"slot {slot} is {self.state[slot]}, cannot activate")
        self.state[slot] = ACTIVE
        self.positions[slot] = self.slot_req[slot].input_len

    def resume(self, slot: int) -> None:
        """RESERVED → ACTIVE at the request's restored decode position.

        The re-admission half of preemption: unlike ``activate`` (which
        starts decode right after prefill, at ``input_len``), a resumed
        request continues from wherever the spill interrupted it —
        ``input_len + generated`` rows of KV are live again."""
        if self.state[slot] != RESERVED:
            raise RuntimeError(f"slot {slot} is {self.state[slot]}, cannot resume")
        req = self.slot_req[slot]
        self.state[slot] = ACTIVE
        self.positions[slot] = req.input_len + req.generated

    def advance(self, slot: int) -> None:
        self.positions[slot] += 1

    def release(self, slot: int) -> Request:
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        self.state[slot] = FREE
        self.positions[slot] = self.cache_len - 1
        return req

    def positions_device(self) -> jax.Array:
        return jnp.asarray(self.positions)

    def active_mask(self) -> np.ndarray:
        return np.array([s == ACTIVE for s in self.state])


def scatter_prefill_caches(
    batch_caches: Dict[str, jax.Array],
    one_caches: Dict[str, jax.Array],
    slot: int,
) -> Dict[str, jax.Array]:
    """Write a single-request prefill cache (batch dim 1) into slot ``slot``
    of the batched caches.  Batch axis is 1 for stacked caches, 0 for
    ``enc_out``."""
    out = dict(batch_caches)
    for k, v in one_caches.items():
        if k == "enc_out":
            out[k] = batch_caches[k].at[slot].set(v[0])
        else:
            out[k] = batch_caches[k].at[:, slot].set(v[:, 0])
    return out


def zero_slots(
    batch_caches: Dict[str, jax.Array],
    slots: List[int],
    paged: Optional["PagedKVCache"] = None,
) -> Dict[str, jax.Array]:
    """Destroy the KV rows of ``slots`` (batch axis 1; ``enc_out`` axis 0).

    Fault-recovery helper: when an attention shard dies, the slots it hosted
    are *actually* zeroed before re-sharding, so recovery tests prove the
    deterministic re-prefill replay rebuilt the state rather than silently
    reading rows a real failure would have destroyed.

    With a ``paged`` manager, the page-pool caches (:data:`PAGED_KEYS`) zero
    the *pages owned by* those slots instead of batch rows — same observable
    destruction, block-indirect layout."""
    if not slots:
        return batch_caches
    idx = np.asarray(slots)
    out = dict(batch_caches)
    for k, v in batch_caches.items():
        if k == "block_tables":
            continue  # the mapping survives; its pages' contents are wiped
        if paged is not None and k in PAGED_KEYS:
            pages = paged.pages_of(slots)
            if len(pages):
                out[k] = v.at[:, pages].set(0)
        elif k == "enc_out":
            out[k] = v.at[idx].set(0)
        else:
            out[k] = v.at[:, idx].set(0)
    return out


def chunk_rows(cache_len: int, start: int, length: int) -> np.ndarray:
    """Position-axis rows holding prompt positions ``[start, start+length)``
    in a cache of ``cache_len`` entries.  Contiguous ``start..start+length-1``
    for full-length caches; rolling-window caches (``cache_len`` < prompt)
    store position ``p`` at slot ``p % cache_len``, so rows wrap."""
    return (start + np.arange(length)) % cache_len


def scatter_prefill_chunk_caches(
    batch_caches: Dict[str, jax.Array],
    one_caches: Dict[str, jax.Array],
    slot: int,
    start: int,
    length: int,
) -> Dict[str, jax.Array]:
    """Stream one prefill chunk's KV slab into slot ``slot``: the rows
    holding prompt positions ``[start, start+length)`` of the per-request
    caches overwrite the same rows of the batched caches (per-cache
    :func:`chunk_rows` mapping — rolling-window caches wrap).  This is the
    per-chunk hand-off of the prefill→decode pipeline — position-indexed KV
    keys only (recurrent / encoder state has no position axis and moves with
    the *final* chunk via :func:`scatter_prefill_caches`)."""
    out = dict(batch_caches)
    for k, v in one_caches.items():
        if not k.startswith("kv_"):
            continue
        rows = chunk_rows(v.shape[2], start, length)  # [L, 1, S, ...] axis 2
        out[k] = batch_caches[k].at[:, slot, rows].set(
            v[:, 0, rows].astype(batch_caches[k].dtype)
        )
    return out


# ---------------------------------------------------------------------------
# Paged KV storage
# ---------------------------------------------------------------------------

# The cache keys stored page-indirectly: the full-attention ("" suffix) KV
# plus its int8 scales.  Rolling-window / hybrid / recurrent caches keep the
# contiguous per-slot layout (their buffers are window- or state-bounded).
PAGED_KEYS = ("kv_k", "kv_v", "kv_k_scale", "kv_v_scale")

NULL_PAGE = 0  # reserved: unallocated block-table entries point here


class PageAllocator:
    """Refcounted free-list allocator over pages ``1 .. num_pages-1`` (page 0
    is the reserved null page).  ``alloc()`` hands a page out at refcount 1;
    ``ref()`` lets another holder (a second block table, a prefix-index node)
    pin it, and ``free()`` decrements — the page returns to the free list
    only when the last holder drops it.  Tracks in-use and peak counts for
    telemetry and raises on exhaustion / double free so leaks surface
    loudly."""

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(f"need ≥ 2 pages (one null + one usable), got {num_pages}")
        self.num_pages = num_pages
        # pop() hands out low page ids first — keeps pools dense and makes
        # allocation order deterministic (replay/migration tests rely on it)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refs: Dict[int, int] = {}
        self.peak_in_use = 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._refs)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"out of KV pages ({self.num_pages - 1} allocatable, all in "
                "use) — raise kv_num_pages or lower the admitted batch"
            )
        p = self._free.pop()
        self._refs[p] = 1
        self.peak_in_use = max(self.peak_in_use, len(self._refs))
        return p

    def ref(self, page: int) -> int:
        """Pin an already-allocated page for an additional holder."""
        if page not in self._refs:
            raise RuntimeError(f"ref of unallocated page {page}")
        self._refs[page] += 1
        return page

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def free(self, page: int) -> None:
        if page not in self._refs:
            raise RuntimeError(f"double free / foreign page {page}")
        self._refs[page] -= 1
        if self._refs[page] == 0:
            del self._refs[page]
            self._free.append(page)


@dataclasses.dataclass
class SpilledKV:
    """A preempted slot's detached KV: its page list (block order) and the
    rows written.  The pages keep the refcounts the slot held — spilling is
    an ownership transfer, not a copy — so prefix-shared pages stay pinned
    by their other holders while the request waits off-batch."""

    pages: List[int]
    tokens: int


class PagedKVCache:
    """Block tables + page lifecycle for one batched paged cache pool.

    Host-side manager: numpy block tables ``[max_batch, blocks_per_slot]``
    (entry 0 = null page), a :class:`PageAllocator`, and per-slot high-water
    marks (rows written) for fragmentation accounting.  ``table_device()``
    returns a device copy, re-uploaded only when the tables changed."""

    def __init__(
        self,
        max_batch: int,
        cache_len: int,
        page_size: int,
        num_pages: Optional[int] = None,
    ):
        if page_size < 1:
            raise ValueError(f"page_size must be ≥ 1, got {page_size}")
        if cache_len % page_size:
            raise ValueError(
                f"cache_len ({cache_len}) must be a multiple of the KV page "
                f"size ({page_size}) so prefill chunks land on page boundaries"
            )
        self.max_batch = max_batch
        self.cache_len = cache_len
        self.page_size = page_size
        self.blocks_per_slot = cache_len // page_size
        if num_pages is None:
            num_pages = max_batch * self.blocks_per_slot + 1  # full backing
        self.num_pages = num_pages
        self.allocator = PageAllocator(num_pages)
        self.tables = np.full((max_batch, self.blocks_per_slot), NULL_PAGE, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(max_batch)]
        self.hiwater = np.zeros(max_batch, np.int64)  # rows written per slot
        self._dirty = True
        self._dev: Optional[jax.Array] = None
        self._dev_device = None

    # -- slot lifecycle ------------------------------------------------------
    def ensure(self, slot: int, upto_pos: int) -> bool:
        """Allocate pages so positions ``[0, upto_pos]`` of ``slot`` are
        backed.  Idempotent; returns True when the table changed."""
        if not 0 <= upto_pos < self.cache_len:
            raise ValueError(f"position {upto_pos} outside cache_len {self.cache_len}")
        need = upto_pos // self.page_size + 1
        owned = self._owned[slot]
        changed = False
        while len(owned) < need:
            page = self.allocator.alloc()
            self.tables[slot, len(owned)] = page
            owned.append(page)
            changed = True
        self.hiwater[slot] = max(self.hiwater[slot], upto_pos + 1)
        if changed:
            self._dirty = True
        return changed

    def truncate(self, slot: int, tokens: int) -> None:
        """Clamp ``slot``'s high-water mark down to ``tokens`` rows.

        Speculative verify writes k candidate rows before acceptance is
        known; rejected rows sit beyond the slot's advanced position, so the
        decode mask already excludes them and later legitimate writes
        overwrite them — truncation is pure bookkeeping honesty (occupancy
        stats, spill record sizing), not a physical rollback.  Pages are
        kept: the very next accepted token reuses them."""
        if tokens < 0:
            raise ValueError(f"cannot truncate slot {slot} to {tokens} tokens")
        self.hiwater[slot] = min(self.hiwater[slot], tokens)

    def release(self, slot: int) -> None:
        """Free every page of ``slot`` (alloc-on-append / free-on-release)."""
        for page in self._owned[slot]:
            self.allocator.free(page)
        if self._owned[slot]:
            self._dirty = True
        self._owned[slot] = []
        self.tables[slot, :] = NULL_PAGE
        self.hiwater[slot] = 0

    # -- preemption: spill / restore -----------------------------------------
    def spill(self, slot: int) -> "SpilledKV":
        """Detach ``slot``'s KV for preemption: the page list moves, in block
        order, from the slot's block table into a :class:`SpilledKV` record.

        No page data is touched and no refcount changes — ownership of the
        already-held references simply transfers to the record, so a page
        pinned by the prefix cache (or spliced into another slot) stays
        shared exactly as before.  The slot is left empty, ready for reuse;
        :meth:`restore` re-attaches the record to a fresh slot later."""
        rec = SpilledKV(pages=list(self._owned[slot]), tokens=int(self.hiwater[slot]))
        if self._owned[slot]:
            self._dirty = True
        self._owned[slot] = []
        self.tables[slot, :] = NULL_PAGE
        self.hiwater[slot] = 0
        return rec

    def restore(self, slot: int, rec: "SpilledKV") -> None:
        """Re-attach a spilled record to a fresh ``slot`` (the inverse of
        :meth:`spill`): block ``b`` maps back to ``rec.pages[b]``, the
        high-water mark returns to ``rec.tokens``.  Again no copy and no
        refcount traffic — the record's ownership moves to the slot."""
        if self._owned[slot]:
            raise RuntimeError(
                f"slot {slot} already holds pages — restore needs a fresh slot"
            )
        for b, page in enumerate(rec.pages):
            self.tables[slot, b] = page
        self._owned[slot] = list(rec.pages)
        self.hiwater[slot] = rec.tokens
        if rec.pages:
            self._dirty = True

    def drop_spilled(self, rec: "SpilledKV") -> None:
        """Abandon a spilled record (deadline lapsed, request cancelled):
        release the record's page references back to the pool."""
        for page in rec.pages:
            self.allocator.free(page)
        rec.pages = []
        rec.tokens = 0

    def rows_of(self, slot: int, start: int, length: int):
        """(pages, offsets) addressing positions ``[start, start+length)``
        of ``slot``.  Callers must :meth:`ensure` coverage first."""
        positions = start + np.arange(length)
        blocks = positions // self.page_size
        if len(positions) and blocks[-1] >= len(self._owned[slot]):
            raise RuntimeError(
                f"slot {slot} rows [{start}, {start + length}) not page-backed"
            )
        return self.tables[slot, blocks], positions % self.page_size

    def pages_of(self, slots: List[int]) -> np.ndarray:
        """All pool pages owned by ``slots`` (for targeted zeroing)."""
        pages = [p for s in slots for p in self._owned[s]]
        return np.asarray(sorted(pages), np.int64)

    def slot_blocks(self, slot: int) -> int:
        return len(self._owned[slot])

    def slot_pages(self, slot: int) -> List[int]:
        """The slot's page list in block order (block b → ``pages[b]``)."""
        return list(self._owned[slot])

    def splice(
        self, slot: int, pages: List[int], tokens: int
    ) -> Optional[Tuple[int, int, int]]:
        """Map positions ``[0, tokens)`` of a fresh ``slot`` onto shared
        ``pages`` (a prefix-cache hit).  Full pages are adopted *by
        reference* — the slot's block table points at the shared page and
        the allocator pins it, so ``release`` later just drops the pin.  A
        trailing partial page cannot be shared (the slot will append into
        its tail), so a fresh page is allocated for it and the caller must
        copy the first ``rows`` rows of every pool array; returns
        ``(src_page, dst_page, rows)`` describing that copy-on-write, or
        ``None`` when ``tokens`` is page-aligned."""
        if self._owned[slot]:
            raise RuntimeError(
                f"slot {slot} already holds pages — splice needs a fresh slot"
            )
        if tokens <= 0:
            return None
        nb = (tokens + self.page_size - 1) // self.page_size
        if nb > len(pages):
            raise ValueError(f"{tokens} tokens need {nb} pages, got {len(pages)}")
        full = tokens // self.page_size
        for i in range(full):
            p = self.allocator.ref(pages[i])
            self.tables[slot, i] = p
            self._owned[slot].append(p)
        cow = None
        rem = tokens - full * self.page_size
        if rem:
            dst = self.allocator.alloc()
            self.tables[slot, full] = dst
            self._owned[slot].append(dst)
            cow = (pages[full], dst, rem)
        self.hiwater[slot] = tokens
        self._dirty = True
        return cow

    # -- device view ---------------------------------------------------------
    def table_device(self, device=None) -> jax.Array:
        if self._dirty or self._dev is None or device is not self._dev_device:
            arr = jnp.asarray(self.tables)
            self._dev = jax.device_put(arr, device) if device is not None else arr
            self._dev_device = device
            self._dirty = False
        return self._dev

    @property
    def dirty(self) -> bool:
        return self._dirty

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Pool health for ``metrics()["kv_pages"]``: counts, occupancy of
        the allocatable pool, and internal fragmentation (the unwritten tail
        of allocated pages)."""
        in_use = self.allocator.in_use
        used_rows = int(self.hiwater.sum())
        alloc_rows = in_use * self.page_size
        return {
            "page_size": self.page_size,
            "num_pages": self.num_pages,
            "pages_in_use": in_use,
            "pages_peak": self.allocator.peak_in_use,
            "pages_free": self.allocator.num_free,
            "occupancy": in_use / max(1, self.num_pages - 1),
            "fragmentation": 1.0 - used_rows / alloc_rows if alloc_rows else 0.0,
        }


def _chunk_key(parent: bytes, tokens: np.ndarray) -> bytes:
    """Chained digest of one prompt chunk: the node key commits to the whole
    prefix (parent digest + this chunk's token bytes), so equal keys mean
    equal token prefixes — the trie needs no token storage."""
    h = hashlib.blake2b(parent, digest_size=16)
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


@dataclasses.dataclass
class _PrefixNode:
    key: bytes
    parent: Optional["_PrefixNode"]
    block0: int  # first cache block this node's pages cover
    pages: List[int]
    children: Dict[bytes, "_PrefixNode"] = dataclasses.field(default_factory=dict)
    last_used: int = 0


class PrefixIndex:
    """Radix/trie over chunk-aligned prompt prefixes → shared KV page runs.

    Keys are chained blake2b digests of ``chunk``-token prompt pieces, so a
    node exists iff some published prompt shared that exact token prefix.
    Each node pins (refcounts) the pool pages holding its chunk's KV rows;
    because the prefill chunk grid is deterministic and quantisation is
    chunk-boundary-deterministic, any prompt sharing the token prefix would
    produce bit-identical rows — serving a hit via
    :meth:`PagedKVCache.splice` is therefore exact, not approximate.

    ``max_pages`` bounds the pages the index may pin; inserts beyond the
    budget evict least-recently-used *leaf* nodes (interior nodes are
    prefixes of live leaves and stay).  Eviction only drops the index's own
    pin — a page still spliced into some slot's block table survives until
    that slot releases it, so eviction can never free a pinned page."""

    def __init__(
        self,
        chunk: int,
        pager: PagedKVCache,
        max_pages: Optional[int] = None,
    ):
        if chunk < 1:
            raise ValueError(f"chunk must be ≥ 1, got {chunk}")
        self.chunk = chunk
        self.pager = pager
        self.max_pages = max_pages
        self.root = _PrefixNode(key=b"", parent=None, block0=0, pages=[])
        self._nodes: List[_PrefixNode] = []
        self._clock = 0
        self.held_pages = 0
        # cumulative telemetry
        self.hits = 0
        self.misses = 0
        self.saved_tokens = 0
        self.lookup_tokens = 0
        self.evicted_pages = 0

    def _touch(self, node: _PrefixNode) -> None:
        self._clock += 1
        node.last_used = self._clock

    # -- lookup --------------------------------------------------------------
    def lookup(
        self, tokens: np.ndarray, limit: Optional[int] = None
    ) -> Tuple[int, List[int]]:
        """Longest chunk-aligned cached prefix of ``tokens`` (capped at
        ``limit`` tokens).  Returns ``(matched_tokens, pages)`` where
        ``pages[b]`` backs cache block ``b`` of the matched span — ready for
        :meth:`PagedKVCache.splice`.  Matched nodes are LRU-touched."""
        n = len(tokens) if limit is None else min(int(limit), len(tokens))
        self.lookup_tokens += len(tokens)
        node = self.root
        key = node.key
        run: Dict[int, int] = {}
        matched = 0
        for c in range(n // self.chunk):
            key = _chunk_key(key, tokens[c * self.chunk : (c + 1) * self.chunk])
            child = node.children.get(key)
            if child is None:
                break
            node = child
            self._touch(node)
            # later nodes override shared boundary blocks (chunk % page_size
            # ≠ 0): the deeper node's page holds the block's *full* rows
            for i, p in enumerate(node.pages):
                run[node.block0 + i] = p
            matched = (c + 1) * self.chunk
        if matched:
            self.hits += 1
            self.saved_tokens += matched
        else:
            self.misses += 1
            return 0, []
        nb = (matched + self.pager.page_size - 1) // self.pager.page_size
        return matched, [run[b] for b in range(nb)]

    # -- publish -------------------------------------------------------------
    def publish(self, tokens: np.ndarray, upto: int, slot: int) -> int:
        """Index the chunk-aligned prefix KV that ``slot`` just prefilled:
        walk/extend the trie over ``tokens[:upto]`` and pin the slot's pages
        backing each *new* chunk's rows.  Returns the number of nodes added.
        Pages stay valid after the slot releases (the index holds its own
        refcount), and published rows are immutable — decode appends at
        positions ≥ the prompt length, never inside a published chunk."""
        owned = self.pager.slot_pages(slot)
        node = self.root
        key = node.key
        added = 0
        for c in range(int(upto) // self.chunk):
            lo, hi = c * self.chunk, (c + 1) * self.chunk
            key = _chunk_key(key, tokens[lo:hi])
            child = node.children.get(key)
            if child is None:
                b0, b1 = lo // self.pager.page_size, (hi - 1) // self.pager.page_size
                if b1 >= len(owned):
                    break  # slot rows not page-backed that far (shouldn't happen)
                pages = owned[b0 : b1 + 1]
                for p in pages:
                    self.pager.allocator.ref(p)
                child = _PrefixNode(key=key, parent=node, block0=b0, pages=pages)
                node.children[key] = child
                self._nodes.append(child)
                self.held_pages += len(pages)
                added += 1
            node = child
            self._touch(node)
        self._evict()
        return added

    # -- eviction ------------------------------------------------------------
    def _evict(self) -> None:
        """LRU leaf eviction down to the page budget.  Dropping a node only
        releases the *index's* refcount — pages spliced into live block
        tables keep their other holders."""
        if self.max_pages is None:
            return
        while self.held_pages > self.max_pages:
            leaves = [n for n in self._nodes if not n.children]
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_used)
            self._drop_node(victim)

    def _drop_node(self, node: _PrefixNode) -> None:
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        self._nodes.remove(node)
        for p in node.pages:
            self.pager.allocator.free(p)
        self.held_pages -= len(node.pages)
        self.evicted_pages += len(node.pages)

    def drop_all(self) -> None:
        """Release every pin and forget the trie (re-shard / cache reset).
        Cumulative hit/miss telemetry survives."""
        for node in list(self._nodes):
            for p in node.pages:
                self.pager.allocator.free(p)
        self._nodes = []
        self.root = _PrefixNode(key=b"", parent=None, block0=0, pages=[])
        self.held_pages = 0

    # -- telemetry -----------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": self.hits / total if total else 0.0,
            "saved_tokens": self.saved_tokens,
            "saved_frac": (
                self.saved_tokens / self.lookup_tokens if self.lookup_tokens else 0.0
            ),
            "shared_pages": self.held_pages,
            "evicted_pages": self.evicted_pages,
            "nodes": len(self._nodes),
        }


def make_paged_caches(
    caches: Dict[str, jax.Array],
    max_batch: int,
    cache_len: int,
    page_size: int,
    num_pages: Optional[int] = None,
):
    """Convert freshly-initialised engine caches to the paged layout.

    The :data:`PAGED_KEYS` arrays ``[L, B, S, ...]`` are replaced by page
    pools ``[L, num_pages, page_size, ...]`` plus a ``block_tables`` entry;
    every other cache keeps its contiguous layout.  Returns
    ``(PagedKVCache, new_caches)``."""
    if "kv_k" not in caches:
        raise ValueError(
            "kv_page_size set but this architecture has no full-attention "
            "KV cache to page (only rolling/recurrent state)"
        )
    pager = PagedKVCache(max_batch, cache_len, page_size, num_pages)
    out = dict(caches)
    for k in PAGED_KEYS:
        if k in caches:
            v = caches[k]
            out[k] = jnp.zeros(
                (v.shape[0], pager.num_pages, page_size, *v.shape[3:]), v.dtype
            )
    out["block_tables"] = pager.table_device()
    return pager, out


def scatter_prefill_chunk_paged(
    batch_caches: Dict[str, jax.Array],
    one_caches: Dict[str, jax.Array],
    slot: int,
    start: int,
    length: int,
    pager: PagedKVCache,
) -> Dict[str, jax.Array]:
    """Paged analogue of :func:`scatter_prefill_chunk_caches`: the chunk's
    rows of the :data:`PAGED_KEYS` land in ``slot``'s pages (allocated on
    demand — chunks land on page boundaries because the worker's chunk size
    and the page size both divide ``cache_len``); any other streamed KV key
    (e.g. a rolling ``_local`` cache) takes the contiguous row path."""
    pager.ensure(slot, start + length - 1)
    out = dict(batch_caches)
    positions = start + np.arange(length)
    pages, offs = pager.rows_of(slot, start, length)
    for k, v in one_caches.items():
        if not k.startswith("kv_"):
            continue
        if k in PAGED_KEYS:
            out[k] = batch_caches[k].at[:, pages, offs].set(
                v[:, 0, positions].astype(batch_caches[k].dtype)
            )
        else:
            S_k = v.shape[2]
            st, ln = start, length
            if ln > S_k:  # whole-prompt hand-off into a shorter rolling buffer
                st, ln = start + ln - S_k, S_k
            rows = chunk_rows(S_k, st, ln)
            out[k] = batch_caches[k].at[:, slot, rows].set(
                v[:, 0, rows].astype(batch_caches[k].dtype)
            )
    out["block_tables"] = pager.table_device()
    return out


def paginate_caches(
    caches: Dict[str, jax.Array],
    lengths: np.ndarray,
    page_size: int,
    num_pages: Optional[int] = None,
):
    """Re-paginate dense engine caches (e.g. a disagg ``export_caches``
    during degrade-to-mono): allocate pages for each slot's live ``lengths``
    rows and copy them in.  Page *ids* are freshly assigned, but the
    position→value mapping is preserved exactly, so replayed streams stay
    bit-identical.  Returns ``(PagedKVCache, paged_caches)``."""
    B = caches["kv_k"].shape[1]
    S = caches["kv_k"].shape[2]
    pager, out = make_paged_caches(caches, B, S, page_size, num_pages)
    for slot in range(B):
        ln = int(lengths[slot])
        if ln <= 0:
            continue
        pager.ensure(slot, ln - 1)
        pages, offs = pager.rows_of(slot, 0, ln)
        for k in PAGED_KEYS:
            if k in caches:
                out[k] = out[k].at[:, pages, offs].set(caches[k][:, slot, :ln])
    out["block_tables"] = pager.table_device()
    return pager, out


def depaginate_caches(
    paged_caches: Dict[str, jax.Array], pager: PagedKVCache
) -> Dict[str, jax.Array]:
    """Inverse of :func:`paginate_caches`: gather each slot's pages back into
    dense ``[L, B, S, ...]`` rows (unbacked rows come back as zeros)."""
    out = {k: v for k, v in paged_caches.items() if k != "block_tables"}
    for k in PAGED_KEYS:
        if k not in paged_caches:
            continue
        pool = np.asarray(paged_caches[k])  # [L, P, ps, ...]
        L = pool.shape[0]
        dense = np.zeros(
            (L, pager.max_batch, pager.cache_len, *pool.shape[3:]), pool.dtype
        )
        for slot in range(pager.max_batch):
            nb = pager.slot_blocks(slot)
            if not nb:
                continue
            pages = pager.tables[slot, :nb]
            rows = pool[:, pages].reshape(L, nb * pager.page_size, *pool.shape[3:])
            dense[:, slot, : nb * pager.page_size] = rows
        out[k] = jnp.asarray(dense)
    return out
