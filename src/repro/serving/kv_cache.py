"""Slot-based batched cache manager for continuous-batching decode.

The engine holds model caches with a fixed ``max_batch`` of request slots
(batch axis 1 of every cache array).  The manager tracks slot occupancy and
per-slot positions; a freed slot is immediately reusable because attention
masks are position-bounded per request.

Slot lifecycle (pipelined admission, prefill-pool disaggregation)::

    FREE ──reserve──▶ RESERVED ──start_prefill──▶ PREFILLING ──activate──▶ ACTIVE
      ▲                              ▲                 │                     │
      │                              │fail + requeue   │fail                 │
      │                         REQUEUED ◀──requeue── FAILED                 │
      └────────────────────────────── release ◀──────────────────────────────┘

``admit`` is the legacy blocking path: FREE → ACTIVE in one call.  Reserved
and prefilling slots are *owned* (not free) but not decoded: the decode loop
only batches ACTIVE slots, so a request whose prompt is still streaming in
chunk-by-chunk never corrupts (or stalls) the in-flight batch.

``FAILED``/``REQUEUED`` are the fault-recovery detour: a prefill-worker
failure (or a lost attention shard mid-prefill) marks the slot FAILED, the
engine requeues the request, and prefill restarts from chunk 0 — chunked
prefill is deterministic, so the restarted request emits the same tokens it
would have without the fault.

Inactive slots park their write position at ``cache_len - 1`` (a reserved
scratch entry no live context may reach), so the batched decode step can run
unconditionally without corrupting live entries.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.request import Request

FREE = "free"
RESERVED = "reserved"
PREFILLING = "prefilling"
ACTIVE = "active"
FAILED = "failed"  # prefill lost to a fault; awaiting requeue
REQUEUED = "requeued"  # re-admitted to the prefill queue after a fault


@dataclasses.dataclass
class SlotManager:
    max_batch: int
    cache_len: int

    def __post_init__(self):
        self.slot_req: List[Optional[Request]] = [None] * self.max_batch
        self.state: List[str] = [FREE] * self.max_batch
        self.positions = np.full(self.max_batch, self.cache_len - 1, np.int32)

    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.state) if s == FREE]

    @property
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.state) if s == ACTIVE]

    @property
    def pending_slots(self) -> List[int]:
        """Slots owned by a request whose prefill has not finished."""
        return [
            i
            for i, s in enumerate(self.state)
            if s in (RESERVED, PREFILLING, FAILED, REQUEUED)
        ]

    @property
    def num_active(self) -> int:
        return len(self.active_slots)

    # -- legacy blocking admission: FREE → ACTIVE in one call ----------------
    def admit(self, req: Request) -> int:
        s = self.reserve(req)
        self.activate(s)
        return s

    # -- pipelined admission -------------------------------------------------
    def reserve(self, req: Request) -> int:
        free = self.free_slots
        if not free:
            raise RuntimeError("no free slot")
        s = free[0]
        self.slot_req[s] = req
        self.state[s] = RESERVED
        req.slot = s
        return s

    def start_prefill(self, slot: int) -> None:
        if self.state[slot] not in (RESERVED, REQUEUED):
            raise RuntimeError(
                f"slot {slot} is {self.state[slot]}, expected {RESERVED} or {REQUEUED}"
            )
        self.state[slot] = PREFILLING

    # -- fault-recovery detour: prefilling → failed → requeued → prefilling --
    def fail(self, slot: int) -> None:
        """Mark a slot whose in-flight prefill was lost to a fault."""
        if self.state[slot] not in (RESERVED, PREFILLING):
            raise RuntimeError(f"slot {slot} is {self.state[slot]}, cannot fail")
        self.state[slot] = FAILED

    def requeue(self, slot: int) -> None:
        """Hand a failed slot back to the prefill queue (restart at chunk 0)."""
        if self.state[slot] != FAILED:
            raise RuntimeError(f"slot {slot} is {self.state[slot]}, expected {FAILED}")
        self.state[slot] = REQUEUED

    def activate(self, slot: int) -> None:
        if self.state[slot] not in (RESERVED, PREFILLING):
            raise RuntimeError(f"slot {slot} is {self.state[slot]}, cannot activate")
        self.state[slot] = ACTIVE
        self.positions[slot] = self.slot_req[slot].input_len

    def advance(self, slot: int) -> None:
        self.positions[slot] += 1

    def release(self, slot: int) -> Request:
        req = self.slot_req[slot]
        self.slot_req[slot] = None
        self.state[slot] = FREE
        self.positions[slot] = self.cache_len - 1
        return req

    def positions_device(self) -> jax.Array:
        return jnp.asarray(self.positions)

    def active_mask(self) -> np.ndarray:
        return np.array([s == ACTIVE for s in self.state])


def scatter_prefill_caches(
    batch_caches: Dict[str, jax.Array],
    one_caches: Dict[str, jax.Array],
    slot: int,
) -> Dict[str, jax.Array]:
    """Write a single-request prefill cache (batch dim 1) into slot ``slot``
    of the batched caches.  Batch axis is 1 for stacked caches, 0 for
    ``enc_out``."""
    out = dict(batch_caches)
    for k, v in one_caches.items():
        if k == "enc_out":
            out[k] = batch_caches[k].at[slot].set(v[0])
        else:
            out[k] = batch_caches[k].at[:, slot].set(v[:, 0])
    return out


def zero_slots(
    batch_caches: Dict[str, jax.Array], slots: List[int]
) -> Dict[str, jax.Array]:
    """Destroy the KV rows of ``slots`` (batch axis 1; ``enc_out`` axis 0).

    Fault-recovery helper: when an attention shard dies, the slots it hosted
    are *actually* zeroed before re-sharding, so recovery tests prove the
    deterministic re-prefill replay rebuilt the state rather than silently
    reading rows a real failure would have destroyed."""
    if not slots:
        return batch_caches
    idx = np.asarray(slots)
    out = dict(batch_caches)
    for k, v in batch_caches.items():
        if k == "enc_out":
            out[k] = v.at[idx].set(0)
        else:
            out[k] = v.at[:, idx].set(0)
    return out


def chunk_rows(cache_len: int, start: int, length: int) -> np.ndarray:
    """Position-axis rows holding prompt positions ``[start, start+length)``
    in a cache of ``cache_len`` entries.  Contiguous ``start..start+length-1``
    for full-length caches; rolling-window caches (``cache_len`` < prompt)
    store position ``p`` at slot ``p % cache_len``, so rows wrap."""
    return (start + np.arange(length)) % cache_len


def scatter_prefill_chunk_caches(
    batch_caches: Dict[str, jax.Array],
    one_caches: Dict[str, jax.Array],
    slot: int,
    start: int,
    length: int,
) -> Dict[str, jax.Array]:
    """Stream one prefill chunk's KV slab into slot ``slot``: the rows
    holding prompt positions ``[start, start+length)`` of the per-request
    caches overwrite the same rows of the batched caches (per-cache
    :func:`chunk_rows` mapping — rolling-window caches wrap).  This is the
    per-chunk hand-off of the prefill→decode pipeline — position-indexed KV
    keys only (recurrent / encoder state has no position axis and moves with
    the *final* chunk via :func:`scatter_prefill_caches`)."""
    out = dict(batch_caches)
    for k, v in one_caches.items():
        if not k.startswith("kv_"):
            continue
        rows = chunk_rows(v.shape[2], start, length)  # [L, 1, S, ...] axis 2
        out[k] = batch_caches[k].at[:, slot, rows].set(
            v[:, 0, rows].astype(batch_caches[k].dtype)
        )
    return out
