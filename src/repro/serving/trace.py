"""Arrival-process synthesis and the trace-replay workload file.

Generators: Poisson, BurstGPT-like bursty arrivals, and the diurnal
production trace shapes of Fig. 4 / Fig. 11.

Workload file: :class:`TraceSpec` — a JSON-serialisable multi-tenant trace
(per-tenant request class, arrival process, priority, TTFT/TPOT SLOs) whose
``build()`` yields one merged request list.  The same spec drives the real
``ServingEngine`` (both executors) and the analytic ``ClusterSimulator``,
so scheduler experiments and scaling-policy experiments replay the *same*
workload (the paper's fig9 SLO-attainment framing)."""

from __future__ import annotations

import dataclasses
import json
from dataclasses import field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.request import Request, WorkloadSpec, sample_requests


def poisson_arrivals(rate: float, duration: float, seed: int = 0) -> np.ndarray:
    """Constant-rate Poisson arrivals over [0, duration) (seconds)."""
    rng = np.random.default_rng(seed)
    n = rng.poisson(rate * duration)
    return np.sort(rng.uniform(0, duration, size=n))


def bursty_arrivals(
    mean_rate: float,
    duration: float,
    burstiness: float = 2.0,
    epoch: float = 10.0,
    seed: int = 0,
) -> np.ndarray:
    """BurstGPT-style doubly-stochastic arrivals: the rate itself follows a
    Gamma process over ``epoch``-second windows (CV² ≈ burstiness)."""
    rng = np.random.default_rng(seed)
    times = []
    t = 0.0
    shape = 1.0 / max(1e-6, burstiness)
    while t < duration:
        lam = rng.gamma(shape, mean_rate / shape)
        n = rng.poisson(lam * epoch)
        times.append(rng.uniform(t, t + epoch, size=n))
        t += epoch
    return np.sort(np.concatenate(times)) if times else np.array([])


def diurnal_rate_profile(
    hours: float = 24.0,
    step_minutes: float = 15.0,
    mean_rate: float = 100.0,
    peak_over_mean: float = 2.5,
    burst_peak_over_mean: float = 7.5,
    n_bursts: int = 3,
    seed: int = 0,
    period_hours: float = 24.0,
) -> Tuple[np.ndarray, np.ndarray]:
    """(window start times [s], per-window mean rates) — the Fig. 4 shape:
    diurnal sinusoid plus sporadic bursts reaching ~7.5× the mean.  Set
    ``period_hours = hours`` to compress one full day into a short trace
    window (what :class:`TraceSpec` does for diurnal tenants)."""
    rng = np.random.default_rng(seed)
    n = int(hours * 60 / step_minutes)
    t = np.arange(n) * step_minutes * 60.0
    phase = 2 * np.pi * (t / 3600.0 % period_hours) / period_hours
    base = 1.0 + (peak_over_mean - 1.0) * 0.5 * (1 - np.cos(phase))
    rates = base / base.mean() * mean_rate
    for _ in range(n_bursts):
        i = rng.integers(n // 8, n)
        width = max(1, int(rng.integers(1, 4)))
        rates[i : i + width] *= burst_peak_over_mean / peak_over_mean
    return t, rates


def arrivals_from_profile(
    window_starts: np.ndarray, rates: np.ndarray, seed: int = 0
) -> np.ndarray:
    """Poisson arrivals following a piecewise-constant rate profile."""
    rng = np.random.default_rng(seed)
    dt = window_starts[1] - window_starts[0] if len(window_starts) > 1 else 60.0
    times = []
    for t0, lam in zip(window_starts, rates):
        n = rng.poisson(lam * dt)
        times.append(rng.uniform(t0, t0 + dt, size=n))
    return np.sort(np.concatenate(times)) if times else np.array([])


# ---------------------------------------------------------------------------
# Multi-tenant trace spec (the workload file)
# ---------------------------------------------------------------------------

# Request-class length presets (WorkloadSpec overrides win over these):
# chat = short interactive turns, long-context = document-QA/RAG prompts,
# batch-offline = throughput jobs with long generations and no latency needs.
CLASS_PRESETS: Dict[str, Dict[str, float]] = {
    "chat": dict(mean_input=16.0, mean_output=48.0, max_input=64, max_output=128),
    "long-context": dict(
        mean_input=512.0, mean_output=64.0, max_input=4096, max_output=256
    ),
    "batch-offline": dict(
        mean_input=32.0, mean_output=256.0, max_input=128, max_output=1024
    ),
}

ARRIVAL_PROCESSES = ("poisson", "bursty", "diurnal")


@dataclasses.dataclass
class TenantSpec:
    """One tenant's slice of a trace: request class, arrival process, and the
    scheduling contract (priority + SLOs) its requests carry."""

    name: str
    klass: str = "chat"  # chat | long-context | batch-offline
    rate: float = 1.0  # mean requests/s over the trace
    arrival: str = "poisson"  # poisson | bursty | diurnal
    burstiness: float = 2.0  # bursty: CV² of the Gamma-modulated rate
    epoch: float = 10.0  # bursty: rate-modulation window (s)
    priority: int = 0  # higher preempts lower under sched="priority"
    ttft_slo: Optional[float] = None  # s, arrival → first token
    tpot_slo: Optional[float] = None  # s, p99 inter-token gap
    deadline: Optional[float] = None  # s after arrival; lapsed → rejected
    workload: Dict = field(default_factory=dict)  # WorkloadSpec overrides
    seed: Optional[int] = None  # None → derived from TraceSpec.seed

    def workload_spec(self, vocab_size: int, seed: int) -> WorkloadSpec:
        if self.klass not in CLASS_PRESETS:
            raise ValueError(
                f"unknown request class {self.klass!r}; choose from "
                f"{sorted(CLASS_PRESETS)}"
            )
        kw = dict(CLASS_PRESETS[self.klass])
        kw.update(self.workload)
        kw.setdefault("vocab_size", vocab_size)
        kw["seed"] = seed
        return WorkloadSpec(**kw)

    def arrivals(self, duration: float, seed: int) -> np.ndarray:
        if self.arrival == "poisson":
            arr = poisson_arrivals(self.rate, duration, seed=seed)
        elif self.arrival == "bursty":
            arr = bursty_arrivals(
                self.rate,
                duration,
                burstiness=self.burstiness,
                epoch=min(self.epoch, duration),
                seed=seed,
            )
        elif self.arrival == "diurnal":
            # compress one full synthetic day into the trace window so short
            # traces still sweep trough → peak → trough
            hours = duration / 3600.0
            t, rates = diurnal_rate_profile(
                hours=hours,
                step_minutes=duration / 60.0 / 96.0,  # 96 windows per trace
                mean_rate=self.rate,
                seed=seed,
                period_hours=hours,
            )
            arr = arrivals_from_profile(t, rates, seed=seed)
        else:
            raise ValueError(
                f"unknown arrival process {self.arrival!r}; choose from "
                f"{ARRIVAL_PROCESSES}"
            )
        return arr[arr < duration]


@dataclasses.dataclass
class TraceSpec:
    """A complete replayable workload: duration, seed, and tenant mix.

    ``to_json``/``from_json`` make it a file format (``--trace`` in
    ``launch/serve.py``); ``build()`` deterministically expands it into the
    merged, arrival-sorted request list both the engine and the simulator
    consume."""

    duration: float = 60.0
    seed: int = 0
    tenants: List[TenantSpec] = field(default_factory=list)

    def to_json(self) -> str:
        return json.dumps(
            {
                "duration": self.duration,
                "seed": self.seed,
                "tenants": [dataclasses.asdict(t) for t in self.tenants],
            },
            indent=2,
        )

    @staticmethod
    def from_json(text: str) -> "TraceSpec":
        d = json.loads(text)
        return TraceSpec(
            duration=float(d.get("duration", 60.0)),
            seed=int(d.get("seed", 0)),
            tenants=[TenantSpec(**t) for t in d.get("tenants", [])],
        )

    def build(
        self, vocab_size: int = 32_000, with_prompts: bool = False
    ) -> List[Request]:
        """Expand the spec into one merged request list: per-tenant arrivals
        and lengths, stamped with the tenant's priority/SLOs/deadline, merged
        by arrival time, rids re-assigned globally (rid seeds the synthetic
        prompt when prompts are generated lazily, so the re-assignment must
        happen before any replay)."""
        merged: List[Request] = []
        for i, t in enumerate(self.tenants):
            seed = t.seed if t.seed is not None else self.seed * 1009 + i
            arr = t.arrivals(self.duration, seed)
            spec = t.workload_spec(vocab_size, seed)
            reqs = sample_requests(spec, arr, with_prompts=with_prompts)
            for r in reqs:
                r.tenant = t.name
                r.klass = t.klass
                r.priority = t.priority
                r.ttft_slo = t.ttft_slo
                r.tpot_slo = t.tpot_slo
                if t.deadline is not None:
                    r.deadline = r.arrival + t.deadline
            merged.extend(reqs)
        # deterministic merge: arrival, then tenant name breaks exact ties
        merged.sort(key=lambda r: (r.arrival, r.tenant, r.rid))
        for i, r in enumerate(merged):
            r.rid = i
        return merged
