"""Arrival-process synthesis: Poisson, BurstGPT-like bursty arrivals, and the
diurnal production trace shapes of Fig. 4 / Fig. 11."""

from __future__ import annotations

from typing import Tuple

import numpy as np


def poisson_arrivals(rate: float, duration: float, seed: int = 0) -> np.ndarray:
    """Constant-rate Poisson arrivals over [0, duration) (seconds)."""
    rng = np.random.default_rng(seed)
    n = rng.poisson(rate * duration)
    return np.sort(rng.uniform(0, duration, size=n))


def bursty_arrivals(
    mean_rate: float,
    duration: float,
    burstiness: float = 2.0,
    epoch: float = 10.0,
    seed: int = 0,
) -> np.ndarray:
    """BurstGPT-style doubly-stochastic arrivals: the rate itself follows a
    Gamma process over ``epoch``-second windows (CV² ≈ burstiness)."""
    rng = np.random.default_rng(seed)
    times = []
    t = 0.0
    shape = 1.0 / max(1e-6, burstiness)
    while t < duration:
        lam = rng.gamma(shape, mean_rate / shape)
        n = rng.poisson(lam * epoch)
        times.append(rng.uniform(t, t + epoch, size=n))
        t += epoch
    return np.sort(np.concatenate(times)) if times else np.array([])


def diurnal_rate_profile(
    hours: float = 24.0,
    step_minutes: float = 15.0,
    mean_rate: float = 100.0,
    peak_over_mean: float = 2.5,
    burst_peak_over_mean: float = 7.5,
    n_bursts: int = 3,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray]:
    """(window start times [s], per-window mean rates) — the Fig. 4 shape:
    diurnal sinusoid plus sporadic bursts reaching ~7.5× the mean."""
    rng = np.random.default_rng(seed)
    n = int(hours * 60 / step_minutes)
    t = np.arange(n) * step_minutes * 60.0
    phase = 2 * np.pi * (t / 3600.0 % 24.0) / 24.0
    base = 1.0 + (peak_over_mean - 1.0) * 0.5 * (1 - np.cos(phase))
    rates = base / base.mean() * mean_rate
    for _ in range(n_bursts):
        i = rng.integers(n // 8, n)
        width = max(1, int(rng.integers(1, 4)))
        rates[i : i + width] *= burst_peak_over_mean / peak_over_mean
    return t, rates


def arrivals_from_profile(
    window_starts: np.ndarray, rates: np.ndarray, seed: int = 0
) -> np.ndarray:
    """Poisson arrivals following a piecewise-constant rate profile."""
    rng = np.random.default_rng(seed)
    dt = window_starts[1] - window_starts[0] if len(window_starts) > 1 else 60.0
    times = []
    for t0, lam in zip(window_starts, rates):
        n = rng.poisson(lam * dt)
        times.append(rng.uniform(t0, t0 + dt, size=n))
    return np.sort(np.concatenate(times)) if times else np.array([])
