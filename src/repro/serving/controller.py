"""Autoscaling controller: demand estimation + periodic SLO-aware scaling.

Wraps :class:`repro.core.scaling.SLOScaler` with a sliding-window demand
estimator and applies decisions at a fixed interval (paper: 15 minutes),
with hysteresis to avoid flapping.  Expert placement is re-derived from the
recent routing trace at each reconfiguration (§3.5 "expert placement").

A decision is no longer advisory: :meth:`AutoScaler.actuate` applies it to a
live ``ServingEngine(executor="disagg")`` via ``engine.reconfigure`` —
prefill, attention and MoE pool counts move independently mid-run, only the
affected pools are re-lowered, and in-flight KV caches are preserved.

The prefill pool scales on its *own* demand signal: prompt tokens/s (from
:meth:`AutoScaler.observe`'s ``input_tokens``) over the sliding window,
divided by the per-device prefill throughput ``prefill_tok_rate`` — long
prompts grow the prefill sub-cluster without touching the decode pools, and
vice versa.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.placement import build_layout
from repro.core.scaling import EvalResult, PerfModel, SLOScaler


@dataclasses.dataclass
class ScalingEvent:
    t: float
    demand: float
    n_a: int
    n_e: int
    tpot: float
    feasible: bool
    n_p: Optional[int] = None  # prefill pool decision (None = not scaled)


class AutoScaler:
    def __init__(
        self,
        model: PerfModel,
        slo: float,
        n_max: int = 16,
        window: float = 300.0,
        hysteresis: float = 0.1,
        prefill_tok_rate: float = 0.0,  # prompt tokens/s one prefill device sustains
        n_prefill_max: Optional[int] = None,
        kv_pressure_threshold: float = 0.9,  # paged-pool occupancy that forces +1 attn
        objective: str = "min_devices",  # min_devices | slo_per_device
        demand_samples_k: int = 6,  # sub-windows scored by slo_per_device
    ):
        self.scaler = SLOScaler(model, n_max=n_max)
        self.slo = slo
        self.window = window
        self.hysteresis = hysteresis
        self.prefill_tok_rate = prefill_tok_rate
        self.n_prefill_max = n_prefill_max if n_prefill_max is not None else n_max
        self.kv_pressure_threshold = kv_pressure_threshold
        if objective not in ("min_devices", "slo_per_device"):
            raise ValueError(
                f"unknown objective {objective!r}; choose min_devices or "
                "slo_per_device"
            )
        self.objective = objective
        self.demand_samples_k = demand_samples_k
        self._arrivals: List[float] = []
        self._tokens: List[float] = []
        self._input_tokens: List[float] = []
        self._accepted: List[float] = []  # per-observation accepted tokens/step
        self._kv_obs: List[tuple] = []  # (t, paged-pool occupancy) samples
        # engine-sampled speculative acceptance (metrics()["spec"], sampled by
        # actuate) — the fallback discount for observations that did not carry
        # their own accepted_per_step
        self._spec_accept_rate = 0.0
        # fraction of recent prompt tokens the prefix cache served from shared
        # pages (engine metrics()["prefix_cache"]["saved_frac"], sampled by
        # actuate) — those tokens never reach the prefill pool
        self._prefix_saved_frac = 0.0
        self.current: Optional[EvalResult] = None
        self.events: List[ScalingEvent] = []
        self.device_losses: List[tuple] = []  # (t, pool) permanent losses seen

    # -- fault feedback --------------------------------------------------------
    def on_device_loss(self, pool: str, now: float) -> None:
        """A permanent device loss shrinks capacity: the scaler must stop
        proposing configurations the surviving hardware cannot host.  Decode
        pools cap the (n_a, n_e) search bound; prefill caps its own bound."""
        if pool == "prefill":
            self.n_prefill_max = max(1, self.n_prefill_max - 1)
        else:
            self.scaler.n_max = max(1, self.scaler.n_max - 1)
        self.device_losses.append((now, pool))

    def attach(self, engine) -> None:
        """Subscribe to the engine's fault events so lost capacity feeds the
        next scaling decision automatically."""
        engine.fault_listeners.append(
            lambda fault, t: self.on_device_loss(fault.pool, t)
        )

    # -- demand estimation ---------------------------------------------------
    def observe(
        self,
        t: float,
        tokens: float,
        input_tokens: float = 0.0,
        kv_occupancy: float = 0.0,
        saved_input_tokens: float = 0.0,
        accepted_per_step: float = 0.0,
    ) -> None:
        """Log one arrival: ``tokens`` drives decode scaling, ``input_tokens``
        (the prompt length) drives prefill-pool scaling, ``kv_occupancy``
        (paged-KV pool fill fraction, 0..1) drives memory-pressure scaling.
        ``saved_input_tokens`` (prompt tokens a prefix-cache hit served from
        shared pages) are subtracted — they cost the prefill pool nothing.
        ``accepted_per_step`` (speculative decode: mean tokens a verify step
        emits, ≥ 1) discounts decode demand — the perf model prices decode
        *steps*, and speculation serves that many tokens per step, so a
        request's step demand is ``tokens / accepted_per_step``.  Callers
        without per-request information can leave the discounts 0 and let
        :meth:`actuate`'s engine-sampled rates apply instead."""
        self._arrivals.append(t)
        self._tokens.append(tokens)
        self._input_tokens.append(max(0.0, input_tokens - saved_input_tokens))
        self._accepted.append(float(accepted_per_step))
        if kv_occupancy > 0.0:
            self._kv_obs.append((t, float(kv_occupancy)))

    def _step_demand(self, tokens: float, accepted: float) -> float:
        """One observation's decode-step demand: tokens discounted by the
        speculative acceptance rate (its own, else the engine-sampled one,
        else no speculation).  Acceptance is clamped to ≥ 1 — a verify step
        always emits at least one token, so speculation can only *reduce*
        step demand; halving the acceptance rate raises it back."""
        eff = accepted if accepted > 0 else self._spec_accept_rate
        return tokens / max(1.0, eff)

    def demand(self, now: float) -> float:
        lo = now - self.window
        tok = sum(
            self._step_demand(tk, acc)
            for t, tk, acc in zip(self._arrivals, self._tokens, self._accepted)
            if t >= lo
        )
        return tok / self.window

    def prefill_demand(self, now: float) -> float:
        """Prompt tokens/s over the sliding window."""
        lo = now - self.window
        tok = sum(tk for t, tk in zip(self._arrivals, self._input_tokens) if t >= lo)
        return tok / self.window

    def kv_pressure(self, now: float) -> float:
        """Worst paged-KV occupancy seen in the sliding window (0.0 if the
        engine is not paged or no sample landed in the window)."""
        lo = now - self.window
        occ = [o for t, o in self._kv_obs if t >= lo]
        return max(occ) if occ else 0.0

    def demand_samples(self, now: float) -> List[float]:
        """The empirical per-sub-window demand distribution (tokens/s) over
        the sliding window — the burstiness the single mean hides.  The
        slo_per_device objective scores candidate configurations against
        these samples instead of the mean, so a bursty window prefers a
        configuration that also holds the SLO at its peaks."""
        k = max(1, self.demand_samples_k)
        lo = now - self.window
        sub = self.window / k
        buckets = [0.0] * k
        for t, tok, acc in zip(self._arrivals, self._tokens, self._accepted):
            if t >= lo:
                buckets[min(k - 1, max(0, int((t - lo) / sub)))] += self._step_demand(
                    tok, acc
                )
        return [b / sub for b in buckets]

    def decide_prefill(self, now: float, demand: Optional[float] = None) -> Optional[int]:
        """Size the prefill pool independently of the decode pools: enough
        devices to keep prompt-token demand below per-device throughput.
        Returns None when prefill scaling is disabled (no rate calibrated)."""
        if self.prefill_tok_rate <= 0:
            return None
        lam_in = demand if demand is not None else self.prefill_demand(now)
        # prefix-cache discount: the fraction of prompt tokens served from
        # shared pages never reaches the prefill devices, so a warm cache
        # shrinks the pool the same demand would otherwise require
        lam_in *= max(0.0, 1.0 - self._prefix_saved_frac)
        if lam_in <= 0:
            return 1  # keep one warm replica — admission stays pipelined
        n_p = int(np.ceil(lam_in / self.prefill_tok_rate))
        return max(1, min(n_p, self.n_prefill_max))

    # -- decision -------------------------------------------------------------
    def _decide_slo_per_device(
        self, lam: float, samples: List[float]
    ) -> Optional[EvalResult]:
        """Score every (n_a, n_e) candidate by SLO-attainment-per-device
        (the paper's fig9 framing): attainment = fraction of recent demand
        samples the candidate holds feasibly, divided by its device count.
        Against bursty demand this picks a configuration sized for the
        window's peaks when the extra devices pay for themselves in
        attainment — where min-devices sizes for the mean and eats the SLO
        misses."""
        live = [s for s in samples if s > 0]
        if not live:
            return self.scaler.scale(lam, self.slo)
        best: Optional[EvalResult] = None
        best_score = 0.0
        for n_a in range(1, self.scaler.n_max + 1):
            for n_e in range(self.scaler.n_e_min, self.scaler.n_max + 1):
                evs = [self.scaler.evaluate(s, self.slo, n_a, n_e) for s in live]
                att = float(
                    np.mean([e is not None and e.feasible for e in evs])
                )
                if att <= 0.0:
                    continue
                score = att / (n_a + n_e)
                if score > best_score + 1e-12:
                    # the stored EvalResult reflects the mean demand (falls
                    # back to the heaviest feasible sample when the mean
                    # itself is unservable at this size)
                    ev = self.scaler.evaluate(lam, self.slo, n_a, n_e)
                    if ev is None:
                        ev = next(e for e in evs if e is not None)
                    best, best_score = ev, score
        return best

    def decide(self, now: float, demand: Optional[float] = None) -> EvalResult:
        lam = demand if demand is not None else self.demand(now)
        if self.objective == "slo_per_device":
            best = self._decide_slo_per_device(lam, self.demand_samples(now))
        else:
            best = self.scaler.scale(lam, self.slo)
        if best is None:
            # infeasible: run at max configuration
            best = self.scaler.model.tpot(1.0, self.scaler.n_max, self.scaler.n_max)
            best.feasible = False
        if self.current is not None and best.feasible:
            same_cost = abs((best.n_a + best.n_e) - (self.current.n_a + self.current.n_e))
            if same_cost == 0 or (
                self.current.feasible
                and abs(lam - self.current.batch / max(self.current.tpot, 1e-9))
                < self.hysteresis * lam
            ):
                pass  # keep current if change is marginal — hysteresis
        # memory pressure: a near-full paged-KV pool means attention devices
        # are KV-bound even when latency looks fine — add one before admission
        # starts rejecting (each attn device shards off part of the batch and
        # its pages with it)
        if best.feasible and self.kv_pressure(now) >= self.kv_pressure_threshold:
            best = dataclasses.replace(best, n_a=min(best.n_a + 1, self.scaler.n_max))
        self.current = best
        self.events.append(
            ScalingEvent(now, lam, best.n_a, best.n_e, best.tpot, best.feasible)
        )
        return best

    # -- placement refresh -----------------------------------------------------
    def replan_layout(self, trace: np.ndarray, n_e: int):
        cfg = self.scaler.model.cfg
        return build_layout(trace, cfg.num_experts, n_e, self.scaler.model.C)

    # -- actuation --------------------------------------------------------------
    def actuate(self, engine, now: float, trace: Optional[np.ndarray] = None) -> EvalResult:
        """Decide and *apply*: reconfigure the engine's pools to the chosen
        (n_a, n_e), replanning expert placement from the routing trace when
        one is provided.  Only the pool whose count changed is re-lowered.
        Requires a disagg engine (checked before any controller state
        mutates) — use :meth:`decide` alone for advisory-only scaling."""
        cur = getattr(engine, "disagg", None)
        if cur is None:
            raise ValueError(
                "actuate requires ServingEngine(executor='disagg'); "
                "use decide() for advisory-only scaling"
            )
        m = engine.metrics()
        pages = m.get("kv_pages")
        if pages is not None:
            self._kv_obs.append((now, float(pages.get("occupancy", 0.0))))
        prefix = m.get("prefix_cache")
        if prefix is not None:
            self._prefix_saved_frac = float(prefix.get("saved_frac", 0.0))
        spec = m.get("spec")
        if spec is not None:
            self._spec_accept_rate = float(spec.get("accepted_per_step", 0.0))
        best = self.decide(now)
        # prefill devices only pay off under pipelined admission — a blocking
        # engine would keep stalling the decode clock no matter the pool size
        n_p = (
            self.decide_prefill(now)
            if getattr(engine, "admission", None) == "pipelined"
            else None
        )
        if self.events:
            self.events[-1] = dataclasses.replace(self.events[-1], n_p=n_p)
        changed_e = best.n_e != len(cur.pools.moe_devices)
        layout = (
            self.replan_layout(trace, best.n_e)
            if trace is not None and changed_e
            else None
        )
        engine.reconfigure(
            n_attn=best.n_a, n_moe=best.n_e, layout=layout, n_prefill=n_p
        )
        return best
