"""Prefill-pool worker: chunked prompt prefill with streamed KV hand-off.

The third Janus sub-cluster.  :class:`PrefillWorker` owns the prefill
devices (``DevicePools.prefill_devices``) — each holds a full model replica —
and drives an admission pipeline that overlaps prompt processing with the
decode loop instead of stalling it:

* the engine *reserves* a batch slot for an arrived request and submits it
  here; the request queues until a prefill device is free;
* the prompt is processed in fixed-size token chunks
  (:func:`repro.models.transformer.prefill_chunk` — bit-equivalent to
  whole-prompt prefill under ample expert capacity); architectures without
  chunked-prefill support (recurrent / encoder-decoder stacks) fall back to
  one whole-prompt call on the same pool;
* after every chunk, the chunk's KV slab is streamed into the decode-side
  batched caches through the engine-provided ``sink`` (mono: a sliced
  ``scatter_prefill_chunk_caches``; disagg: ``DisaggExecutor
  .scatter_prefill_chunk`` onto the owning attention shard) — the decode
  pool sees the cache fill up incrementally, and the hand-off never moves
  the whole prompt cache in one bulk transfer;
* when the last chunk lands, the request's first token (greedy over the
  final chunk's last-position logits) is returned to the engine, which flips
  the slot ``prefilling → active``.

Timing model: chunks are timed per call (wall clock, or ``prefill_time_fn``
when the engine runs a modeled clock) and accumulated on a *per-device pool
timeline* (``busy_until``) that runs concurrently with the engine's decode
clock — on disjoint hardware the two pools really do overlap; on shared host
devices the schedule (chunk order, per-device serialisation, completion
stamps) is the real one even though the arithmetic shares cores.  The engine
activates a finished request once its clock passes the completion stamp.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_mod
from repro.serving.request import Request


@dataclasses.dataclass
class PrefillEvent:
    """A finished prefill: returned by :meth:`PrefillWorker.poll`."""

    req: Request
    slot: int
    first_token: int
    finish_t: float  # completion stamp on the prefill-pool timeline


@dataclasses.dataclass
class _InFlight:
    req: Request
    slot: int
    dev_index: int
    prompt: np.ndarray
    caches: Optional[Dict[str, jax.Array]] = None  # per-request decode-format caches
    done: int = 0  # prompt tokens already prefilled (or served from cache)
    ready_t: float = 0.0  # pool-timeline moment the next chunk may start
    prefix: int = 0  # leading tokens served by the prefix cache (skipped here)
    seed: Optional[Dict] = None  # prefix KV rows [L, prefix, ...] to pre-load


class PrefillWorker:
    """Chunked prefill over a dedicated device pool + admission queue."""

    def __init__(
        self,
        cfg,
        params,
        devices: Optional[Sequence[jax.Device]] = None,
        *,
        cache_len: int,
        chunk: int = 64,
        extra: Optional[Dict] = None,
        prefill_time_fn: Optional[Callable[[int], float]] = None,
        max_chunks_per_poll: int = 1,
        batch: int = 1,
    ):
        self.cfg = cfg
        self.cache_len = cache_len
        self.chunk = max(1, int(chunk))
        if getattr(cfg, "sliding_window", None):
            # windowed layers attend [cache ⊕ chunk]: a chunk larger than the
            # rolling window would overwrite keys its own queries still need
            self.chunk = min(self.chunk, min(cache_len, cfg.sliding_window))
        self.extra = extra
        self.prefill_time_fn = prefill_time_fn
        self.max_chunks_per_poll = max(1, int(max_chunks_per_poll))
        self.chunked = model_mod.supports_chunked_prefill(cfg)
        # batched multi-prompt prefill: pack up to ``batch`` pending prompts
        # into one padded-and-masked prefill_chunk call per device, so short
        # prompts stop serialising behind long ones.  Row-independent by
        # construction (per-row starts/lengths mask), so streams stay
        # bit-identical to the one-at-a-time path.
        self.batch = max(1, int(batch))
        self.batched = self.batch > 1 and model_mod.supports_batched_prefill(cfg)
        self.chunks_done = 0
        # fault-injection hook (repro.serving.faults): called before each
        # chunk's compute with (slot, dev_index, chunk_ordinal); may raise
        # PoolFault.  None (the default) keeps the fault-free path untouched.
        self.fault_hook = None
        self.set_devices(devices, params)

        def _call_extra(n_tokens: int):
            """Drop-free MoE capacity by default: a ``None`` capacity becomes
            the call's own token count (an expert can receive at most that
            many tokens), so chunked and whole-prompt prefill both see zero
            drops — the regime where they are bit-equivalent.  ``n_tokens``
            is a static trace-time shape, so this costs no retraces."""
            extra = self.extra
            mc = (extra or {}).get("moe_ctx")
            if mc is not None and mc.get("capacity") is None:
                extra = dict(extra)
                extra["moe_ctx"] = dict(mc, capacity=n_tokens)
            return extra

        def _chunk_fn(p, toks, caches, start):
            return model_mod.prefill_chunk(
                p, toks, caches, start, cfg, extra=_call_extra(toks.shape[1])
            )

        def _full_fn(p, toks):
            return model_mod.prefill(
                p, toks, cfg, self.cache_len, extra=_call_extra(toks.shape[1])
            )

        def _batched_fn(p, toks, caches, starts, lengths):
            return model_mod.prefill_chunk_batched(
                p, toks, caches, starts, lengths, cfg,
                extra=_call_extra(toks.shape[0] * toks.shape[1]),
            )

        self._chunk_jit = jax.jit(_chunk_fn)
        self._full_jit = jax.jit(_full_fn)
        self._batch_jit = jax.jit(_batched_fn)

        self._queue: List[_InFlight] = []
        self._current: List[List[_InFlight]] = [[] for _ in self.devices]

    # ------------------------------------------------------------------
    # pool membership (reconfigure support)
    # ------------------------------------------------------------------
    def set_devices(self, devices: Optional[Sequence[jax.Device]], params) -> None:
        """(Re-)place the full-model replica on every pool device.  With an
        empty pool the worker degrades to the default device (prefill is then
        co-located with decode — the pre-disaggregation layout).  In-flight
        per-request caches migrate with their device index, so a mid-prefill
        pool resize never loses chunk progress."""
        devs = list(devices or [])
        if not devs:
            devs = [jax.devices()[0]]
        self.devices = devs
        self._params = [jax.device_put(params, d) for d in devs]
        # pool timeline survives a resize: a surviving device keeps the time
        # it already claimed (new devices start idle, which is exact)
        old_busy = getattr(self, "busy_until", [])
        self.busy_until = [
            old_busy[i] if i < len(old_busy) else 0.0 for i in range(len(devs))
        ]
        cur = getattr(self, "_current", None)
        if cur:  # migrate in-flight work into the resized pool
            carry = [e for group in cur for e in group]
            self._current = [[] for _ in devs]
            for e in carry:
                e.dev_index = min(e.dev_index, len(devs) - 1)
                if e.caches is not None:
                    e.caches = jax.device_put(e.caches, devs[e.dev_index])
                if len(self._current[e.dev_index]) < self.batch:
                    self._current[e.dev_index].append(e)
                else:
                    self._queue.insert(0, e)

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def submit(
        self,
        req: Request,
        slot: int,
        now: float,
        start: int = 0,
        seed_caches: Optional[Dict] = None,
    ) -> None:
        """Queue a reserved request for prefill (FIFO).  A prefix-cache hit
        passes ``start`` (chunk-aligned tokens already served by shared
        pages) and ``seed_caches`` (those positions' KV rows, ``[L, start,
        ...]`` per key) — the worker seeds its per-request cache with them
        and skips straight to the first cold chunk."""
        prompt = req.prompt
        if prompt is None:
            rng = np.random.default_rng(req.rid)
            prompt = rng.integers(0, self.cfg.vocab_size, size=req.input_len, dtype=np.int32)
        start = int(start)
        self._queue.append(
            _InFlight(
                req, slot, -1, np.asarray(prompt, np.int32),
                done=start, ready_t=now, prefix=start, seed=seed_caches,
            )
        )

    @property
    def num_pending(self) -> int:
        return len(self._queue) + sum(len(g) for g in self._current)

    # ------------------------------------------------------------------
    # fault recovery
    # ------------------------------------------------------------------
    def fail_device(self, dev_index: int) -> List[Request]:
        """A prefill device died: drop its in-flight entry's partial caches
        (they lived on the dead device — a real failure destroys them) and
        return the displaced requests for the engine to requeue from chunk 0.
        The device itself stays in ``self.devices`` until the engine resizes
        the pool (``set_devices`` / ``engine.reconfigure``)."""
        displaced: List[Request] = []
        if 0 <= dev_index < len(self._current):
            for entry in self._current[dev_index]:
                entry.caches = None
                entry.seed = None
                entry.done = entry.prefix = 0
                displaced.append(entry.req)
            self._current[dev_index] = []
        return displaced

    def cancel_slot(self, slot: int) -> Optional[Request]:
        """Withdraw a queued or in-flight request by slot (its streamed-out
        chunks were lost downstream); returns the request, or None if the
        worker no longer holds it."""
        for i, entry in enumerate(self._queue):
            if entry.slot == slot:
                self._queue.pop(i)
                return entry.req
        for di, group in enumerate(self._current):
            for entry in group:
                if entry.slot == slot:
                    group.remove(entry)
                    return entry.req
        return None

    def run_sync(self, prompt: np.ndarray, slot: int, sink) -> int:
        """Deterministic replay: prefill ``prompt`` synchronously on device 0
        and stream every chunk through ``sink``, bypassing the queue, the
        pool timeline *and* the fault hook (recovery work is not re-faulted).
        Chunk boundaries match the queued path (fixed size from 0), so the
        replayed KV slabs are bit-identical to what the original admission
        streamed.  Returns the first generated token (greedy)."""
        dev = self.devices[0]
        params = self._params[0]
        prompt = np.asarray(prompt, np.int32)
        n = len(prompt)
        if not self.chunked:
            toks = jax.device_put(jnp.asarray(prompt)[None, :], dev)
            logits, caches = self._full_jit(params, toks)
            sink(slot, 0, -1, caches)
            return int(np.argmax(np.asarray(logits[0])))
        caches = jax.device_put(
            model_mod.init_decode_caches(self.cfg, 1, self.cache_len), dev
        )
        logits = None
        for lo in range(0, n, self.chunk):
            hi = min(lo + self.chunk, n)
            toks = jax.device_put(jnp.asarray(prompt[lo:hi])[None, :], dev)
            logits, caches = self._chunk_jit(params, toks, caches, jnp.int32(lo))
            sink(slot, lo, hi - lo, caches)
        return int(np.argmax(np.asarray(logits[0])))

    # ------------------------------------------------------------------
    # the pipeline: one poll = at most ``max_chunks_per_poll`` chunks/device
    # ------------------------------------------------------------------
    def poll(self, sink: Callable[[int, int, int, Dict], None]) -> List[PrefillEvent]:
        """Advance prefill work and stream finished chunks through ``sink``.

        ``sink(slot, start, length, one_caches)`` lands the chunk's KV rows
        in the decode-side caches (``start``/``length`` index the position
        axis; ``length == -1`` marks a whole-prompt fallback cache).  Returns
        the requests whose prefill finished this poll, stamped with their
        pool-timeline completion times.
        """
        events: List[PrefillEvent] = []
        # groups of size 1 take the exact legacy one-at-a-time path; larger
        # groups (prefill_batch > 1 on a batchable architecture) fuse one
        # padded chunk call per device
        limit = self.batch if self.batched else 1
        for di in range(len(self.devices)):
            group = self._current[di]
            while len(group) < limit and self._queue:
                entry = self._queue.pop(0)
                if entry.caches is not None and entry.dev_index != di:
                    # a resize-displaced entry resumes on a different device:
                    # its partial caches must follow (params live per device)
                    entry.caches = jax.device_put(entry.caches, self.devices[di])
                entry.dev_index = di
                group.append(entry)
            if not group:
                continue
            for _ in range(self.max_chunks_per_poll):
                events.extend(self._advance_group(di, sink))
                if not self._current[di]:
                    break
        return events

    def _advance_group(self, di: int, sink) -> List[PrefillEvent]:
        group = self._current[di]
        if len(group) == 1:
            ev = self._advance(group[0], sink)
            if ev is not None:
                self._current[di] = []
                return [ev]
            return []
        return self._advance_batched(di, sink)

    def _init_caches(self, entry: _InFlight, dev) -> Dict[str, jax.Array]:
        """Fresh per-request caches; a prefix-cache hit pre-loads the shared
        rows so cold chunks attend the full ``[0, start + c)`` span."""
        caches = jax.device_put(
            model_mod.init_decode_caches(self.cfg, 1, self.cache_len), dev
        )
        if entry.seed:
            m = entry.prefix
            for k, rows in entry.seed.items():
                if k in caches:
                    caches[k] = caches[k].at[:, 0, :m].set(
                        jax.device_put(jnp.asarray(rows), dev).astype(caches[k].dtype)
                    )
            entry.seed = None
        return caches

    def _advance(self, entry: _InFlight, sink) -> Optional[PrefillEvent]:
        if self.fault_hook is not None:
            # before any compute or state mutation: a raise here leaves the
            # entry untouched, so a retry of the same poll is trivially safe
            self.fault_hook(entry.slot, entry.dev_index, self.chunks_done)
        dev = self.devices[entry.dev_index]
        params = self._params[entry.dev_index]
        n = len(entry.prompt)
        if not self.chunked:
            # whole-prompt fallback (recurrent / enc-dec stacks): one call on
            # the pool device, one bulk hand-off
            toks = jax.device_put(jnp.asarray(entry.prompt)[None, :], dev)
            t0 = time.perf_counter()
            logits, caches = self._full_jit(params, toks)
            logits.block_until_ready()
            dt = self.prefill_time_fn(n) if self.prefill_time_fn else time.perf_counter() - t0
            sink(entry.slot, 0, -1, caches)
            return self._finish(entry, logits, dt)

        lo = entry.done
        hi = min(lo + self.chunk, n)
        if entry.caches is None:
            entry.caches = self._init_caches(entry, dev)
        toks = jax.device_put(jnp.asarray(entry.prompt[lo:hi])[None, :], dev)
        t0 = time.perf_counter()
        logits, entry.caches = self._chunk_jit(params, toks, entry.caches, jnp.int32(lo))
        logits.block_until_ready()
        dt = (
            self.prefill_time_fn(hi - lo)
            if self.prefill_time_fn
            else time.perf_counter() - t0
        )
        sink(entry.slot, lo, hi - lo, entry.caches)
        entry.done = hi
        self.chunks_done += 1
        if hi < n:
            # pool-timeline accounting: the chunk starts as soon as both the
            # device and the request's previous chunk are done — the engine's
            # decode clock runs concurrently and is never charged
            start_t = max(self.busy_until[entry.dev_index], entry.ready_t)
            self.busy_until[entry.dev_index] = entry.ready_t = start_t + dt
            return None
        return self._finish(entry, logits, dt)

    def _finish(self, entry: _InFlight, logits, dt: float) -> PrefillEvent:
        start_t = max(self.busy_until[entry.dev_index], entry.ready_t)
        finish_t = start_t + dt
        self.busy_until[entry.dev_index] = finish_t
        first = int(np.argmax(np.asarray(logits[0])))
        entry.caches = None  # working copy dropped; KV already streamed out
        return PrefillEvent(entry.req, entry.slot, first, finish_t)

    def _advance_batched(self, di: int, sink) -> List[PrefillEvent]:
        """One fused chunk call for every request on device ``di``: each
        row's tokens are padded to the widest member chunk and masked by its
        own (start, length), so rows are computed exactly as the serial path
        would — one kernel launch instead of ``len(group)``.  The device
        timeline is charged once for the fused call (the batching win)."""
        group = self._current[di]
        if self.fault_hook is not None:
            for entry in group:
                self.fault_hook(entry.slot, entry.dev_index, self.chunks_done)
        dev = self.devices[di]
        params = self._params[di]
        for entry in group:
            if entry.caches is None:
                entry.caches = self._init_caches(entry, dev)
        B = len(group)
        los = [e.done for e in group]
        his = [min(e.done + self.chunk, len(e.prompt)) for e in group]
        lens = [hi - lo for lo, hi in zip(los, his)]
        cmax = max(lens)
        toks = np.zeros((B, cmax), np.int32)
        for i, e in enumerate(group):
            toks[i, : lens[i]] = e.prompt[los[i] : his[i]]
        keys = list(group[0].caches.keys())
        stacked = {
            k: jnp.concatenate([e.caches[k] for e in group], axis=1) for k in keys
        }
        toks_d = jax.device_put(jnp.asarray(toks), dev)
        starts = jax.device_put(jnp.asarray(los, jnp.int32), dev)
        lengths = jax.device_put(jnp.asarray(lens, jnp.int32), dev)
        t0 = time.perf_counter()
        logits, stacked = self._batch_jit(params, toks_d, stacked, starts, lengths)
        logits.block_until_ready()
        total = sum(lens)
        dt = (
            self.prefill_time_fn(total)
            if self.prefill_time_fn
            else time.perf_counter() - t0
        )
        start_t = max([self.busy_until[di]] + [e.ready_t for e in group])
        finish_t = start_t + dt
        self.busy_until[di] = finish_t
        events: List[PrefillEvent] = []
        logits_np: Optional[np.ndarray] = None
        remaining: List[_InFlight] = []
        for i, e in enumerate(group):
            e.caches = {k: stacked[k][:, i : i + 1] for k in keys}
            sink(e.slot, los[i], lens[i], e.caches)
            e.done = his[i]
            e.ready_t = finish_t
            self.chunks_done += 1
            if e.done >= len(e.prompt):
                if logits_np is None:
                    logits_np = np.asarray(logits)
                first = int(np.argmax(logits_np[i]))
                e.caches = None
                events.append(PrefillEvent(e.req, e.slot, first, finish_t))
            else:
                remaining.append(e)
        self._current[di] = remaining
        return events
