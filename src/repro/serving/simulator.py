"""Trace-driven cluster simulator (Fig. 11) — the paper's method for the
24-hour scaling study: "we evaluate scaling behavior through trace-driven
simulation using the measured performance of various systems".

Given a rate profile (15-minute windows), each policy picks a configuration
per window using the shared performance model; the simulator accumulates
GPU-hours and SLO attainment."""

from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core.baselines import (
    CoupledPolicy,
    FixedUnitPolicy,
    MonolithicPolicy,
    PolicyDecision,
)
from repro.core.scaling import PerfModel, SLOScaler


@dataclasses.dataclass
class WindowRecord:
    t: float
    demand: float
    n_a: int
    n_e: int
    total_gpus: int
    tpot: float
    slo_ok: bool


@dataclasses.dataclass
class SimResult:
    records: List[WindowRecord]

    @property
    def gpu_hours(self) -> float:
        if not self.records:
            return 0.0
        dt_h = np.diff([r.t for r in self.records] + [2 * self.records[-1].t - self.records[-2].t]).mean() / 3600.0
        return float(sum(r.total_gpus for r in self.records) * dt_h)

    @property
    def slo_attainment(self) -> float:
        if not self.records:
            return 0.0
        return float(np.mean([r.slo_ok for r in self.records]))


class ClusterSimulator:
    """Replays a rate profile through a scaling policy."""

    def __init__(self, model: PerfModel, slo: float, n_max: int = 32):
        self.model = model
        self.slo = slo
        self.n_max = n_max

    def run_janus(self, window_starts, rates, tokens_per_req: float) -> SimResult:
        scaler = SLOScaler(self.model, n_max=self.n_max)
        recs = []
        for t, r in zip(window_starts, rates):
            lam = r * tokens_per_req
            best = scaler.scale(lam, self.slo)
            if best is None:
                n_a = n_e = self.n_max
                ev = self.model.tpot(1.0, n_a, n_e)
                recs.append(WindowRecord(t, lam, n_a, n_e, n_a + n_e, ev.tpot, False))
            else:
                recs.append(
                    WindowRecord(t, lam, best.n_a, best.n_e, best.n_a + best.n_e, best.tpot, best.tpot <= self.slo)
                )
        return SimResult(recs)

    def run_policy(self, policy, window_starts, rates, tokens_per_req: float) -> SimResult:
        scaler = SLOScaler(self.model, n_max=self.n_max)
        recs = []
        for t, r in zip(window_starts, rates):
            lam = r * tokens_per_req
            d: PolicyDecision = policy.decide(scaler, lam, self.slo)
            ev = scaler.evaluate(lam, self.slo, d.n_a, d.n_e)
            tpot = ev.tpot if ev is not None else float("inf")
            recs.append(
                WindowRecord(t, lam, d.n_a, d.n_e, d.total_gpus, tpot, d.feasible and tpot <= self.slo)
            )
        return SimResult(recs)

    def compare(self, window_starts, rates, tokens_per_req: float) -> Dict[str, SimResult]:
        return {
            "janus": self.run_janus(window_starts, rates, tokens_per_req),
            "sglang": self.run_policy(MonolithicPolicy(), window_starts, rates, tokens_per_req),
            "megascale": self.run_policy(CoupledPolicy(), window_starts, rates, tokens_per_req),
            "xdeepserve": self.run_policy(FixedUnitPolicy(), window_starts, rates, tokens_per_req),
        }
