"""Trace-driven cluster simulator (Fig. 11) — the paper's method for the
24-hour scaling study: "we evaluate scaling behavior through trace-driven
simulation using the measured performance of various systems".

Given a rate profile (15-minute windows), each policy picks a configuration
per window using the shared performance model; the simulator accumulates
GPU-hours and SLO attainment.

Two demand paths, one workload:

* ``run_janus``/``run_policy``/``compare`` take a rate profile plus either a
  ``tokens_per_req`` scalar or a :class:`WorkloadSpec` — with a spec, the
  per-request token demand is measured through the *same* sampler
  ``sample_requests`` uses (``expected_tokens_per_request``), so the
  analytic simulator and the replayed engine see one distribution;
* ``replay`` takes a concrete request list (e.g. ``TraceSpec.build()``) and
  bins the requests' actual arrivals and sampled output lengths into
  windows — the million-request path: the identical workload the engine
  serves, pushed through every scaling policy.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.baselines import (
    CoupledPolicy,
    FixedUnitPolicy,
    MonolithicPolicy,
    PolicyDecision,
)
from repro.core.scaling import PerfModel, SLOScaler
from repro.serving.request import Request, WorkloadSpec, expected_tokens_per_request


@dataclasses.dataclass
class WindowRecord:
    t: float
    demand: float
    n_a: int
    n_e: int
    total_gpus: int
    tpot: float
    slo_ok: bool


@dataclasses.dataclass
class SimResult:
    records: List[WindowRecord]

    @property
    def gpu_hours(self) -> float:
        if not self.records:
            return 0.0
        dt_h = np.diff([r.t for r in self.records] + [2 * self.records[-1].t - self.records[-2].t]).mean() / 3600.0
        return float(sum(r.total_gpus for r in self.records) * dt_h)

    @property
    def slo_attainment(self) -> float:
        if not self.records:
            return 0.0
        return float(np.mean([r.slo_ok for r in self.records]))

    @property
    def mean_gpus(self) -> float:
        if not self.records:
            return 0.0
        return float(np.mean([r.total_gpus for r in self.records]))

    @property
    def slo_per_device(self) -> float:
        """SLO attainment per occupied device — the fig9 framing: a policy
        that attains the SLO with fewer devices scores higher than one that
        buys attainment with idle capacity."""
        return self.slo_attainment / max(self.mean_gpus, 1e-9)


class ClusterSimulator:
    """Replays a rate profile (or a concrete request list) through scaling
    policies."""

    def __init__(self, model: PerfModel, slo: float, n_max: int = 32):
        self.model = model
        self.slo = slo
        self.n_max = n_max

    # -- demand resolution ---------------------------------------------------
    def _tokens_per_req(
        self, tokens_per_req: Optional[float], spec: Optional[WorkloadSpec]
    ) -> float:
        if tokens_per_req is not None:
            return float(tokens_per_req)
        if spec is None:
            raise ValueError("pass tokens_per_req or a WorkloadSpec (spec=)")
        return expected_tokens_per_request(spec)

    # -- window engines ------------------------------------------------------
    def _run_windows(self, policy, window_starts, lams) -> SimResult:
        """One policy over per-window token demand ``lams`` (tokens/s).
        ``policy=None`` is the Janus SLOScaler (Algorithm 2); anything else
        is a baseline with a ``decide`` method."""
        scaler = SLOScaler(self.model, n_max=self.n_max)
        recs = []
        for t, lam in zip(window_starts, lams):
            if policy is None:
                best = scaler.scale(lam, self.slo)
                if best is None:
                    n_a = n_e = self.n_max
                    ev = self.model.tpot(1.0, n_a, n_e)
                    recs.append(
                        WindowRecord(t, lam, n_a, n_e, n_a + n_e, ev.tpot, False)
                    )
                else:
                    recs.append(
                        WindowRecord(
                            t, lam, best.n_a, best.n_e, best.n_a + best.n_e,
                            best.tpot, best.tpot <= self.slo,
                        )
                    )
            else:
                d: PolicyDecision = policy.decide(scaler, lam, self.slo)
                ev = scaler.evaluate(lam, self.slo, d.n_a, d.n_e)
                tpot = ev.tpot if ev is not None else float("inf")
                recs.append(
                    WindowRecord(
                        t, lam, d.n_a, d.n_e, d.total_gpus, tpot,
                        d.feasible and tpot <= self.slo,
                    )
                )
        return SimResult(recs)

    # -- rate-profile API ----------------------------------------------------
    def run_janus(
        self,
        window_starts,
        rates,
        tokens_per_req: Optional[float] = None,
        spec: Optional[WorkloadSpec] = None,
    ) -> SimResult:
        tpr = self._tokens_per_req(tokens_per_req, spec)
        return self._run_windows(None, window_starts, np.asarray(rates) * tpr)

    def run_policy(
        self,
        policy,
        window_starts,
        rates,
        tokens_per_req: Optional[float] = None,
        spec: Optional[WorkloadSpec] = None,
    ) -> SimResult:
        tpr = self._tokens_per_req(tokens_per_req, spec)
        return self._run_windows(policy, window_starts, np.asarray(rates) * tpr)

    def compare(
        self,
        window_starts,
        rates,
        tokens_per_req: Optional[float] = None,
        spec: Optional[WorkloadSpec] = None,
    ) -> Dict[str, SimResult]:
        tpr = self._tokens_per_req(tokens_per_req, spec)
        lams = np.asarray(rates) * tpr
        return {
            "janus": self._run_windows(None, window_starts, lams),
            "sglang": self._run_windows(MonolithicPolicy(), window_starts, lams),
            "megascale": self._run_windows(CoupledPolicy(), window_starts, lams),
            "xdeepserve": self._run_windows(FixedUnitPolicy(), window_starts, lams),
        }

    # -- request-replay API --------------------------------------------------
    @staticmethod
    def window_demand(
        requests: Sequence[Request], window_s: float
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Bin a concrete request list into ``window_s``-second windows of
        token demand (tokens/s): each request contributes its *sampled*
        output length to its arrival window — no re-sampling, no drift from
        what the engine actually serves."""
        if not requests:
            return np.array([]), np.array([])
        t_end = max(r.arrival for r in requests)
        n = max(1, int(np.ceil((t_end + 1e-9) / window_s)))
        starts = np.arange(n) * window_s
        toks = np.zeros(n)
        for r in requests:
            toks[min(n - 1, int(r.arrival // window_s))] += r.output_len
        return starts, toks / window_s

    def replay(
        self, requests: Sequence[Request], window_s: float = 60.0
    ) -> Dict[str, SimResult]:
        """Replay a request list (e.g. ``TraceSpec.build()`` — the same list
        the engine serves) through every scaling policy."""
        starts, lams = self.window_demand(requests, window_s)
        return {
            "janus": self._run_windows(None, starts, lams),
            "sglang": self._run_windows(MonolithicPolicy(), starts, lams),
            "megascale": self._run_windows(CoupledPolicy(), starts, lams),
            "xdeepserve": self._run_windows(FixedUnitPolicy(), starts, lams),
        }
