"""Pure-jnp oracle for the AEBS Pallas kernel.

Semantics are exactly :func:`repro.core.aebs.aebs_assign` (Algorithm 1), with
the kernel's -1-padded-item convention added: padded items (eid < 0) do not
activate experts and map to slot -1.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aebs import aebs_assign


def aebs_ref(eids: jax.Array, hosts: jax.Array, counts: jax.Array, slot_of: jax.Array):
    """Returns (slot_ids [T,k], load [n_e], act_rep [E])."""
    n_e = slot_of.shape[1]
    valid = eids >= 0
    safe = jnp.where(valid, eids, 0)
    # mask padded rows out of the activation union by pointing them at an
    # impossible value: rebuild activation from valid entries only
    tables = {"expert_hosts": hosts, "replica_counts": counts, "slot_of": slot_of}
    # aebs_assign builds the activated set from all entries; neutralise pads
    # by replacing them with a valid eid *only if* that eid is independently
    # activated — instead, do it exactly: compute with a filtered scatter.
    E = hosts.shape[0]
    act = jnp.zeros(E, bool).at[jnp.where(valid, eids, E)].set(True, mode="drop")

    # re-implement the two passes against the explicit activation mask
    def assign_pass(carry, want_multi):
        def body(e, c):
            load, rep = c
            is_multi = counts[e] > 1
            eligible = act[e] & (is_multi == want_multi) & (counts[e] >= 1)
            row = hosts[e]
            row_load = jnp.where(row >= 0, load[jnp.maximum(row, 0)], jnp.int32(2**30))
            sel = jnp.argmin(row_load)
            g = jnp.maximum(row[sel], 0)
            slot = slot_of[e, g]
            load = jnp.where(eligible, load.at[g].add(1), load)
            rep = rep.at[e].set(jnp.where(eligible, slot, rep[e]))
            return (load, rep)

        return jax.lax.fori_loop(0, E, body, carry)

    load0 = jnp.zeros((n_e,), jnp.int32)
    rep0 = jnp.full((E,), -1, jnp.int32)
    l1, r1 = assign_pass((load0, rep0), False)
    l2, r2 = assign_pass((l1, r1), True)
    slot_ids = jnp.where(valid, r2[safe], -1)
    return slot_ids, l2, r2
