"""AEBS as a Pallas TPU kernel — the paper's §3.4 GPU kernel, TPU-native.

Design (DESIGN.md §6): the scheduling workflow is two ``pallas_call``s.

Kernel 1 (``_collect_and_greedy``) — grid over token blocks:
  * stage 1 (token-parallel, VPU): each block folds its activated-expert
    bitmap into a VMEM scratch accumulator via max (grid iterations on TPU
    run sequentially per core, so scratch accumulation is well-defined);
  * stage 2 (sequential, final grid step only): the greedy two-pass replica
    selection of Algorithm 1 over ≤E experts (E ≤ 512 — a scalar-ish loop,
    negligible next to the MoE GEMMs), producing ``act_rep`` and ``load``.

Kernel 2 (``_rewrite``) — grid over token blocks: rewrite per-token logical
EIDs to physical replica slots.  The gather is expressed as a one-hot matmul
(MXU-friendly, avoids relying on dynamic-gather lowering support).

Both run identically on every MoE shard — Janus's synchronisation-free
redundant-compute trick carries over unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

def _collect_and_greedy_kernel(
    eids_ref,  # [TB, k] int32 block (padded items = -1)
    hosts_ref,  # [E, R] int32
    counts_ref,  # [E, 1] int32
    slot_of_ref,  # [E, n_e] int32
    actrep_ref,  # out [E, 1] int32
    load_ref,  # out [n_e, 1] int32
    act_scratch,  # VMEM scratch [E, 1] int32
    *,
    num_blocks: int,
):
    i = pl.program_id(0)
    E = hosts_ref.shape[0]
    n_e = slot_of_ref.shape[1]

    @pl.when(i == 0)
    def _init():
        act_scratch[...] = jnp.zeros_like(act_scratch)

    # ---- stage 1: token-parallel activation bitmap ----
    blk = eids_ref[...]  # [TB, k]
    eye = jax.lax.broadcasted_iota(jnp.int32, (1, 1, E), 2)
    hits = (blk[:, :, None] == eye).any(axis=(0, 1))  # [E] bool
    act_scratch[...] = jnp.maximum(act_scratch[...], hits.astype(jnp.int32)[:, None])

    # ---- stage 2: greedy two-pass assignment (Algorithm 1), last block ----
    @pl.when(i == num_blocks - 1)
    def _greedy():
        act = act_scratch[...][:, 0]  # [E]
        hosts = hosts_ref[...]  # [E, R]
        counts = counts_ref[...][:, 0]  # [E]
        slot_of = slot_of_ref[...]  # [E, n_e]

        def assign_pass(carry, want_multi):
            def body(e, c):
                load, rep = c
                is_multi = counts[e] > 1
                eligible = (act[e] > 0) & (is_multi == want_multi) & (counts[e] >= 1)
                row = hosts[e]  # [R]
                row_load = jnp.where(row >= 0, load[jnp.maximum(row, 0)], jnp.int32(2**30))
                sel = jnp.argmin(row_load)
                g = jnp.maximum(row[sel], 0)
                slot = slot_of[e, g]
                load = jnp.where(eligible, load.at[g].add(1), load)
                rep = rep.at[e].set(jnp.where(eligible, slot, rep[e]))
                return (load, rep)

            return jax.lax.fori_loop(0, E, body, carry)

        load0 = jnp.zeros((n_e,), jnp.int32)
        rep0 = jnp.full((E,), -1, jnp.int32)
        load1, rep1 = assign_pass((load0, rep0), False)
        load2, rep2 = assign_pass((load1, rep1), True)
        actrep_ref[...] = rep2[:, None]
        load_ref[...] = load2[:, None]


def _rewrite_kernel(eids_ref, actrep_ref, out_ref):
    """slot_ids = act_rep[eids] via one-hot matmul (exact for values < 2^24)."""
    blk = eids_ref[...]  # [TB, k]
    E = actrep_ref.shape[0]
    tb, k = blk.shape
    eye = jax.lax.broadcasted_iota(jnp.int32, (tb * k, E), 1)
    oh = (blk.reshape(tb * k, 1) == eye).astype(jnp.float32)
    rep = actrep_ref[...][:, 0].astype(jnp.float32)  # [E]
    vals = jnp.dot(oh, rep[:, None], preferred_element_type=jnp.float32)  # [tb*k, 1]
    invalid = blk.reshape(tb * k, 1) < 0
    out = jnp.where(invalid, -1.0, vals).astype(jnp.int32)
    out_ref[...] = out.reshape(tb, k)


def aebs_pallas(
    eids: jax.Array,  # [T, k] int32 (pad items with -1)
    hosts: jax.Array,  # [E, R]
    counts: jax.Array,  # [E]
    slot_of: jax.Array,  # [E, n_e]
    *,
    block_tokens: int = 256,
    interpret: bool = True,
):
    """Returns (slot_ids [T, k], load [n_e], act_rep [E])."""
    T, k = eids.shape
    E, n_e = slot_of.shape
    TB = min(block_tokens, T)
    pad = (-T) % TB
    if pad:
        eids = jnp.concatenate([eids, jnp.full((pad, k), -1, jnp.int32)], axis=0)
    Tp = eids.shape[0]
    num_blocks = Tp // TB

    actrep, load = pl.pallas_call(
        functools.partial(_collect_and_greedy_kernel, num_blocks=num_blocks),
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((TB, k), lambda i: (i, 0)),
            pl.BlockSpec((E, hosts.shape[1]), lambda i: (0, 0)),
            pl.BlockSpec((E, 1), lambda i: (0, 0)),
            pl.BlockSpec((E, n_e), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((E, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_e, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_e, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((E, 1), jnp.int32)],
        interpret=interpret,
    )(eids, hosts, counts[:, None], slot_of)

    slot_ids = pl.pallas_call(
        _rewrite_kernel,
        grid=(num_blocks,),
        in_specs=[
            pl.BlockSpec((TB, k), lambda i: (i, 0)),
            pl.BlockSpec((E, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TB, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, k), jnp.int32),
        interpret=interpret,
    )(eids, actrep)

    return slot_ids[:T], load[:, 0], actrep[:, 0]
