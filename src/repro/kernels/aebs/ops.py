"""Jit'd public wrapper for the AEBS kernel.

On CPU (this container) the kernel body executes via ``interpret=True``;
on TPU it compiles to Mosaic.  The wrapper handles padding and exposes the
same (slot_ids, load, act_rep) contract as ``repro.core.aebs.aebs_assign``.
"""

from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.aebs.kernel import aebs_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("num_instances", "block_tokens"))
def aebs_schedule(
    eids: jax.Array,
    tables: Dict[str, jax.Array],
    num_instances: int,
    block_tokens: int = 256,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    return aebs_pallas(
        eids,
        tables["expert_hosts"],
        tables["replica_counts"],
        tables["slot_of"],
        block_tokens=block_tokens,
        interpret=not _on_tpu(),
    )


# same Algorithm-1 semantics as aebs_assign: one replica per activated expert
aebs_schedule.single_active_replica = True
