"""Jit'd wrapper for the flash-decode attention kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import (
    decode_attention_int8_pallas,
    decode_attention_pallas,
)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_kv", "logit_cap"))
def decode_attention(q, k_cache, v_cache, valid_len, block_kv: int = 512, logit_cap: float = 0.0):
    S = k_cache.shape[1]
    bk = min(block_kv, S)
    while S % bk:
        bk //= 2
    return decode_attention_pallas(
        q, k_cache, v_cache, valid_len,
        block_kv=bk, logit_cap=logit_cap, interpret=not _on_tpu(),
    )


@functools.partial(jax.jit, static_argnames=("block_kv", "logit_cap"))
def decode_attention_int8(
    q, k_cache, v_cache, k_scale, v_scale, valid_len,
    block_kv: int = 512, logit_cap: float = 0.0,
):
    """Flash decode over an int8 KV cache (in-VMEM dequantisation)."""
    S = k_cache.shape[1]
    bk = min(block_kv, S)
    while S % bk:
        bk //= 2
    return decode_attention_int8_pallas(
        q, k_cache, v_cache, k_scale, v_scale, valid_len,
        block_kv=bk, logit_cap=logit_cap, interpret=not _on_tpu(),
    )
