"""Jit'd wrapper for the flash-decode attention kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.decode_attention.kernel import (
    decode_attention_int8_pallas,
    decode_attention_pallas,
    paged_decode_attention_pallas,
)
from repro.kernels.decode_attention.ref import paged_decode_attention_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_kv", "logit_cap"))
def decode_attention(q, k_cache, v_cache, valid_len, block_kv: int = 512, logit_cap: float = 0.0):
    S = k_cache.shape[1]
    bk = min(block_kv, S)
    while S % bk:
        bk //= 2
    return decode_attention_pallas(
        q, k_cache, v_cache, valid_len,
        block_kv=bk, logit_cap=logit_cap, interpret=not _on_tpu(),
    )


@functools.partial(jax.jit, static_argnames=("block_kv", "logit_cap"))
def decode_attention_int8(
    q, k_cache, v_cache, k_scale, v_scale, valid_len,
    block_kv: int = 512, logit_cap: float = 0.0,
):
    """Flash decode over an int8 KV cache (in-VMEM dequantisation)."""
    S = k_cache.shape[1]
    bk = min(block_kv, S)
    while S % bk:
        bk //= 2
    return decode_attention_int8_pallas(
        q, k_cache, v_cache, k_scale, v_scale, valid_len,
        block_kv=bk, logit_cap=logit_cap, interpret=not _on_tpu(),
    )


@functools.partial(jax.jit, static_argnames=("logit_cap", "backend"))
def paged_decode_attention(
    q, k_pages, v_pages, block_tables, lengths,
    logit_cap: float = 0.0, backend: str = "pallas",
):
    """Paged flash decode over a block-table-indirect page pool.

    ``backend="jnp"`` selects the gather-based fallback (the oracle) for
    platforms without a Pallas lowering; the default runs the kernel,
    interpreted off-TPU."""
    if backend == "jnp":
        return paged_decode_attention_ref(
            q, k_pages, v_pages, block_tables, lengths, logit_cap=logit_cap
        )
    return paged_decode_attention_pallas(
        q, k_pages, v_pages, block_tables, lengths,
        logit_cap=logit_cap, interpret=not _on_tpu(),
    )
