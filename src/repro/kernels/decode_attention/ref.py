"""Pure-jnp oracle for the flash-decode attention kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(
    q: jax.Array,  # [B, nh, hd]
    k_cache: jax.Array,  # [B, S, nkv, hd]
    v_cache: jax.Array,
    valid_len: jax.Array,  # scalar
    logit_cap: float = 0.0,
) -> jax.Array:
    B, nh, hd = q.shape
    S, nkv = k_cache.shape[1], k_cache.shape[2]
    G = nh // nkv
    qg = q.reshape(B, nkv, G, hd).astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    s = jnp.einsum("bngh,bsnh->bngs", qg, k) * (hd**-0.5)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    mask = jnp.arange(S) < valid_len
    s = jnp.where(mask[None, None, None, :], s, -1.0e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngs,bsnh->bngh", p, v)
    return o.reshape(B, nh, hd).astype(q.dtype)


def decode_attention_int8_ref(q, k_cache, v_cache, k_scale, v_scale, valid_len, logit_cap: float = 0.0):
    """Oracle: dequantise the int8 cache, then full-precision attention."""
    k = k_cache.astype(jnp.float32) * k_scale[..., None].astype(jnp.float32)
    v = v_cache.astype(jnp.float32) * v_scale[..., None].astype(jnp.float32)
    return decode_attention_ref(q, k.astype(q.dtype), v.astype(q.dtype), valid_len, logit_cap)


def paged_decode_attention_ref(
    q: jax.Array,  # [B, nh, hd]
    k_pages: jax.Array,  # [P, ps, nkv, hd]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [B, nblk] int32
    lengths: jax.Array,  # [B] int32 — per-slot valid lengths
    logit_cap: float = 0.0,
) -> jax.Array:
    """Oracle for the paged kernel: gather each slot's pages into the dense
    [B, S, nkv, hd] view (S = nblk · ps) and run per-slot masked attention."""
    B, nh, hd = q.shape
    ps, nkv = k_pages.shape[1], k_pages.shape[2]
    nblk = block_tables.shape[1]
    S = nblk * ps
    k = k_pages[block_tables].reshape(B, S, nkv, hd).astype(jnp.float32)
    v = v_pages[block_tables].reshape(B, S, nkv, hd).astype(jnp.float32)
    G = nh // nkv
    qg = q.reshape(B, nkv, G, hd).astype(jnp.float32)
    s = jnp.einsum("bngh,bsnh->bngs", qg, k) * (hd**-0.5)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    mask = jnp.arange(S)[None, :] < lengths[:, None]  # [B, S]
    s = jnp.where(mask[:, None, None, :], s, -1.0e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngs,bsnh->bngh", p, v)
    return o.reshape(B, nh, hd).astype(q.dtype)
