"""Flash-decode GQA attention Pallas kernel (decode_32k / long_500k path).

One query token per sequence against a long KV cache:

  grid = (batch, kv_heads, kv_blocks)   (kv_blocks innermost → sequential)

Per (b, h): the G query heads sharing kv-head h stream KV blocks from HBM
through VMEM, maintaining the online-softmax running max / denominator /
accumulator in VMEM scratch.  Positions ≥ valid_len are masked.  The final
block normalises and writes the [G, head_dim] output tile.

This is the memory-bound half of decode (KV bytes dominate); the roofline
term it addresses is c_kv·b·S_ctx of Eq. 1b.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e30


def _decode_attn_kernel(
    valid_ref,  # [1, 1] int32 — number of valid cache entries
    q_ref,  # [1, 1, G, hd]
    k_ref,  # [1, SB, 1, hd]
    v_ref,  # [1, SB, 1, hd]
    o_ref,  # [1, 1, G, hd]
    m_scr,  # VMEM [G, 1] f32
    l_scr,  # VMEM [G, 1] f32
    acc_scr,  # VMEM [G, hd] f32
    *,
    num_kv_blocks: int,
    block_kv: int,
    logit_cap: float,
):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, 0].astype(jnp.float32)  # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)  # [SB, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)  # [SB, hd]
    hd = q.shape[-1]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (hd**-0.5)  # [G, SB]
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    pos = sb * block_kv + jax.lax.broadcasted_iota(jnp.int32, (1, block_kv), 1)
    s = jnp.where(pos < valid_ref[0, 0], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]  # [G,1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # [G, SB]
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(sb == num_kv_blocks - 1)
    def _finish():
        o_ref[0, 0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def _decode_attn_int8_kernel(
    valid_ref,  # [1, 1] int32
    q_ref,  # [1, 1, 1, G, hd]
    k_ref,  # [1, SB, 1, hd] int8
    v_ref,  # [1, SB, 1, hd] int8
    ks_ref,  # [1, SB, 1] f32 — per-(token, head) scales
    vs_ref,  # [1, SB, 1] f32
    o_ref,  # [1, 1, 1, G, hd]
    m_scr,
    l_scr,
    acc_scr,
    *,
    num_kv_blocks: int,
    block_kv: int,
    logit_cap: float,
):
    """int8-KV flash decode: dequantisation happens in VMEM, fused into the
    streaming loop — HBM sees only int8 cache bytes (the §Perf P3b fix)."""
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, 0].astype(jnp.float32)  # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32) * ks_ref[0, :, 0][:, None]  # [SB, hd]
    v = v_ref[0, :, 0].astype(jnp.float32) * vs_ref[0, :, 0][:, None]
    hd = q.shape[-1]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (hd**-0.5)
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    pos = sb * block_kv + jax.lax.broadcasted_iota(jnp.int32, (1, block_kv), 1)
    s = jnp.where(pos < valid_ref[0, 0], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(sb == num_kv_blocks - 1)
    def _finish():
        o_ref[0, 0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_int8_pallas(
    q: jax.Array,  # [B, n_heads, hd]
    k_cache: jax.Array,  # [B, S, n_kv, hd] int8
    v_cache: jax.Array,
    k_scale: jax.Array,  # [B, S, n_kv] f32
    v_scale: jax.Array,
    valid_len: jax.Array,
    *,
    block_kv: int = 512,
    logit_cap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    B, nh, hd = q.shape
    _, S, nkv, _ = k_cache.shape
    G = nh // nkv
    SB = min(block_kv, S)
    if S % SB:
        raise ValueError(f"cache len {S} not divisible by block_kv {SB}")
    nblk = S // SB
    qg = q.reshape(B, nkv, G, hd)[:, :, None, :, :]
    valid = jnp.broadcast_to(valid_len.astype(jnp.int32), (1, 1))

    out = pl.pallas_call(
        functools.partial(
            _decode_attn_int8_kernel,
            num_kv_blocks=nblk,
            block_kv=SB,
            logit_cap=logit_cap,
        ),
        grid=(B, nkv, nblk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (0, 0)),
            pl.BlockSpec((1, 1, 1, G, hd), lambda b, h, s: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, SB, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, SB, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, SB, 1), lambda b, h, s: (b, s, h)),
            pl.BlockSpec((1, SB, 1), lambda b, h, s: (b, s, h)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, G, hd), lambda b, h, s: (b, h, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nkv, 1, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(valid, qg, k_cache, v_cache, k_scale, v_scale)

    return out.reshape(B, nkv, G, hd).reshape(B, nh, hd)


def _paged_decode_attn_kernel(
    bt_ref,  # scalar-prefetch [B, nblk] int32 — per-slot block table
    len_ref,  # scalar-prefetch [B] int32 — per-slot valid lengths
    q_ref,  # [1, 1, 1, G, hd]
    k_ref,  # [1, ps, 1, hd] — page bt[b, j] of the pool
    v_ref,  # [1, ps, 1, hd]
    o_ref,  # [1, 1, 1, G, hd]
    m_scr,  # VMEM [G, 1] f32
    l_scr,  # VMEM [G, 1] f32
    acc_scr,  # VMEM [G, hd] f32
    *,
    num_kv_blocks: int,
    page_size: int,
    logit_cap: float,
):
    """Page-indirect flash decode: the grid walks each slot's *virtual* KV
    blocks in order, and the scalar-prefetched block table redirects the K/V
    BlockSpecs to the physical page (the slot-indirect `expert_ffn` idiom).
    Unbacked table entries point at the null page; per-slot ``len_ref``
    masking zeroes whatever garbage lives there."""
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0, 0].astype(jnp.float32)  # [G, hd]
    k = k_ref[0, :, 0].astype(jnp.float32)  # [ps, hd]
    v = v_ref[0, :, 0].astype(jnp.float32)  # [ps, hd]
    hd = q.shape[-1]

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * (hd**-0.5)  # [G, ps]
    if logit_cap > 0.0:
        s = logit_cap * jnp.tanh(s / logit_cap)
    pos = j * page_size + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev, l_prev = m_scr[...], l_scr[...]  # [G,1]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)  # [G, ps]
    l_new = l_prev * corr + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == num_kv_blocks - 1)
    def _finish():
        o_ref[0, 0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_attention_pallas(
    q: jax.Array,  # [B, n_heads, hd] — one token per sequence
    k_pages: jax.Array,  # [P, ps, n_kv, hd] — page pool
    v_pages: jax.Array,  # [P, ps, n_kv, hd]
    block_tables: jax.Array,  # [B, nblk] int32 — slot → page map
    lengths: jax.Array,  # [B] int32 — per-slot valid lengths
    *,
    logit_cap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    """Paged flash decode.  Returns attention output [B, n_heads, hd].

    Virtual block j of slot b streams physical page ``block_tables[b, j]``
    through VMEM; pages are the KV blocks (block_kv == page_size), so the
    online-softmax loop is identical to the contiguous kernel's."""
    B, nh, hd = q.shape
    P, ps, nkv, _ = k_pages.shape
    G = nh // nkv
    nblk = block_tables.shape[1]
    qg = q.reshape(B, nkv, G, hd)[:, :, None, :, :]  # [B, nkv, 1, G, hd]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, nkv, nblk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, G, hd), lambda b, h, j, bt, ln: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, ps, 1, hd), lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
            pl.BlockSpec((1, ps, 1, hd), lambda b, h, j, bt, ln: (bt[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, G, hd), lambda b, h, j, bt, ln: (b, h, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(
            _paged_decode_attn_kernel,
            num_kv_blocks=nblk,
            page_size=ps,
            logit_cap=logit_cap,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nkv, 1, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), qg, k_pages, v_pages)

    return out.reshape(B, nkv, G, hd).reshape(B, nh, hd)


def decode_attention_pallas(
    q: jax.Array,  # [B, n_heads, hd] — one token per sequence
    k_cache: jax.Array,  # [B, S, n_kv, hd]
    v_cache: jax.Array,  # [B, S, n_kv, hd]
    valid_len: jax.Array,  # scalar int32 (entries < valid_len attend)
    *,
    block_kv: int = 512,
    logit_cap: float = 0.0,
    interpret: bool = True,
) -> jax.Array:
    """Returns attention output [B, n_heads, hd]."""
    B, nh, hd = q.shape
    _, S, nkv, _ = k_cache.shape
    G = nh // nkv
    SB = min(block_kv, S)
    if S % SB:
        raise ValueError(f"cache len {S} not divisible by block_kv {SB}")
    nblk = S // SB
    qg = q.reshape(B, nkv, G, hd)[:, :, None, :, :]  # [B, nkv, 1, G, hd]
    valid = jnp.broadcast_to(valid_len.astype(jnp.int32), (1, 1))

    out = pl.pallas_call(
        functools.partial(
            _decode_attn_kernel,
            num_kv_blocks=nblk,
            block_kv=SB,
            logit_cap=logit_cap,
        ),
        grid=(B, nkv, nblk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (0, 0)),
            pl.BlockSpec((1, 1, 1, G, hd), lambda b, h, s: (b, h, 0, 0, 0)),
            pl.BlockSpec((1, SB, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, SB, 1, hd), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, G, hd), lambda b, h, s: (b, h, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nkv, 1, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(valid, qg, k_cache, v_cache)

    return out.reshape(B, nkv, G, hd).reshape(B, nh, hd)
