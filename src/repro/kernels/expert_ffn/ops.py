"""Jit'd wrapper for the grouped expert-FFN kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.expert_ffn.kernel import expert_ffn_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("ff_tile",))
def expert_ffn(x, w_gate, w_up, w_down, active, ff_tile: int = 512):
    f = w_gate.shape[-1]
    ft = ff_tile
    while f % ft:
        ft //= 2
    return expert_ffn_pallas(
        x, w_gate, w_up, w_down, active, ff_tile=ft, interpret=not _on_tpu()
    )
