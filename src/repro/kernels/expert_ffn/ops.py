"""Jit'd wrappers for the grouped expert-FFN kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.expert_ffn.kernel import expert_ffn_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _ff_tile(f: int, ff_tile: int) -> int:
    ft = ff_tile
    while f % ft:
        ft //= 2
    return ft


@functools.partial(jax.jit, static_argnames=("ff_tile",))
def expert_ffn(x, w_gate, w_up, w_down, active, ff_tile: int = 512):
    """Stacked-weights form: weights [S, d, f], one slab per slot."""
    return expert_ffn_pallas(
        x, w_gate, w_up, w_down, active,
        ff_tile=_ff_tile(w_gate.shape[-1], ff_tile), interpret=not _on_tpu(),
    )


@functools.partial(jax.jit, static_argnames=("ff_tile",))
def expert_ffn_grouped(x, w_gate, w_up, w_down, slot_to_expert, active, ff_tile: int = 512):
    """Slot-indirect form: logical weights [E, d, f] + flat slot→expert map.

    No per-slot weight copy is ever materialised — the kernel's BlockSpec
    index_maps dereference ``slot_to_expert`` (a scalar-prefetch operand)
    to stream each activated slot's expert weights directly.
    """
    return expert_ffn_pallas(
        x, w_gate, w_up, w_down, active, slot_to_expert,
        ff_tile=_ff_tile(w_gate.shape[-1], ff_tile), interpret=not _on_tpu(),
    )
