"""Pure-jnp oracles for the grouped expert-FFN kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def expert_ffn_ref(
    x: jax.Array,  # [S, CAP, d]
    w_gate: jax.Array,  # [S, d, f]
    w_up: jax.Array,
    w_down: jax.Array,  # [S, f, d]
    active: jax.Array,  # [S]
) -> jax.Array:
    g = jnp.einsum("scd,sdf->scf", x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.einsum("scd,sdf->scf", x, w_up, preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    y = jnp.einsum("scf,sfd->scd", h, w_down, preferred_element_type=jnp.float32)
    mask = (active.astype(jnp.int32) > 0)[:, None, None]
    return jnp.where(mask, y, 0.0).astype(x.dtype)


def expert_ffn_grouped_ref(
    x: jax.Array,  # [S, CAP, d]
    w_gate: jax.Array,  # [E, d, f] logical
    w_up: jax.Array,
    w_down: jax.Array,  # [E, f, d]
    slot_to_expert: jax.Array,  # [S] int32, -1 → inactive
    active: jax.Array,  # [S]
) -> jax.Array:
    """Oracle for the slot-indirect kernel (the oracle may gather; the kernel
    must not)."""
    idx = jnp.maximum(slot_to_expert, 0)
    act = active.astype(jnp.int32) * (slot_to_expert >= 0)
    return expert_ffn_ref(x, w_gate[idx], w_up[idx], w_down[idx], act)
