"""Grouped expert-FFN Pallas kernel — the MoE hot loop (§2.2 / Fig. 2).

The paper's central performance fact is that MoE-layer latency is set by the
number of *distinct activated experts* per instance, because each activated
expert's weights must be streamed from HBM regardless of its token count.
This kernel makes that structure explicit on TPU:

  grid = (num_slots, d_ff_tiles)
  * weights are read *slot-indirectly*: the flat ``slot_to_expert`` map is a
    scalar-prefetch operand and the weight BlockSpec index_maps dereference it,
    so the kernel streams gate/up/down blocks straight out of the logical
    ``[E, d, f]`` arrays — replica slots never materialise a weight copy;
  * inactive expert slots skip all compute via ``@pl.when`` (their weight
    index_maps degenerate to expert 0's blocks, which the pipeline elides
    for consecutive inactive steps), so per-instance FLOPs ∝ activated-slot
    count — the β·a_max model of Eq. 1c.  Hosts where a compiled kernel is
    unavailable get the same activated-only behaviour from the stream-loop
    fallback (``repro.models.moe.stream_slot_ffn``), which iterates over
    active slots exclusively;
  * active slots run a double GEMM (gate/up) + SwiGLU + down-projection over
    their capacity-packed token block, tiled along d_ff so every working set
    fits VMEM with MXU-aligned (multiples of 128) matmul dims;
  * the down-projection accumulates across d_ff tiles into the output block
    (the d_ff grid axis iterates innermost → sequential on TPU).

When ``slot_to_expert`` is the identity the kernel degenerates to the old
stacked-weights form (weights [S, d, f], one slab per slot), which is how the
pinned-replica deployment path (launch.steps.materialize_slot_params) and the
pre-existing tests drive it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _expert_ffn_kernel(
    s2e_ref,  # [S] int32 scalar-prefetch — slot → logical expert
    active_ref,  # [S] int32 scalar-prefetch — slot activation bitmap
    x_ref,  # [1, CAP, d]
    wg_ref,  # [1, d, FT]  (block of w_gate[s2e[s]])
    wu_ref,  # [1, d, FT]
    wd_ref,  # [1, FT, d]
    out_ref,  # [1, CAP, d]
):
    s = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(active_ref[s] > 0)
    def _compute():
        x = x_ref[0]  # [CAP, d]
        g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        u = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)  # [CAP, FT]
        acc = jnp.dot(h, wd_ref[0], preferred_element_type=jnp.float32)
        out_ref[0] = (out_ref[0].astype(jnp.float32) + acc).astype(out_ref.dtype)


def expert_ffn_pallas(
    x: jax.Array,  # [S, CAP, d] capacity-packed tokens per slot
    w_gate: jax.Array,  # [E, d, f] logical (or [S, d, f] stacked w/ identity map)
    w_up: jax.Array,  # [E, d, f]
    w_down: jax.Array,  # [E, f, d]
    active: jax.Array,  # [S] int32/bool — slot activation bitmap
    slot_to_expert: jax.Array | None = None,  # [S] int32, -1 → skip; None = identity
    *,
    ff_tile: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """SwiGLU expert FFN per slot with slot-indirect weight reads.

    Inactive slots (``active == 0`` or ``slot_to_expert == -1``) yield zeros
    and stream no weights.
    """
    S, CAP, d = x.shape
    f = w_gate.shape[-1]
    FT = min(ff_tile, f)
    if f % FT:
        raise ValueError(f"d_ff={f} not divisible by ff_tile={FT}")
    nft = f // FT
    if slot_to_expert is None:
        if w_gate.shape[0] != S:
            raise ValueError(
                f"identity slot map needs stacked weights: {w_gate.shape[0]} != {S}"
            )
        slot_to_expert = jnp.arange(S, dtype=jnp.int32)
    slot_to_expert = slot_to_expert.astype(jnp.int32)
    active = (active.astype(jnp.int32) * (slot_to_expert >= 0)).astype(jnp.int32)

    def _wslab(s, j, s2e, act):
        return (jnp.maximum(s2e[s], 0), 0, j)

    def _wslab_t(s, j, s2e, act):
        return (jnp.maximum(s2e[s], 0), j, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(S, nft),
        in_specs=[
            pl.BlockSpec((1, CAP, d), lambda s, j, s2e, act: (s, 0, 0)),
            pl.BlockSpec((1, d, FT), _wslab),
            pl.BlockSpec((1, d, FT), _wslab),
            pl.BlockSpec((1, FT, d), _wslab_t),
        ],
        out_specs=pl.BlockSpec((1, CAP, d), lambda s, j, s2e, act: (s, 0, 0)),
    )
    return pl.pallas_call(
        _expert_ffn_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((S, CAP, d), x.dtype),
        interpret=interpret,
    )(slot_to_expert, active, x, w_gate, w_up, w_down)
