"""Grouped expert-FFN Pallas kernel — the MoE hot loop (§2.2 / Fig. 2).

The paper's central performance fact is that MoE-layer latency is set by the
number of *distinct activated experts* per instance, because each activated
expert's weights must be streamed from HBM regardless of its token count.
This kernel makes that structure explicit on TPU:

  grid = (num_slots, d_ff_tiles)
  * inactive expert slots are skipped entirely via ``@pl.when`` — no weight
    streaming, no FLOPs: per-instance time ∝ activated-slot count, exactly
    the β·a_max model of Eq. 1c;
  * active slots run a double GEMM (gate/up) + SwiGLU + down-projection over
    their capacity-packed token block, tiled along d_ff so every working set
    fits VMEM with MXU-aligned (multiples of 128) matmul dims;
  * the down-projection accumulates across d_ff tiles into the output block
    (the d_ff grid axis iterates innermost → sequential on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _expert_ffn_kernel(
    active_ref,  # [1, 1] int32 — is this slot activated?
    x_ref,  # [1, CAP, d]
    wg_ref,  # [1, d, FT]
    wu_ref,  # [1, d, FT]
    wd_ref,  # [1, FT, d]
    out_ref,  # [1, CAP, d]
    *,
    num_ff_tiles: int,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(active_ref[0, 0] > 0)
    def _compute():
        x = x_ref[0]  # [CAP, d]
        g = jnp.dot(x, wg_ref[0], preferred_element_type=jnp.float32)
        u = jnp.dot(x, wu_ref[0], preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(x.dtype)  # [CAP, FT]
        acc = jnp.dot(h, wd_ref[0], preferred_element_type=jnp.float32)
        out_ref[0] = (out_ref[0].astype(jnp.float32) + acc).astype(out_ref.dtype)


def expert_ffn_pallas(
    x: jax.Array,  # [S, CAP, d] capacity-packed tokens per slot
    w_gate: jax.Array,  # [S, d, f]
    w_up: jax.Array,  # [S, d, f]
    w_down: jax.Array,  # [S, f, d]
    active: jax.Array,  # [S] int32/bool — slot activation bitmap
    *,
    ff_tile: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """SwiGLU expert FFN per slot; inactive slots yield zeros."""
    S, CAP, d = x.shape
    f = w_gate.shape[-1]
    FT = min(ff_tile, f)
    if f % FT:
        raise ValueError(f"d_ff={f} not divisible by ff_tile={FT}")
    nft = f // FT
    active = active.astype(jnp.int32).reshape(S, 1)

    return pl.pallas_call(
        functools.partial(_expert_ffn_kernel, num_ff_tiles=nft),
        grid=(S, nft),
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, j: (s, 0)),
            pl.BlockSpec((1, CAP, d), lambda s, j: (s, 0, 0)),
            pl.BlockSpec((1, d, FT), lambda s, j: (s, 0, j)),
            pl.BlockSpec((1, d, FT), lambda s, j: (s, 0, j)),
            pl.BlockSpec((1, FT, d), lambda s, j: (s, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, CAP, d), lambda s, j: (s, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((S, CAP, d), x.dtype),
        interpret=interpret,
    )(active, x, w_gate, w_up, w_down)
