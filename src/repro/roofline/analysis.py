"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (DESIGN.md / prompt spec):

    compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
    memory     = HLO_bytes   / (chips × HBM_bw)
    collective = coll_bytes  / (chips × link_bw)

``cost_analysis`` of an SPMD-compiled executable reports *per-device* flops
and bytes, so we scale by the device count to get the cluster totals the
formulas above divide back down (equivalently: per-device values divided by
per-chip peaks).  Collective bytes are parsed from the compiled (partitioned)
HLO text: the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op, which are per-device
quantities.

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (we count ~3 usable links, but report the single-link figure the prompt
specifies for the collective term)."""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
LINK_BW = 50e9  # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# result shapes like `bf16[2,128]{1,0}` or tuples `(f32[8]{0}, f32[8]{0})`
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes moved by each collective kind (result-shape basis)."""
    out = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(.+?)\s+([\w-]+)\(", stripped)
        if not m:
            continue
        result_shape, opname = m.groups()
        for kind in _COLLECTIVES:
            if opname == kind or opname.startswith(kind + "-"):
                out[kind] += _shape_bytes(result_shape)
                out["count"] += 1
                break
    return out


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collective_breakdown: Dict[str, int]
    peak_memory_per_device: float
    model_flops: float  # 6·N_active·D analytic
    # XLA's cost_analysis counts a while-loop body ONCE, not × trip count
    # (verified by calibration: a bare sharded matmul reports exactly
    # 2MNK/devices, but a scan over L layer-periods reports ≈ 1/L of the true
    # cost).  All our step functions put the layer stack in a scan, so the
    # three terms are scaled by the period count (the dominant loop).  Inner
    # loops (SSM time scan, q-chunk map) are still counted once — noted in
    # EXPERIMENTS.md §Roofline.
    loop_scale: float = 1.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_device * self.loop_scale / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device * self.loop_scale / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes_per_device * self.loop_scale / LINK_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.loop_scale * self.chips
        return self.model_flops / total if total > 0 else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes_per_device,
            "collective_breakdown": self.collective_breakdown,
            "peak_memory_per_device": self.peak_memory_per_device,
            "model_flops": self.model_flops,
            "loop_scale": self.loop_scale,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def model_flops_estimate(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D (dense) / 6·N_active·D (MoE) for training;
    2·N·D for a forward-only step (prefill), 2·N_active per decoded token."""
    pc = cfg.param_counts()
    n_dense = pc["attn"] + pc["ffn"] + pc["ssm"] + pc["norm"] + pc["embed"]
    if cfg.has_moe:
        active_frac = cfg.top_k / max(1, cfg.num_experts)
        n_active = n_dense + pc["expert"] * active_frac
    else:
        n_active = n_dense
    if shape.kind == "train":
        per_tok = 6.0 * n_active
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        per_tok = 2.0 * n_active
        tokens = shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence
        per_tok = 2.0 * n_active
        tokens = shape.global_batch
    return per_tok * tokens


def analyze(
    arch: str,
    shape_name: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    mem: Optional[object],
    model_flops: float,
    loop_scale: float = 1.0,
) -> RooflineTerms:
    coll = collective_bytes(hlo_text)
    coll_total = sum(v for k, v in coll.items() if k != "count")
    peak_mem = 0.0
    if mem is not None:
        peak_mem = float(
            getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0)
        )
    return RooflineTerms(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_bytes_per_device=float(coll_total),
        collective_breakdown=coll,
        peak_memory_per_device=peak_mem,
        model_flops=model_flops,
        loop_scale=loop_scale,
    )


def save(terms: RooflineTerms, path: str) -> None:
    with open(path, "w") as f:
        json.dump(terms.to_dict(), f, indent=1)
