"""Config for yi-34b — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b",
    family="dense",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64_000,
    ffn_activation="swiglu",
    rope_theta=5_000_000.0,
    source="arXiv:2403.04652 (Yi; llama-arch GQA)",
)
