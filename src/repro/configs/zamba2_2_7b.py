"""Config for zamba2-27b — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_version=2,
    ssm_head_dim=64,
    hybrid_attn_every=6,  # shared attention block applied every 6 mamba layers
    source="arXiv:2411.15242 (Zamba2; Mamba-2 backbone + shared attention blocks)",
)
