"""Aggregates the per-architecture config modules into registries.

Each assigned architecture lives in its own module (one ``<arch>.py`` per
architecture, per the framework layout) and exposes a single ``CONFIG``.
"""

from __future__ import annotations

from typing import Dict

from repro.configs.base import ModelConfig
from repro.configs.gemma_7b import CONFIG as GEMMA_7B
from repro.configs.yi_34b import CONFIG as YI_34B
from repro.configs.pixtral_12b import CONFIG as PIXTRAL_12B
from repro.configs.falcon_mamba_7b import CONFIG as FALCON_MAMBA_7B
from repro.configs.gemma2_2b import CONFIG as GEMMA2_2B
from repro.configs.phi4_mini_3_8b import CONFIG as PHI4_MINI_38B
from repro.configs.qwen2_moe_a2_7b import CONFIG as QWEN2_MOE_A27B
from repro.configs.zamba2_2_7b import CONFIG as ZAMBA2_27B
from repro.configs.whisper_tiny import CONFIG as WHISPER_TINY
from repro.configs.phi3_5_moe_42b import CONFIG as PHI35_MOE_42B
from repro.configs.dsv2_lite import CONFIG as DSV2_LITE
from repro.configs.dsv2 import CONFIG as DSV2
from repro.configs.scaled_ds_1 import CONFIG as SCALED_DS_1
from repro.configs.scaled_ds_2 import CONFIG as SCALED_DS_2

ASSIGNED: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        GEMMA_7B,
        YI_34B,
        PIXTRAL_12B,
        FALCON_MAMBA_7B,
        GEMMA2_2B,
        PHI4_MINI_38B,
        QWEN2_MOE_A27B,
        ZAMBA2_27B,
        WHISPER_TINY,
        PHI35_MOE_42B,
    )
}

PAPER_MODELS: Dict[str, ModelConfig] = {
    c.name: c for c in (DSV2_LITE, DSV2, SCALED_DS_1, SCALED_DS_2)
}

REGISTRY: Dict[str, ModelConfig] = {**ASSIGNED, **PAPER_MODELS}
