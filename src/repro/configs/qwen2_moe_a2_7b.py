"""Config for qwen2-moe-a27b — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=151_936,
    num_experts=60,
    num_shared_experts=4,
    top_k=4,
    d_ff_expert=1408,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B (60 routed top-4 + 4 shared experts)",
)
