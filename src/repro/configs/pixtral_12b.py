"""Config for pixtral-12b — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131_072,
    ffn_activation="swiglu",
    rope_theta=1_000_000.0,
    frontend="vision_patches",
    num_patch_tokens=256,  # one 1024px image tile -> 16x16 patch grid stub
    source="hf:mistralai/Pixtral-12B-2409 (pixtral-ViT frontend stubbed; mistral-nemo backbone)",
)
