"""Config registry: ``get_config(name)`` / ``REGISTRY`` / shapes."""

from repro.configs.base import (
    LONG_CONTEXT_ARCHS,
    SHAPES,
    InputShape,
    ModelConfig,
    input_specs,
    shape_supported,
)
from repro.configs.archs import ASSIGNED, PAPER_MODELS, REGISTRY


def get_config(name: str) -> ModelConfig:
    if name.endswith("-reduced"):
        return REGISTRY[name[: -len("-reduced")]].reduced()
    return REGISTRY[name]


__all__ = [
    "ASSIGNED",
    "LONG_CONTEXT_ARCHS",
    "PAPER_MODELS",
    "REGISTRY",
    "SHAPES",
    "InputShape",
    "ModelConfig",
    "get_config",
    "input_specs",
    "shape_supported",
]
