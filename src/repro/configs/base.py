"""Configuration system for the repro framework.

``ModelConfig`` is a frozen dataclass that can describe every architecture
family this framework supports (dense GQA transformers, MoE transformers,
Mamba-1/2 SSMs, hybrid SSM+attention stacks, encoder-decoder audio models and
VLM text backbones).  Each assigned architecture lives in its own module under
``repro.configs`` and registers itself in ``repro.configs.REGISTRY``.

``InputShape`` describes one of the assigned workload shapes (train_4k,
prefill_32k, decode_32k, long_500k).  ``input_specs`` builds
``jax.ShapeDtypeStruct`` stand-ins for every model input of a given
(config, shape) pair — these are what the multi-pod dry-run lowers against,
so they must never allocate device memory.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """A single description language for every supported architecture."""

    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention variants -------------------------------------------------
    rope_theta: float = 10_000.0
    use_rope: bool = True
    sliding_window: Optional[int] = None  # window for local layers
    attn_pattern: str = "global"  # "global" | "local_global" (alternating)
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    use_qk_norm: bool = False

    # --- FFN -----------------------------------------------------------------
    ffn_activation: str = "swiglu"  # swiglu | geglu | gelu

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1  # a layer l is MoE iff num_experts>0 and l % moe_every == 0
    router_jitter: float = 0.0
    capacity_factor: float = 1.25

    # --- SSM (Mamba) ----------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1  # 1 = Mamba-1 (falcon-mamba), 2 = Mamba-2 (zamba2)
    ssm_head_dim: int = 64  # Mamba-2 head dim

    # --- hybrid (zamba2-style shared attention blocks) ------------------------
    hybrid_attn_every: int = 0  # insert shared attn block every N ssm layers

    # --- encoder-decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder sequence length (audio frames)

    # --- modality frontend stub -------------------------------------------------
    frontend: Optional[str] = None  # None | "audio_frames" | "vision_patches"
    num_patch_tokens: int = 0  # VLM: prompt prefix of image-patch embeddings

    # --- numerics ----------------------------------------------------------------
    dtype: str = "bfloat16"
    kv_quant: bool = False  # int8 KV cache (per-token-per-head absmax scales)
    norm_eps: float = 1e-6

    # --- provenance ----------------------------------------------------------------
    source: str = ""  # citation

    # -------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def has_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        """Mamba inner dimension."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_num_heads(self) -> int:
        """Mamba-2 head count."""
        return max(1, self.d_inner // self.ssm_head_dim)

    @property
    def conv_dim(self) -> int:
        """Channels covered by the depthwise conv in the mamba block.

        Mamba-1 convolves x only; Mamba-2 convolves [x, B, C] (n_groups=1).
        """
        if self.ssm_version == 2:
            return self.d_inner + 2 * self.ssm_state
        return self.d_inner

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-decoder-layer kind: attn+ffn composition for this family.

        Returns a tuple of strings, one per layer, drawn from:
          "dense"        attention + dense FFN
          "dense_local"  sliding-window attention + dense FFN
          "moe"          attention + MoE FFN
          "ssm"          mamba block (no attention)
          "ssm_hybrid"   mamba block + shared attention block
        """
        kinds = []
        for l in range(self.num_layers):
            if self.family == "ssm":
                kinds.append("ssm")
            elif self.family == "hybrid":
                if self.hybrid_attn_every and l % self.hybrid_attn_every == 0:
                    kinds.append("ssm_hybrid")
                else:
                    kinds.append("ssm")
            elif self.has_moe and l % self.moe_every == 0:
                kinds.append("moe")
            elif self.attn_pattern == "local_global":
                # even layers local (sliding window), odd layers global
                kinds.append("dense_local" if l % 2 == 0 else "dense")
            else:
                kinds.append("dense")
        return tuple(kinds)

    # -------------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family variant for CPU smoke tests.

        ≤2 layers, d_model ≤ 512, ≤4 experts, small vocab.
        """
        d_model = min(self.d_model, 256)
        num_heads = min(self.num_heads, 4)
        num_kv = max(1, min(self.num_kv_heads, num_heads))
        # keep the GQA ratio flavour: if the full config is GQA, stay GQA
        if self.num_kv_heads < self.num_heads:
            num_kv = max(1, num_heads // 2)
        head_dim = 64
        changes: Dict[str, Any] = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=num_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
        )
        if self.has_moe:
            changes.update(
                num_experts=min(self.num_experts, 4),
                top_k=min(self.top_k, 2),
                d_ff_expert=min(self.d_ff_expert, 128),
                num_shared_experts=min(self.num_shared_experts, 1),
            )
        if self.family in ("ssm", "hybrid"):
            changes.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=32)
        if self.family == "hybrid":
            changes.update(hybrid_attn_every=1)
        if self.encoder_layers:
            changes.update(encoder_layers=1, encoder_seq=min(self.encoder_seq, 64))
        if self.num_patch_tokens:
            changes.update(num_patch_tokens=16)
        return dataclasses.replace(self, **changes)

    # -------------------------------------------------------------------------
    # Parameter / memory accounting (used by Table-1 bench + scaling model)
    # -------------------------------------------------------------------------
    def param_counts(self) -> Dict[str, int]:
        """Approximate parameter counts per subsystem (embedding, attention,
        dense ffn, expert ffn, ssm)."""
        d = self.d_model
        hd = self.resolved_head_dim
        nh, nkv = self.num_heads, self.num_kv_heads
        counts = dict(embed=0, attn=0, ffn=0, expert=0, ssm=0, norm=0)
        counts["embed"] = self.vocab_size * d * (2 if self.encoder_layers else 1)
        attn_p = d * nh * hd + 2 * d * nkv * hd + nh * hd * d
        glu_mult = 3 if self.ffn_activation in ("swiglu", "geglu") else 2
        ffn_p = glu_mult * d * self.d_ff if self.d_ff else 0
        kinds = self.layer_kinds()
        n_attn = sum(1 for k in kinds if k in ("dense", "dense_local", "moe", "ssm_hybrid"))
        n_dense_ffn = sum(1 for k in kinds if k in ("dense", "dense_local", "ssm_hybrid"))
        n_moe = sum(1 for k in kinds if k == "moe")
        n_ssm = sum(1 for k in kinds if k.startswith("ssm"))
        counts["attn"] = n_attn * attn_p
        counts["ffn"] = n_dense_ffn * ffn_p
        if n_moe:
            expert_p = glu_mult * d * self.d_ff_expert
            routed = self.num_experts * expert_p
            shared = self.num_shared_experts * expert_p
            router = d * self.num_experts
            counts["expert"] = n_moe * routed
            counts["ffn"] += n_moe * (shared + router)
        if n_ssm:
            di = self.d_inner
            ssm_p = (
                d * 2 * di  # in_proj
                + di * self.ssm_conv  # conv
                + di * d  # out_proj
            )
            if self.ssm_version == 1:
                dt_rank = max(1, math.ceil(d / 16))
                ssm_p += di * (dt_rank + 2 * self.ssm_state) + dt_rank * di + di * self.ssm_state + di
            else:
                nh2 = self.ssm_num_heads
                ssm_p += d * (2 * self.ssm_state + nh2) + nh2 * 2 + di
            counts["ssm"] = n_ssm * ssm_p
        if self.encoder_layers:
            counts["attn"] += self.encoder_layers * attn_p * 2  # self+cross approx
            counts["ffn"] += self.encoder_layers * ffn_p
        counts["norm"] = self.num_layers * 4 * d
        return counts

    def total_params(self) -> int:
        return sum(self.param_counts().values())

    def expert_param_fraction(self) -> float:
        c = self.param_counts()
        tot = sum(c.values())
        return c["expert"] / tot if tot else 0.0

    def bytes_per_param(self) -> int:
        return 2 if self.dtype == "bfloat16" else 4

    def kv_bytes_per_token(self) -> int:
        """KV-cache bytes per token across all attention layers."""
        kinds = self.layer_kinds()
        n_attn = sum(1 for k in kinds if k != "ssm")
        return n_attn * 2 * self.num_kv_heads * self.resolved_head_dim * self.bytes_per_param()


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

# Sub-quadratic families allowed to run long_500k.  gemma2 qualifies because
# its local layers use a sliding window (see DESIGN.md §4).
LONG_CONTEXT_ARCHS = ("falcon-mamba-7b", "zamba2-2.7b", "gemma2-2b")


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether (arch, shape) runs, and the reason if not (recorded in DESIGN)."""
    if shape.name == "long_500k" and cfg.name not in LONG_CONTEXT_ARCHS:
        if cfg.name.endswith("-reduced") and cfg.name[: -len("-reduced")] in LONG_CONTEXT_ARCHS:
            return True, ""
        return False, "pure full-attention arch: long_500k requires sub-quadratic attention"
    return True, ""


# ---------------------------------------------------------------------------
# Input specs — ShapeDtypeStruct stand-ins (dry-run safe: no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, jax.ShapeDtypeStruct]:
    """Build the exact abstract inputs that train_step / prefill_step /
    serve_step of this architecture consume."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    specs: Dict[str, jax.ShapeDtypeStruct] = {}

    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token against a KV cache of length S
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["cache_index"] = jax.ShapeDtypeStruct((), i32)
        specs.update(_cache_specs(cfg, B, S, dt))

    # modality frontend stubs — precomputed embeddings of the right shape
    if cfg.frontend == "audio_frames" and shape.kind != "decode":
        # decode consumes the cached encoder output (`enc_out`) instead
        specs["encoder_frames"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
    elif cfg.frontend == "vision_patches" and shape.kind != "decode":
        specs["patch_embeds"] = jax.ShapeDtypeStruct((B, cfg.num_patch_tokens, cfg.d_model), dt)

    return specs


def _cache_specs(cfg: ModelConfig, B: int, S: int, dt) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract decode-state (KV caches / SSM states) for serve_step."""
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    kinds = cfg.layer_kinds()
    hd = cfg.resolved_head_dim
    n_full = sum(1 for k in kinds if k in ("dense", "moe"))
    n_local = sum(1 for k in kinds if k == "dense_local")
    n_ssm = sum(1 for k in kinds if k.startswith("ssm"))
    n_hyb = sum(1 for k in kinds if k == "ssm_hybrid")
    kv_dt = jnp.int8 if cfg.kv_quant else dt

    def kv(name, n, L):
        specs[f"kv_k{name}"] = jax.ShapeDtypeStruct((n, B, L, cfg.num_kv_heads, hd), kv_dt)
        specs[f"kv_v{name}"] = jax.ShapeDtypeStruct((n, B, L, cfg.num_kv_heads, hd), kv_dt)
        if cfg.kv_quant:
            specs[f"kv_k{name}_scale"] = jax.ShapeDtypeStruct((n, B, L, cfg.num_kv_heads), jnp.float32)
            specs[f"kv_v{name}_scale"] = jax.ShapeDtypeStruct((n, B, L, cfg.num_kv_heads), jnp.float32)

    if n_full:
        kv("", n_full, S)
    if n_local:
        kv("_local", n_local, min(S, cfg.sliding_window or S))
    if n_hyb:
        kv("_hybrid", n_hyb, S)
    if n_ssm:
        di = cfg.d_inner
        if cfg.ssm_version == 1:
            specs["ssm_state"] = jax.ShapeDtypeStruct((n_ssm, B, di, cfg.ssm_state), jnp.float32)
        else:
            specs["ssm_state"] = jax.ShapeDtypeStruct(
                (n_ssm, B, cfg.ssm_num_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
            )
        specs["conv_state"] = jax.ShapeDtypeStruct((n_ssm, B, cfg.ssm_conv - 1, cfg.conv_dim), dt)
    if cfg.encoder_layers:
        specs["enc_out"] = jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt)
    return specs
