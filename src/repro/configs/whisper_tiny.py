"""Config for whisper-tiny — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51_865,
    ffn_activation="gelu",
    use_rope=False,  # sinusoidal absolute positions
    encoder_layers=4,
    encoder_seq=1500,
    frontend="audio_frames",
    source="arXiv:2212.04356 (Whisper; enc-dec, conv/mel frontend stubbed)",
)
