"""Config for dsv2-lite — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dsv2-lite",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=102_400,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    d_ff_expert=1408,
    source="arXiv:2405.04434 (DeepSeek-V2-Lite routing structure; paper's model family)",
)
