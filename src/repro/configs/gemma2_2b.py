"""Config for gemma2-2b — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    ffn_activation="geglu",
    attn_pattern="local_global",
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    source="arXiv:2408.00118 (Gemma 2; local+global alternating, logit softcap)",
)
