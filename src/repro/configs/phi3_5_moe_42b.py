"""Config for phi35-moe-42b — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32_064,
    num_experts=16,
    num_shared_experts=0,
    top_k=2,
    d_ff_expert=6400,
    source="hf:microsoft/Phi-3.5-MoE-instruct (16 experts top-2)",
)
