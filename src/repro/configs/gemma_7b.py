"""Config for gemma-7b — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    ffn_activation="geglu",
    source="arXiv:2403.08295 (Gemma; GeGLU, head_dim=256; the 2b sibling is MQA)",
)
