"""Config for deepseek-v2 (full scale) — the paper's primary evaluation model."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dsv2",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=0,
    vocab_size=102_400,
    num_experts=160,
    num_shared_experts=2,
    top_k=6,
    d_ff_expert=1536,
    source="arXiv:2405.04434 (DeepSeek-V2 236B: 160 routed top-6 + 2 shared; "
    "MLA approximated as MHA for the serving-system study)",
)
