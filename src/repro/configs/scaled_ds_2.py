"""Config for scaled-ds-2 — see `source` field for citation."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="scaled-ds-2",
    family="moe",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=102_400,
    num_experts=200,
    num_shared_experts=2,
    top_k=8,
    d_ff_expert=1536,
    source="Janus §5.1 Scaled-DS-2 (200 experts, top-8, expert d_ff 1536)",
)
